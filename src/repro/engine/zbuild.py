"""Z-build stage: the §4.3 TTM hot spot, one implementation for every path.

Each HOOI mode step first materializes the (local) penultimate matrix
``Z = segment_sum(kron_contributions, rows)``. Two variants exist — the
pure-jnp reference and the Pallas ``kron_segsum`` kernel (the one-hot-matmul
reformulation, ``repro.kernels``) — and the choice is *static*: it is baked
into the trace, so executors must key compiled steps on it.

``resolve_kernel`` is the one gate: VMEM admission (``tile_geometry``) plus
the backend policy. ``use_kernel=None`` auto-engages the kernel on a real
TPU backend only (off-TPU it would run in interpret mode, far slower than
the reference) — unless the ``REPRO_FORCE_KERNEL=1`` environment variable is
set, which treats ``None`` as "kernel wherever it fits" so CI can run the
whole fast suite through the interpret-mode kernel path as a blocking job.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro import envknobs
from repro.core.ttm import kron_contributions
from repro.kernels import ops as kernel_ops

__all__ = ["build_local_z", "build_local_z_oracle", "resolve_kernel",
           "kernel_forced_by_env", "resolve_precision",
           "resolve_fused_zbuild", "PRECISIONS"]

PRECISIONS = envknobs.PRECISIONS  # historical re-export


def kernel_forced_by_env() -> bool:
    """True when ``REPRO_FORCE_KERNEL=1``: auto-resolution engages the
    (interpret-mode, off-TPU) kernel wherever the VMEM gate admits it.
    Parsing lives in ``repro.envknobs`` (malformed values raise)."""
    return envknobs.force_kernel()


def resolve_precision(precision: str | None) -> str:
    """Static Z-build precision for a mode step: ``"f32"`` or ``"bf16"``.

    ``None``/``"auto"`` honor ``REPRO_PRECISION`` (CI's bf16 leg; parsed and
    validated by ``repro.envknobs``); ``"auto"`` additionally consults the
    fitted ``CostModel`` — when calibration measured a materially faster
    bf16 TTM rate, auto picks bf16. The resolved value is static (baked
    into traces and compiled-step cache keys).
    """
    if precision in PRECISIONS:
        return precision
    if precision not in (None, "auto"):
        raise ValueError(f"unknown precision {precision!r} "
                         f"(expected one of {PRECISIONS + ('auto', None)})")
    env = envknobs.precision()
    if env is not None:
        return env
    if precision == "auto":
        from repro.core.calibrate import current_cost_model

        model = current_cost_model()
        bf16 = getattr(model, "ttm_flop_rate_bf16", None)
        f32 = model.ttm_flop_rate or model.flop_rate
        if bf16 and bf16 > 1.05 * f32:
            return "bf16"
    return "f32"


def resolve_fused_zbuild(fused_zbuild: bool | None) -> bool:
    """Static fused Z-build→first-oracle pipeline decision.

    ``None`` honors ``REPRO_FUSED_ZBUILD=1`` (CI leg; parsed by
    ``repro.envknobs``), else off. Like the kernel flag, the resolved value
    must be part of compiled-step keys.
    """
    if fused_zbuild is None:
        return envknobs.fused_zbuild()
    return bool(fused_zbuild)


def resolve_kernel(num_rows: int, core_dims: Sequence[int], mode: int,
                   use_kernel: bool | None) -> bool:
    """Static kernel/reference decision for one mode step's Z build.

    ``True`` forces the kernel wherever the VMEM gate admits the shape
    (differential tests); ``False`` pins the jnp ``segment_sum`` reference;
    ``None`` is the auto policy described in the module docstring. The
    resolved choice must be part of any compiled-step cache key.
    """
    if use_kernel is False:
        return False
    Ka, Kb = kernel_ops.split_kron_dims(core_dims, mode)
    fits = kernel_ops.kernel_fits_vmem(num_rows, Ka, Kb)
    if use_kernel is None:
        return fits and (jax.default_backend() == "tpu"
                         or kernel_forced_by_env())
    return fits


def build_local_z(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    local_rows: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_rows: int,
    *,
    use_kernel: bool = False,
    sorted_rows: bool = True,
    precision: str = "f32",
) -> jnp.ndarray:
    """The (local) penultimate matrix Z — (num_rows, K_hat).

    ``use_kernel`` routes through the Pallas ``kron_segsum`` kernel.
    ``sorted_rows=True`` asserts the partition.py contract (per-rank
    elements pre-sorted by dense local row id), skipping the runtime
    argsort; the single-process path passes ``sorted_rows=False`` since raw
    COO order is arbitrary. ``precision="bf16"`` rounds kron contributions
    to bf16 with f32 accumulation (kernel and reference implement the same
    contract). All flags are static (baked into the trace).
    """
    if use_kernel:
        fn = (kernel_ops.penultimate_sorted if sorted_rows
              else kernel_ops.penultimate_local)
        return fn(coords, values, local_rows, factors, mode, num_rows,
                  use_kernel=True, precision=precision)
    contribs = kron_contributions(coords, values, factors, mode)
    if precision == "bf16":
        contribs = contribs.astype(jnp.bfloat16).astype(jnp.float32)
    return jax.ops.segment_sum(contribs, local_rows, num_segments=num_rows)


def build_local_z_oracle(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    local_rows: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_rows: int,
    X: jnp.ndarray,  # (K_hat, s) first oracle panel
    *,
    use_kernel: bool = False,
    sorted_rows: bool = True,
    precision: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused pipeline stage: ``(Z, Z @ X)`` in one pass over the elements.

    On the kernel path the first oracle product is contracted against the
    VMEM-resident Z tile inside the same ``pallas_call`` (one HBM round-trip
    of Z saved per sweep·mode); the reference fallback computes the same
    product explicitly, keeping numerics identical across the gate.
    """
    if use_kernel and sorted_rows:
        return kernel_ops.penultimate_sorted_oracle(
            coords, values, local_rows, factors, mode, num_rows, X,
            use_kernel=True, precision=precision)
    Z = build_local_z(coords, values, local_rows, factors, mode, num_rows,
                      use_kernel=use_kernel, sorted_rows=sorted_rows,
                      precision=precision)
    return Z, Z @ X
