"""Z-build stage: the §4.3 TTM hot spot, one implementation for every path.

Each HOOI mode step first materializes the (local) penultimate matrix
``Z = segment_sum(kron_contributions, rows)``. Two variants exist — the
pure-jnp reference and the Pallas ``kron_segsum`` kernel (the one-hot-matmul
reformulation, ``repro.kernels``) — and the choice is *static*: it is baked
into the trace, so executors must key compiled steps on it.

``resolve_kernel`` is the one gate: VMEM admission (``tile_geometry``) plus
the backend policy. ``use_kernel=None`` auto-engages the kernel on a real
TPU backend only (off-TPU it would run in interpret mode, far slower than
the reference) — unless the ``REPRO_FORCE_KERNEL=1`` environment variable is
set, which treats ``None`` as "kernel wherever it fits" so CI can run the
whole fast suite through the interpret-mode kernel path as a blocking job.
"""

from __future__ import annotations

import os
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.ttm import kron_contributions
from repro.kernels import ops as kernel_ops

__all__ = ["build_local_z", "resolve_kernel", "kernel_forced_by_env"]


def kernel_forced_by_env() -> bool:
    """True when ``REPRO_FORCE_KERNEL=1``: auto-resolution engages the
    (interpret-mode, off-TPU) kernel wherever the VMEM gate admits it."""
    return os.environ.get("REPRO_FORCE_KERNEL", "") == "1"


def resolve_kernel(num_rows: int, core_dims: Sequence[int], mode: int,
                   use_kernel: bool | None) -> bool:
    """Static kernel/reference decision for one mode step's Z build.

    ``True`` forces the kernel wherever the VMEM gate admits the shape
    (differential tests); ``False`` pins the jnp ``segment_sum`` reference;
    ``None`` is the auto policy described in the module docstring. The
    resolved choice must be part of any compiled-step cache key.
    """
    if use_kernel is False:
        return False
    Ka, Kb = kernel_ops.split_kron_dims(core_dims, mode)
    fits = kernel_ops.kernel_fits_vmem(num_rows, Ka, Kb)
    if use_kernel is None:
        return fits and (jax.default_backend() == "tpu"
                         or kernel_forced_by_env())
    return fits


def build_local_z(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    local_rows: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_rows: int,
    *,
    use_kernel: bool = False,
    sorted_rows: bool = True,
) -> jnp.ndarray:
    """The (local) penultimate matrix Z — (num_rows, K_hat).

    ``use_kernel`` routes through the Pallas ``kron_segsum`` kernel.
    ``sorted_rows=True`` asserts the partition.py contract (per-rank
    elements pre-sorted by dense local row id), skipping the runtime
    argsort; the single-process path passes ``sorted_rows=False`` since raw
    COO order is arbitrary. Both flags are static (baked into the trace).
    """
    if use_kernel:
        fn = (kernel_ops.penultimate_sorted if sorted_rows
              else kernel_ops.penultimate_local)
        return fn(coords, values, local_rows, factors, mode, num_rows,
                  use_kernel=True)
    contribs = kron_contributions(coords, values, factors, mode)
    return jax.ops.segment_sum(contribs, local_rows, num_segments=num_rows)
