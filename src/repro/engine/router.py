"""StreamRouter: priority + cost routing over an ExecutorPool, with
admission control and backpressure.

The paper's thesis is that distribution decisions are cheap enough to make
at serve time; the router applies that one level up — *placement across
executors* is also decided per submission, from the same modeled cost the
``auto`` selector uses (``PlanCost``):

* **lane choice** — each lane carries a modeled backlog (seconds of
  admitted-but-unfinished work). A new source goes to the least-loaded
  lane; a known source's cost estimate is, in order of preference, its
  last *measured* prepare+sweep seconds, the modeled
  ``PlanCost.total_s x n_invocations`` of its adopted plan, then a flat
  default. Streams are **sticky**: a ``StreamingTensor`` keeps its lane so
  the refresh ladder (reuse / repartition) and the lane executor's caches
  stay warm.

* **admission control** — a bounded queue over the whole pool
  (``max_pending``), scaled per priority class: ``interactive`` may fill
  the whole queue, ``normal`` most of it, ``batch`` half (defaults;
  ``admission_shares``). When a class's share is full, ``submit`` raises
  ``PoolSaturated`` *immediately* — backpressure is surfaced to the
  caller, never absorbed into an unbounded internal queue. Priority
  governs admission and lane choice; within a lane, execution order stays
  submission order (the scheduler contract).

* **warm-start reroutes** — when a sticky stream's home lane is backlogged
  past ``reroute_threshold_s`` (or ``reroute()`` is called), the home
  lane's adopted plan is serialized with ``PartitionPlan.save()`` and
  ``load()``-ed against the stream's current snapshot on the target lane
  (the same bytes would cross processes). On success the target adopts it:
  the next submit replays as ``reuse``/``repartition`` instead of a full
  re-selection, and — because ``pad_geometric`` quantizes padded shapes —
  lands with 0 new jit wherever the target executor has already compiled
  shape-compatible steps. A stale plan (the stream grew since
  serialization) is refused by the fingerprint check and the stream
  simply re-plans cold on the new lane.

Per-stream accounting (queue wait, prepare/sweep seconds, SLO deadline
hit/miss, lane) lands on each run's ``DistHooiStats``; ``stats()``
aggregates the pool view into ``PoolStats``. See docs/scheduler.md.
"""

from __future__ import annotations

import dataclasses
import io
import threading
import weakref
from concurrent.futures import CancelledError, Future, wait as futures_wait

from repro.core.coo import SparseTensor
from repro.core.plan import PartitionPlan
from repro.engine.pool import ExecutorPool, PoolStats
from repro.streaming import StreamingTensor

__all__ = ["StreamRouter", "PoolSaturated", "ADMISSION_SHARES"]

# priority class -> fraction of max_pending that class may fill. Interactive
# traffic can always use headroom that batch admission left free, so a
# saturated batch tier never starves the latency-sensitive one.
ADMISSION_SHARES = {"interactive": 1.0, "normal": 0.85, "batch": 0.5}

# modeled-cost fallback for a source the router has never seen and that has
# no adopted plan yet (seconds per invocation; deliberately generic — the
# first completion replaces it with a measurement)
DEFAULT_COST_S = 0.05


class PoolSaturated(RuntimeError):
    """Admission refused: the pool's bounded queue is full for this class.

    Backpressure is the caller's signal to shed, delay, or retry at a
    higher priority — the router never buffers beyond ``max_pending``.
    """

    def __init__(self, priority: str, pending: int, limit: int):
        super().__init__(
            f"pool saturated for priority={priority!r}: {pending} pending "
            f">= class limit {limit} — retry later or raise the priority")
        self.priority = priority
        self.pending = pending
        self.limit = limit


class StreamRouter:
    """Routes ``submit()`` calls across an ``ExecutorPool``'s lanes.

    Thread-safe: many client threads may submit concurrently; completion
    bookkeeping runs on the lanes' worker threads. ``drain()`` returns
    results in global submission order (across lanes). ``close()`` closes
    the router *and* the pool's lanes.
    """

    def __init__(
        self,
        pool: ExecutorPool,
        *,
        max_pending: int = 64,
        admission_shares: dict | None = None,
        reroute_threshold_s: float | None = None,
        default_cost_s: float = DEFAULT_COST_S,
    ):
        self.pool = pool
        self.max_pending = int(max_pending)
        if self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self.shares = dict(ADMISSION_SHARES if admission_shares is None
                           else admission_shares)
        # None disables load-triggered reroutes (explicit reroute() always
        # works); small thresholds make hot lanes shed sticky streams
        self.reroute_threshold_s = reroute_threshold_s
        self.default_cost_s = float(default_cost_s)

        self._lock = threading.Lock()
        self._closed = False
        self._futures: list[Future] = []  # submission order, since last drain
        self._backlog = [0.0] * pool.n_lanes  # modeled pending seconds
        self._inflight = 0
        self._rr = 0  # round-robin tiebreak for equal backlogs
        # sticky lane per stream; weak so a dead stream frees its slot
        self._affinity: "weakref.WeakKeyDictionary[StreamingTensor, int]" \
            = weakref.WeakKeyDictionary()
        # last measured prepare+sweep seconds per source (cost estimator)
        self._measured: "weakref.WeakKeyDictionary[object, float]" \
            = weakref.WeakKeyDictionary()
        self._submitted = 0
        self._rejected: dict[str, int] = {}
        self._rerouted = 0

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "StreamRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Stop admitting, then drain and stop every pool lane."""
        with self._lock:
            self._closed = True
        self.pool.close()

    # --------------------------------------------------------------- submit
    def submit(
        self,
        source: SparseTensor | StreamingTensor,
        *,
        name: str | None = None,
        seed: int = 0,
        priority: str = "normal",
        deadline_s: float | None = None,
        n_invocations: int | None = None,
        objective=None,
    ) -> Future:
        """Admit, route, and queue one decomposition of ``source``.

        Raises ``PoolSaturated`` (backpressure) when ``priority``'s share
        of the bounded queue is full, and ``RuntimeError`` after
        ``close()``. On admission, returns the lane scheduler's future
        (resolves to a ``ScheduledResult``; SLO fields stamped when
        ``deadline_s`` is given). ``objective`` is forwarded to the lane
        scheduler (per-submission sweep objective override).
        """
        if priority not in self.shares:
            raise ValueError(f"unknown priority {priority!r}; known: "
                             f"{sorted(self.shares)}")
        with self._lock:
            if self._closed:
                raise RuntimeError("router is closed")
            limit = max(1, int(round(self.shares[priority]
                                     * self.max_pending)))
            if self._inflight >= limit:
                self._rejected[priority] = \
                    self._rejected.get(priority, 0) + 1
                raise PoolSaturated(priority, self._inflight, limit)
            est = self._estimate_cost(source, n_invocations)
            lane_i = self._choose_lane(source)
            lane = self.pool.lanes[lane_i]
            # submit under the router lock: _futures order must equal the
            # global submission order the lanes see (the lane scheduler
            # takes its own lock; it never calls back into the router, so
            # the router -> scheduler lock order cannot invert)
            fut = lane.scheduler.submit(
                source, name=name, seed=seed, deadline_s=deadline_s,
                n_invocations=n_invocations, objective=objective)
            self._inflight += 1
            self._backlog[lane_i] += est
            self._submitted += 1
            self._futures.append(fut)
        # outside the lock: done callbacks may fire inline if the job
        # already resolved, and they re-take the router lock
        fut.add_done_callback(
            lambda f, li=lane_i, e=est, src=source:
            self._on_done(li, e, src, f))
        return fut

    def drain(self, *, return_exceptions: bool = False) -> list:
        """Wait for everything admitted since the last drain; results in
        global submission order (semantics mirror ``StreamScheduler.drain``:
        all futures are awaited before any failure re-raises)."""
        with self._lock:
            futs = list(self._futures)
            self._futures.clear()
        futures_wait(futs)
        if return_exceptions:
            out = []
            for f in futs:
                if f.cancelled():
                    out.append(CancelledError())
                else:
                    e = f.exception()
                    out.append(e if e is not None else f.result())
            return out
        return [f.result() for f in futs]

    # -------------------------------------------------------------- routing
    def _estimate_cost(self, source, n_invocations) -> float:
        """Modeled seconds this submission will occupy its lane (lock held).

        Measured history beats the plan model beats the flat default —
        exactly the ``auto`` selector's calibration story applied to
        placement.
        """
        try:
            measured = self._measured.get(source)
        except TypeError:  # un-weakrefable source; fall through to model
            measured = None
        if measured is not None:
            return measured
        n = n_invocations
        if n is None:
            n = self.pool.lanes[0].scheduler.n_invocations
        if isinstance(source, StreamingTensor):
            home = self._affinity.get(source)
            if home is not None:
                pl = self.pool.lanes[home].scheduler.adopted_plan(source)
                if pl is not None:
                    return max(float(pl.cost.total_s) * n, 1e-6)
        return self.default_cost_s * n

    def _least_loaded(self, exclude: int | None = None) -> int:
        order = range(self.pool.n_lanes)
        cands = [i for i in order if i != exclude]
        best = min(cands, key=lambda i: (self._backlog[i],
                                         (i - self._rr)
                                         % self.pool.n_lanes))
        self._rr = (best + 1) % self.pool.n_lanes
        return best

    def _choose_lane(self, source) -> int:
        """Sticky for streams (with threshold-triggered warm-start
        reroutes), least modeled backlog otherwise. Lock held."""
        if isinstance(source, StreamingTensor):
            home = self._affinity.get(source)
            if home is None:
                home = self._least_loaded()
                self._affinity[source] = home
                return home
            if self.reroute_threshold_s is not None \
                    and self.pool.n_lanes > 1:
                best = self._least_loaded(exclude=home)
                if (self._backlog[home] - self._backlog[best]
                        > self.reroute_threshold_s):
                    return self._reroute_locked(source, home, best)
            return home
        return self._least_loaded()

    def _reroute_locked(self, src: StreamingTensor, home: int,
                        target: int) -> int:
        """Move a stream's affinity, carrying its plan via save()/load()."""
        pl = self.pool.lanes[home].scheduler.adopted_plan(src)
        if pl is not None and pl.fingerprint is not None:
            buf = io.BytesIO()
            try:
                # validate under the TARGET lane's objective: its view is
                # what future submits there will fingerprint against; an
                # objective mismatch is refused like a stale plan and the
                # stream simply re-plans cold on the new lane
                tsched = self.pool.lanes[target].scheduler
                pl.save(buf)
                warm = PartitionPlan.load(io.BytesIO(buf.getvalue()),
                                          src.snapshot(),
                                          objective=tsched.objective)
            except ValueError:
                warm = None  # stream grew since adoption: stale plan
            if warm is not None:
                tsched.adopt(src, warm)
        self._affinity[src] = target
        self._rerouted += 1
        return target

    def reroute(self, src: StreamingTensor, lane: int | None = None) -> int:
        """Explicitly move a stream to ``lane`` (default: least-loaded
        other lane), warm-starting its plan on the target. Returns the new
        lane index."""
        with self._lock:
            home = self._affinity.get(src)
            if home is None:
                raise ValueError("stream has no lane yet — submit it first")
            target = self._least_loaded(exclude=home) if lane is None \
                else int(lane)
            if not 0 <= target < self.pool.n_lanes:
                raise ValueError(f"lane {target} outside pool of "
                                 f"{self.pool.n_lanes}")
            if target == home:
                return home
            return self._reroute_locked(src, home, target)

    # ------------------------------------------------------------ bookkeeping
    def _on_done(self, lane_i: int, est: float, source, fut: Future) -> None:
        with self._lock:
            self._backlog[lane_i] = max(0.0, self._backlog[lane_i] - est)
            self._inflight -= 1
            if not fut.cancelled() and fut.exception() is None:
                r = fut.result()
                try:
                    self._measured[source] = \
                        max(float(r.prepare_s + r.run_s), 1e-6)
                except TypeError:
                    pass  # un-weakrefable source: keep the model estimate

    # ---------------------------------------------------------------- stats
    def stats(self) -> PoolStats:
        """Pool aggregates + this router's admission/affinity counters."""
        base = self.pool.stats()
        with self._lock:
            return dataclasses.replace(
                base,
                rejected=sum(self._rejected.values()),
                rejected_by_priority=dict(self._rejected),
                rerouted=self._rerouted,
                backlog_s=tuple(self._backlog),
            )

    def pending(self) -> int:
        """Admitted-but-unfinished jobs across the pool (queue occupancy)."""
        with self._lock:
            return self._inflight
