"""Comm backends: how a mode step's oracle answers cross the device mesh.

The paper's framing (shared with the dense companion paper,
arXiv:1707.05594) is that ONE compute schedule runs under different data
distributions — only the placement and the collectives change. This module
makes that the literal architecture: a backend wraps the per-device Z
products (``engine.oracle.z_products``) into the global oracle the shared
Lanczos body consumes, and owns nothing else.

Three backends, selected per mode from the plan's partition metrics
(``resolve_backend``):

* ``local`` — P = 1: no collectives at all. The single-process HOOI in
  ``repro.core.hooi`` is this backend applied to the identity partition,
  and ``dist_hooi(P=1)`` resolves here too — single-process/distributed
  parity is a property of the architecture, not a differential test.

* ``psum`` — the paper's framework mapped 1:1 onto SPMD (the historical
  ``baseline`` path): the oracle answer lives replicated in the full padded
  row space L_sent = P*Lp, aggregated with a ``psum`` over the full row
  vector (the all-reduce analogue of the MPI owner reduction). Comm per
  query: O(L) per device; the u-space is replicated (``axis=None``).

* ``boundary`` — the beyond-paper TPU-native path (the historical
  ``liteopt``): rows are relabelled so each device owns a contiguous block;
  the oracle answer is produced *sharded* and the only cross-device traffic
  is the tiny boundary vector of split-slice rows — size R_sum - L <= P for
  Lite (Theorem 6.1.2). Comm per query: O(S_pad) ~ O(P); the u-space is
  sharded (``axis="ranks"``), cutting reorthogonalization memory and FLOPs
  by P.

All backends assume they run inside ``shard_map`` over the ``"ranks"`` axis
(``local`` merely never issues a collective, so its 1-device mesh is
degenerate by construction).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["OracleSpace", "make_comm_space", "resolve_backend",
           "cheaper_backend", "backend_comm_bytes", "COMM_BACKENDS",
           "BACKEND_BYTES_KEY", "AXIS"]

AXIS = "ranks"  # the one mesh axis every distributed step runs over

COMM_BACKENDS = ("local", "psum", "boundary")

# historical path names -> backend families (P=1 always resolves to local)
PATH_BACKENDS = {"baseline": "psum", "liteopt": "boundary"}

# which comm_model entry a backend's collectives move — the single source
# of truth for plan costing (repro.core.plan) and calibration accounting
# (repro.distributed.executor)
BACKEND_BYTES_KEY = {"psum": "baseline_bytes", "boundary": "liteopt_bytes"}


def backend_comm_bytes(backend: str, comm: dict) -> float:
    """Collective bytes one mode moves under ``backend`` (local: none)."""
    if backend == "local":
        return 0.0
    return float(comm[BACKEND_BYTES_KEY[backend]])


def cheaper_backend(comm: dict, model) -> str:
    """The modeled-cheaper of psum/boundary for one mode's comm model.

    THE auto selection rule — plan costing, run-time backend resolution,
    and calibration accounting all call this one function, so calibrated
    per-backend bandwidths shift every consumer together.
    """
    return ("psum"
            if model.comm_seconds(comm["baseline_bytes"], "psum")
            < model.comm_seconds(comm["liteopt_bytes"], "boundary")
            else "boundary")


@dataclasses.dataclass
class OracleSpace:
    """What a comm backend hands the shared Lanczos body.

    All closures are panel-polymorphic: ``x`` may be ``(K_hat,)`` or a
    ``(K_hat, s)`` panel (block Lanczos), and u-space values broadcast the
    same way. ``wrap_matvec_out`` is the backend's placement step alone —
    ``matvec = wrap_matvec_out ∘ zmv`` — exposed so a fused Z-build stage
    that already holds the local product ``Z_local @ V_1`` can lift it into
    the global oracle space without a second pass over Z.
    """

    matvec: Callable  # x (K_hat,)|(K_hat, s) -> u-space vector/panel
    rmatvec: Callable  # u (dim_u,)|(dim_u, s) -> (K_hat, ...) replicated
    dim_u: int  # per-device u-space dimension
    axis: str | None  # mesh axis the u-space is sharded over (None: replicated)
    finalize: Callable  # left vectors (dim_u, k) -> per-device factor shard
    wrap_matvec_out: Callable = None  # local Z product -> u-space placement


def resolve_backend(path: str, P: int, comm: dict | None = None) -> str:
    """Backend for one mode step, from the plan's partition metrics.

    ``path`` is ``"baseline"``/``"liteopt"`` (forced family), ``"auto"``
    (pick the cheaper of psum/boundary from the mode's analytic comm model
    ``comm``), or already a backend name. P = 1 always resolves to
    ``local`` — no collectives exist worth modeling.
    """
    if P == 1:
        return "local"
    if path in COMM_BACKENDS:
        return path
    if path == "auto":
        if comm is None:
            return "boundary"
        from repro.core.calibrate import current_cost_model

        return cheaper_backend(comm, current_cost_model())
    try:
        return PATH_BACKENDS[path]
    except KeyError:
        raise ValueError(f"unknown path/backend {path!r}") from None


def _local_space(ms: dict, arrs: dict, zmv, zrmv) -> OracleSpace:
    Lp = ms["Lp"]
    row_gid = arrs["row_gid"]

    def wrap(local):
        # P = 1: every real row is owned; padding rows carry the
        # out-of-range gid sentinel and drop out of the scatter
        return jnp.zeros((Lp,) + local.shape[1:], local.dtype).at[
            row_gid].add(local, mode="drop")

    def rmatvec(u):
        return zrmv(u.at[row_gid].get(mode="fill", fill_value=0.0))

    return OracleSpace(lambda x: wrap(zmv(x)), rmatvec, Lp, None,
                       lambda left: left, wrap)


def _psum_space(ms: dict, arrs: dict, zmv, zrmv) -> OracleSpace:
    Lp = ms["Lp"]
    L_sent = ms["P"] * Lp
    row_gid = arrs["row_gid"]
    p = jax.lax.axis_index(AXIS)

    def wrap(local):  # (R_pad, ...) local Z product -> replicated row space
        out = jnp.zeros((L_sent,) + local.shape[1:], local.dtype).at[
            row_gid].add(local, mode="drop")
        return jax.lax.psum(out, AXIS)

    def rmatvec(u):
        y_loc = u.at[row_gid].get(mode="fill", fill_value=0.0)
        return jax.lax.psum(zrmv(y_loc), AXIS)

    def finalize(left):  # (L_sent, k) replicated -> (Lp, k) shard
        return jax.lax.dynamic_slice_in_dim(left, p * Lp, Lp, 0)

    return OracleSpace(lambda x: wrap(zmv(x)), rmatvec, L_sent, None,
                       finalize, wrap)


def _boundary_space(ms: dict, arrs: dict, zmv, zrmv) -> OracleSpace:
    Lp, S_pad = ms["Lp"], ms["S_pad"]
    row_gid, row_owned = arrs["row_gid"], arrs["row_owned"]
    bnd_slot = arrs["bnd_slot"]
    own_bnd_slot, own_bnd_off = arrs["own_bnd_slot"], arrs["own_bnd_off"]
    p = jax.lax.axis_index(AXIS)
    off = row_gid - p * Lp  # owned rows: in [0, Lp); foreign/pad: out of range

    def _bmask(ref):  # row_owned broadcast against vector or panel values
        return row_owned if ref.ndim == 1 else row_owned[:, None]

    def wrap(local):  # (R_pad, ...) local Z product -> owned row shard
        owned_contrib = jnp.where(_bmask(local), local, 0.0)
        shard = jnp.zeros((Lp,) + local.shape[1:], local.dtype).at[
            jnp.where(row_owned, off, Lp)
        ].add(owned_contrib, mode="drop")
        # boundary rows -> tiny global slot vector (size S_pad ~ O(P))
        bvec = jnp.zeros((S_pad,) + local.shape[1:], local.dtype).at[
            bnd_slot].add(local, mode="drop")
        # owned/pad rows have slot S_pad -> dropped
        bvec = jax.lax.psum(bvec, AXIS)
        add = bvec.at[own_bnd_slot].get(mode="fill", fill_value=0.0)
        shard = shard.at[own_bnd_off].add(add, mode="drop")
        return shard  # (Lp, ...) sharded over ranks

    def rmatvec(u_shard):
        # owners publish boundary-row values into the tiny slot vector
        vals = u_shard.at[own_bnd_off].get(mode="fill", fill_value=0.0)
        ybnd = jnp.zeros((S_pad,) + u_shard.shape[1:], u_shard.dtype).at[
            own_bnd_slot].set(vals, mode="drop")
        ybnd = jax.lax.psum(ybnd, AXIS)
        y_own = u_shard.at[off].get(mode="fill", fill_value=0.0)
        y_for = ybnd.at[bnd_slot].get(mode="fill", fill_value=0.0)
        y_loc = jnp.where(_bmask(y_own), y_own, y_for)
        return jax.lax.psum(zrmv(y_loc), AXIS)

    return OracleSpace(lambda x: wrap(zmv(x)), rmatvec, Lp, AXIS,
                       lambda left: left, wrap)


_SPACES = {
    "local": _local_space,
    "psum": _psum_space,
    "boundary": _boundary_space,
}


def make_comm_space(backend: str, ms: dict, arrs: dict, zmv, zrmv
                    ) -> OracleSpace:
    """Wrap per-device Z products into the global oracle for ``backend``."""
    if backend == "local" and ms["P"] != 1:
        raise ValueError("local comm backend requires P == 1")
    try:
        make = _SPACES[backend]
    except KeyError:
        raise ValueError(f"unknown comm backend {backend!r}") from None
    return make(ms, arrs, zmv, zrmv)
