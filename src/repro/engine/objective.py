"""Objectives: *what* the sweep loop optimizes, as a first-class stage.

The paper's HOOI is one objective — minimize the Frobenius residual of an
orthonormal-factor Tucker model — over the Z-build → oracle → comm pipeline.
Constrained and masked sparse Tucker variants (SGD_Tucker, arXiv 2012.03550)
share the exact same sparse-contraction core; what changes is the data the
sweeps see, what happens to a factor after the oracle solve, and how the
per-sweep scalar trajectory is scored. Those three seams are the
``Objective`` contract:

* ``prepare_tensor(t)`` — the host-side *view* of the input the sweeps run
  on. ``CompletionObjective`` drops held-out entries here (masked fit);
  others pass the tensor through. Views are stamped and returned unchanged
  on re-entry, so the executor, scheduler, and plan layers may each call it
  without double-masking — and so a view keeps its memoized fingerprint.
* ``refine_factor(F, S)`` — post-processing of one mode's oracle solve,
  applied to the full-row factor in *original* row order (after the comm
  backend's finalize and the executor's row-perm restore). Identity for
  Tucker/completion; ADMM splitting onto the nonnegative orthant for
  ``NNTuckerObjective``. Running after the restore means the exact same
  update executes on every comm backend by construction.
* ``fit(t, core, factors)`` + ``sweep_metrics(out, t, core, factors)`` —
  the per-sweep fit scalar and any extra trajectory stats (held-out RMSE
  for completion). ``TuckerObjective.fit`` is byte-for-byte the historical
  ``fit_score`` call, which is what makes the refactor behavior-preserving.

Two static tokens key the caches: ``cache_token()`` discriminates plan
cache entries and plan files (a plan partitions an objective's *view* and
scores its cost model), and ``name`` enters the executor's compiled-step
key — distinct objectives never alias each other's compiled steps or
uploads, while reruns under the same objective stay 0 new jit / 0 new
uploads.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Sequence

import jax.numpy as jnp
import numpy as np

from repro import envknobs

__all__ = ["Objective", "TuckerObjective", "CompletionObjective",
           "NNTuckerObjective", "TUCKER", "resolve_objective",
           "predict_at_coords", "admm_nonneg_factor", "holdout_mask"]


# --------------------------------------------------------------- helpers

def holdout_mask(nnz: int, fraction: float, seed: int) -> np.ndarray:
    """Deterministic per-index holdout selection, stable under appends.

    Entry ``i`` is held out iff a splitmix64-style hash of ``(i, seed)``
    falls below ``fraction`` — so appending entries to a streamed tensor
    never reshuffles the split of the already-covered prefix (the scheduler
    repartition path depends on the view being append-extended).

    The hash is ``core.stochastic.sample_unit`` at ``HOLDOUT_DOMAIN`` (0)
    — bitwise the historical stream — while the stochastic-refine sampler
    draws from disjoint nonzero domains, so held-out entries are never
    preferentially resampled into training minibatches when seeds collide.
    """
    from repro.core.stochastic import HOLDOUT_DOMAIN, sample_unit

    if fraction <= 0.0 or nnz == 0:
        return np.zeros(nnz, dtype=bool)
    if fraction >= 1.0:
        return np.ones(nnz, dtype=bool)
    unit = sample_unit(np.arange(nnz, dtype=np.uint64), seed, HOLDOUT_DOMAIN)
    return unit < float(fraction)


def predict_at_coords(core, factors: Sequence, coords: np.ndarray,
                      chunk: int = 65536) -> np.ndarray:
    """Model values ``M[i_1..i_N] = core ×_n F_n`` gathered at ``coords``.

    Host-side numpy, chunked over entries: per chunk, the mode-0 factor
    rows contract the core once, then each remaining mode contracts its
    gathered rows elementwise over the batch — O(nnz · Π K_n) total, no
    densification. Shared by completion's held-out RMSE and the NN
    residual fit.
    """
    coords = np.asarray(coords)
    core64 = np.asarray(core, dtype=np.float64)
    fs = [np.asarray(f, dtype=np.float64) for f in factors]
    out = np.empty(coords.shape[0], dtype=np.float64)
    for s in range(0, coords.shape[0], chunk):
        c = coords[s:s + chunk]
        acc = np.tensordot(fs[0][c[:, 0]], core64, axes=[[1], [0]])
        for n in range(1, len(fs)):
            acc = np.einsum("bk...,bk->b...", acc, fs[n][c[:, n]])
        out[s:s + c.shape[0]] = acc.reshape(-1)
    return out


def admm_nonneg_factor(F: jnp.ndarray, S: jnp.ndarray, iters: int = 8,
                       rho: float = 1.0, ridge: float = 0.0,
                       residual_balance: bool = False,
                       balance_mu: float = 10.0,
                       balance_tau: float = 2.0) -> jnp.ndarray:
    """Project one mode's oracle solve onto the nonnegative orthant by ADMM.

    The oracle returns an orthonormal left basis ``F`` and singular values
    ``S``; the energy-weighted unconstrained solution is ``M = F·diag(S)``.
    We solve ``min_X ½‖X−M‖² + ridge/2·‖X‖² + I₊(X)`` by scaled ADMM
    splitting ``X = W``:

        X ← (M + ρ(W − Y)) / (1 + ridge + ρ)      (x-update)
        W ← max(X + Y, 0)                          (projection)
        Y ← Y + X − W                              (dual ascent)

    Because the quadratic term is built from an *orthonormal* basis, the
    x-update's normal matrix is a scalar multiple of the identity and the
    whole iteration is elementwise closed form — no per-iteration solve
    (docs/objectives.md spells out this collapse). The iteration count is
    static and small, so this unrolls into a handful of fused elementwise
    ops. Returns the projected variable ``W`` (exactly nonnegative) with
    columns renormalized so downstream Z-builds stay well-scaled; dead
    columns keep scale via the eps clamp.

    ``residual_balance=True`` enables the Boyd §3.4.1 adaptive penalty:
    after each iteration the primal residual ``r_p = ‖X − W‖_F`` and dual
    residual ``r_d = ρ·‖W − W_prev‖_F`` are compared, and ρ is scaled by
    ``balance_tau`` whenever one exceeds ``balance_mu``× the other —
    growing ρ when the primal residual dominates (splitting too loose),
    shrinking it when the dual dominates (over-damped). The *scaled* dual
    ``Y = y/ρ`` is rescaled by ``ρ_old/ρ_new`` at each change so the
    underlying dual variable is preserved, and the x-update denominator is
    recomputed in-loop from the live ρ. ρ becomes a traced scalar under
    this schedule (data-dependent), which is why the fixed-ρ path is kept
    as a separate branch — it stays bitwise-identical to the historical
    iteration.
    """
    M = F * S[None, :]
    W = jnp.maximum(M, 0.0)
    Y = jnp.zeros_like(M)
    if not residual_balance:
        denom = 1.0 + float(ridge) + float(rho)
        for _ in range(max(int(iters), 1)):
            X = (M + rho * (W - Y)) / denom
            W = jnp.maximum(X + Y, 0.0)
            Y = Y + X - W
    else:
        mu = float(balance_mu)
        tau = float(balance_tau)
        rho_t = jnp.asarray(float(rho), M.dtype)
        for _ in range(max(int(iters), 1)):
            denom = 1.0 + float(ridge) + rho_t
            X = (M + rho_t * (W - Y)) / denom
            W_new = jnp.maximum(X + Y, 0.0)
            Y = Y + X - W_new
            r_p = jnp.linalg.norm(X - W_new)
            r_d = rho_t * jnp.linalg.norm(W_new - W)
            new_rho = jnp.where(
                r_p > mu * r_d, rho_t * tau,
                jnp.where(r_d > mu * r_p, rho_t / tau, rho_t))
            Y = Y * (rho_t / new_rho)
            rho_t = new_rho
            W = W_new
    norms = jnp.sqrt(jnp.sum(W * W, axis=0))
    return W / jnp.maximum(norms, 1e-6)[None, :]


# ------------------------------------------------------------ objectives

@dataclasses.dataclass(frozen=True)
class Objective:
    """Base contract; the defaults are the standard Tucker behaviors."""

    name: ClassVar[str] = "tucker"

    def cache_token(self) -> tuple:
        """Static discriminator for plan cache keys and plan files."""
        return (self.name,)

    def prepare_tensor(self, t):
        """The view of ``t`` the sweeps run on (idempotent)."""
        return t

    def refine_factor(self, F: jnp.ndarray, S: jnp.ndarray) -> jnp.ndarray:
        """Post-process one mode's oracle solve (full rows, original order)."""
        return F

    def finalize_core(self, core, factors):
        """The core the decomposition reports for these factors.

        The sweep loop hands in the projection core ``T ×_n F_nᵀ`` — the
        least-squares core only when the factors are orthonormal. The
        identity default keeps Tucker/completion bitwise-historical;
        ``NNTuckerObjective`` Gram-corrects.
        """
        return core

    def fit(self, t, core, factors) -> float:
        """Per-sweep fit scalar; the default is the historical fit_score."""
        from repro.core.hooi import Decomposition, fit_score

        return fit_score(t, Decomposition(core=core, factors=list(factors)))

    def sweep_metrics(self, out: dict, t, core, factors) -> None:
        """Append per-sweep extra stats (e.g. held-out RMSE) into ``out``."""

    def extra_svd_flops(self, metrics, core_dims, model) -> float:
        """Objective-specific critical-path flops added to the SVD phase of
        ``core/plan.py::_plan_cost`` — the per-objective FLOP term, with its
        rate knob living on ``CostModel`` (``admm_flops_per_entry``)."""
        return 0.0


@dataclasses.dataclass(frozen=True)
class TuckerObjective(Objective):
    """The paper's standard objective — extraction of the implicit default.

    Behavior-preserving: ``hooi``/``dist_hooi`` under this objective
    reproduce the historical fit trajectories bitwise on all three comm
    backends (every seam above is the identity / the historical call).
    """

    name: ClassVar[str] = "tucker"


TUCKER = TuckerObjective()


@dataclasses.dataclass(frozen=True)
class CompletionObjective(Objective):
    """Masked fit: residuals over *trusted* observed entries only.

    ``prepare_tensor`` drops the held-out fraction of entries from the COO
    view, so every downstream stage — partitioning, Z-build via
    kron_segsum, the oracle, the fit — sees only the training entries
    (in the implicit-zero Frobenius objective, removing an entry and
    masking it are the same statement). The held-out coordinates and their
    stored values ride along on the view; ``sweep_metrics`` scores the
    model's predictions at those coordinates as held-out RMSE per sweep.

    ``holdout_fraction=0`` is the all-ones mask: the view is the input
    tensor itself and the objective reduces exactly to ``TuckerObjective``.
    """

    name: ClassVar[str] = "completion"

    holdout_fraction: float = 0.2
    holdout_seed: int = 0

    def cache_token(self) -> tuple:
        return (self.name, float(self.holdout_fraction),
                int(self.holdout_seed))

    def prepare_tensor(self, t):
        from repro.core.coo import SparseTensor

        if getattr(t, "_objective_view", None) == self.cache_token():
            return t
        if self.holdout_fraction <= 0.0 or t.nnz == 0:
            return t
        # memoized per source object: repeated calls on the same snapshot
        # (the scheduler's reuse path) return the *same* view, keeping its
        # fingerprint memo and its identity in plan/upload caches
        memo = getattr(t, "_objective_view_memo", None)
        if memo is not None and memo[0] == self.cache_token():
            return memo[1]
        held = holdout_mask(t.nnz, self.holdout_fraction, self.holdout_seed)
        view = SparseTensor(coords=t.coords[~held], values=t.values[~held],
                            shape=t.shape)
        object.__setattr__(view, "_objective_view", self.cache_token())
        object.__setattr__(view, "_holdout_coords", t.coords[held])
        object.__setattr__(view, "_holdout_values", t.values[held])
        sv = getattr(t, "_stream_version", None)
        if sv is not None:  # plan provenance survives the masking
            object.__setattr__(view, "_stream_version", sv)
        object.__setattr__(t, "_objective_view_memo",
                           (self.cache_token(), view))
        return view

    def sweep_metrics(self, out: dict, t, core, factors) -> None:
        hc = getattr(t, "_holdout_coords", None)
        if hc is None or len(hc) == 0:
            return
        hv = np.asarray(getattr(t, "_holdout_values"), dtype=np.float64)
        pred = predict_at_coords(core, factors, hc)
        rmse = float(np.sqrt(np.mean((pred - hv) ** 2)))
        out.setdefault("holdout_rmse", []).append(rmse)


@dataclasses.dataclass(frozen=True)
class NNTuckerObjective(Objective):
    """Nonnegative / ridge-regularized Tucker via ADMM splitting.

    Each mode's oracle solve is wrapped by ``admm_nonneg_factor`` — the
    factors the sweep carries forward are exactly nonnegative with
    unit-normalized columns. The factors are no longer orthonormal, so the
    fit comes from the explicit residual expansion

        ‖T − M‖² = ‖T‖² − 2⟨T, M⟩ + ‖M‖²

    with ``⟨T, M⟩`` evaluated sparsely at the stored coordinates
    (``predict_at_coords``) and ``‖M‖²`` via the factor Gram matrices
    folded into the core — never densifying the model.
    """

    name: ClassVar[str] = "nn"

    admm_iters: int = 8
    rho: float = 1.0
    ridge: float = 0.0
    residual_balance: bool = False
    balance_mu: float = 10.0
    balance_tau: float = 2.0

    def cache_token(self) -> tuple:
        tok = (self.name, int(self.admm_iters), float(self.rho),
               float(self.ridge))
        if self.residual_balance:
            # appended only when on, so historical plan files / cache keys
            # for the fixed-rho default keep their exact token
            tok += ("rb", float(self.balance_mu), float(self.balance_tau))
        return tok

    def refine_factor(self, F: jnp.ndarray, S: jnp.ndarray) -> jnp.ndarray:
        return admm_nonneg_factor(F, S, iters=self.admm_iters, rho=self.rho,
                                  ridge=self.ridge,
                                  residual_balance=self.residual_balance,
                                  balance_mu=self.balance_mu,
                                  balance_tau=self.balance_tau)

    def finalize_core(self, core, factors):
        # nonneg factors are not orthonormal, so the projection core
        # T ×_n F_nᵀ overshoots; the least-squares core solves the
        # separable normal equations G ×_n (F_nᵀF_n) = G_proj — one K×K
        # solve per mode (columns are unit-normalized, so the tiny ridge
        # only guards exactly-dead columns)
        g64 = np.asarray(core, dtype=np.float64)
        for n, f in enumerate(factors):
            fn = np.asarray(f, dtype=np.float64)
            gram = fn.T @ fn + 1e-10 * np.eye(fn.shape[1])
            mat = np.moveaxis(g64, n, 0).reshape(g64.shape[n], -1)
            g64 = np.moveaxis(
                np.linalg.solve(gram, mat).reshape(
                    (g64.shape[n],) + tuple(np.delete(g64.shape, n))),
                0, n)
        return jnp.asarray(g64, dtype=jnp.asarray(core).dtype)

    def fit(self, t, core, factors) -> float:
        vals = np.asarray(t.values, dtype=np.float64)
        true_norm2 = getattr(t, "_true_norm2", None)
        t2 = float(true_norm2) if true_norm2 is not None else float(
            np.sum(vals ** 2))
        pred = predict_at_coords(core, factors, np.asarray(t.coords))
        tm = float(np.dot(vals, pred))
        core64 = np.asarray(core, dtype=np.float64)
        acc = core64
        for n, f in enumerate(factors):
            g = np.asarray(f, dtype=np.float64)
            acc = np.moveaxis(
                np.tensordot(g.T @ g, acc, axes=[[1], [n]]), 0, n)
        m2 = float(np.sum(acc * core64))
        err2 = max(t2 - 2.0 * tm + m2, 0.0)
        return 1.0 - float(np.sqrt(err2) / (np.sqrt(t2) + 1e-30))

    def extra_svd_flops(self, metrics, core_dims, model) -> float:
        # elementwise ops per (row, column) factor entry per ADMM iteration
        # (CostModel.admm_flops_per_entry), replicated on every rank -> a
        # critical-path term added to the SVD phase the refine runs after.
        total = 0.0
        for n, pm in enumerate(metrics.per_mode):
            total += float(pm.L) * float(core_dims[n])
        return float(self.admm_iters) \
            * float(getattr(model, "admm_flops_per_entry", 6.0)) * total


_BY_NAME = {
    "tucker": TuckerObjective,
    "completion": CompletionObjective,
    "nn": NNTuckerObjective,
}


def resolve_objective(objective=None) -> Objective:
    """The one resolution rule for every entry point.

    ``None`` honors ``REPRO_OBJECTIVE`` (default: the standard Tucker
    objective); a string names a default-parameter instance; an
    ``Objective`` instance passes through.
    """
    if objective is None:
        objective = envknobs.objective() or "tucker"
    if isinstance(objective, str):
        try:
            return _BY_NAME[objective]()
        except KeyError:
            raise ValueError(
                f"unknown objective {objective!r} "
                f"(expected one of {tuple(_BY_NAME)})") from None
    if isinstance(objective, Objective):
        return objective
    raise TypeError(f"objective must be None, a name, or an Objective, "
                    f"got {type(objective).__name__}")
