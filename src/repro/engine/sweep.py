"""The one HOOI sweep loop.

Both entry points — single-process ``repro.core.hooi.hooi`` and the
distributed ``HooiExecutor.run`` — drive this loop; they differ only in the
``mode_step`` callable they plug in (a local engine step vs. a cached
compiled ``shard_map`` step). That is the whole point of the engine: the
iteration structure, key derivation, fit accounting, and finalization exist
once, so single-process vs. distributed parity is structural.

Key derivation is the shared contract: the step for invocation ``it`` and
mode ``n`` receives ``sweep_key(key, it, N, n)``. Every backend therefore
draws identical Lanczos start/restart vectors for the same (seed, it, n),
which is what makes ``hooi(t, ...)`` and ``dist_hooi(t, ..., P=1)`` produce
the same fit trajectory.
"""

from __future__ import annotations

import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

__all__ = ["sweep_key", "run_hooi_sweeps"]


def sweep_key(key: jax.Array, it: int, nmodes: int, mode: int) -> jax.Array:
    """Per-(invocation, mode) PRNG key — one convention for every backend."""
    return jax.random.fold_in(key, 1000 + it * nmodes + mode)


def run_hooi_sweeps(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    t,
    factors: list,
    key: jax.Array,
    n_invocations: int,
    mode_step: Callable[[int, Sequence[jnp.ndarray], jax.Array], jnp.ndarray],
    on_sweep: Callable[[int, float, float], None] | None = None,
    objective=None,
    metrics_out: dict | None = None,
):
    """Run ``n_invocations`` HOOI sweeps, returning (Decomposition, fits).

    ``mode_step(n, factors, key) -> new factor`` must return the refined
    mode-n factor in *original* row order (distributed steps undo their row
    relabeling before returning). ``on_sweep(it, seconds, fit)`` observes
    each sweep's blocking wall time — the executor's calibration hook. The
    core is (re)finalized from the final factors, so ``n_invocations=0``
    still yields a valid decomposition of the bootstrap factors.

    ``objective`` (an ``engine.objective.Objective``) owns the per-sweep
    fit accounting; ``None`` runs the historical inline fit_score —
    ``TuckerObjective`` reproduces it bitwise, so both arms are the same
    trajectory. ``metrics_out`` collects the objective's extra per-sweep
    stats (e.g. completion's held-out RMSE).
    """
    from repro.core.hooi import Decomposition, fit_score
    from repro.core.ttm import core_from_factors

    N = t.ndim
    fits: list[float] = []
    core = None
    for it in range(n_invocations):
        t0 = time.perf_counter()
        for n in range(N):
            factors[n] = mode_step(n, factors, sweep_key(key, it, N, n))
        jax.block_until_ready(factors)
        sweep_s = time.perf_counter() - t0
        core = core_from_factors(coords, values, factors)
        if objective is None:
            fit = fit_score(t, Decomposition(core=core, factors=factors))
        else:
            core = objective.finalize_core(core, factors)
            fit = objective.fit(t, core, factors)
            if metrics_out is not None:
                objective.sweep_metrics(metrics_out, t, core, factors)
        fits.append(fit)
        if on_sweep is not None:
            on_sweep(it, sweep_s, fit)
    if core is None:  # n_invocations == 0: finalize the initial factors
        core = core_from_factors(coords, values, factors)
        if objective is not None:
            core = objective.finalize_core(core, factors)
    return Decomposition(core=core, factors=factors), fits
