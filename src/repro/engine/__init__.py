"""The layered HOOI engine: Z-build -> oracle -> comm backend.

One mode step = three explicit stages (paper §3's components made
structural, after the dense companion paper's one-schedule/many-
distributions framing):

* **Z-build** (``engine.zbuild``) — the penultimate matrix, Pallas
  ``kron_segsum`` kernel or jnp reference.
* **oracle** (``engine.oracle``) — the per-device Z products (plain or the
  fused Pallas ``oracle_pair`` kernel) feeding the repo's ONE Lanczos body
  (``repro.core.lanczos``).
* **comm backend** (``engine.comm``) — ``local`` (P=1, no collectives),
  ``psum`` (replicated row space, the paper baseline) or ``boundary``
  (sharded rows + O(P) boundary exchange), selected per mode from the
  plan's partition metrics.

``engine.steps`` composes the stages into mode steps; ``engine.sweep`` is
the single HOOI sweep loop both ``repro.core.hooi.hooi`` and
``repro.distributed.executor.HooiExecutor`` drive; ``engine.objective``
parameterizes *what* that loop optimizes (standard Tucker, masked
completion, nonnegative ADMM Tucker — see docs/objectives.md); ``engine.scheduler``
pipelines many tensors (or stream versions) through one executor,
overlapping host-side partitioning with device sweeps; ``engine.pool`` +
``engine.router`` serve many concurrent streams over several executors on
disjoint device slices, with priority admission and warm-start reroutes.
See docs/architecture.md and docs/scheduler.md.
"""

from .comm import (
    AXIS,
    COMM_BACKENDS,
    OracleSpace,
    make_comm_space,
    resolve_backend,
)
from .objective import (
    CompletionObjective,
    NNTuckerObjective,
    Objective,
    TuckerObjective,
    resolve_objective,
)
from .oracle import (
    choose_warm_start,
    count_z_passes,
    resolve_block_size,
    resolve_warm_start,
    solve_oracle,
    solve_oracle_block,
    z_products,
)
from .pool import ExecutorPool, PoolLane, PoolStats, device_slices
from .router import PoolSaturated, StreamRouter
from .scheduler import ScheduledResult, StreamScheduler
from .steps import (
    ARRAY_FIELDS,
    local_mode_step,
    make_mode_step_fn,
    make_stochastic_step_fn,
    make_zbuild_step_fn,
)
from .sweep import run_hooi_sweeps, sweep_key
from .zbuild import (
    build_local_z,
    build_local_z_oracle,
    kernel_forced_by_env,
    resolve_fused_zbuild,
    resolve_kernel,
    resolve_precision,
)

__all__ = [
    "AXIS",
    "COMM_BACKENDS",
    "OracleSpace",
    "make_comm_space",
    "resolve_backend",
    "Objective",
    "TuckerObjective",
    "CompletionObjective",
    "NNTuckerObjective",
    "resolve_objective",
    "solve_oracle",
    "solve_oracle_block",
    "count_z_passes",
    "resolve_block_size",
    "resolve_warm_start",
    "choose_warm_start",
    "z_products",
    "ExecutorPool",
    "PoolLane",
    "PoolStats",
    "device_slices",
    "PoolSaturated",
    "StreamRouter",
    "ScheduledResult",
    "StreamScheduler",
    "ARRAY_FIELDS",
    "local_mode_step",
    "make_mode_step_fn",
    "make_stochastic_step_fn",
    "make_zbuild_step_fn",
    "run_hooi_sweeps",
    "sweep_key",
    "build_local_z",
    "build_local_z_oracle",
    "kernel_forced_by_env",
    "resolve_kernel",
    "resolve_precision",
    "resolve_fused_zbuild",
]
