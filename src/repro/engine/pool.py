"""ExecutorPool: a serving tier of executors over disjoint device slices.

The paper's "MPI ranks" abstraction has so far only ever met one device
slice: ``StreamScheduler`` pipelines many tensors, but every sweep still
runs on the single attached ``HooiExecutor``. The serving regime the
ROADMAP targets (SGD_Tucker's many-concurrent-clients shape: lots of small
independent decomposition streams) needs the opposite — several executors
running *simultaneously*, each pinned to its own slice of the host's
devices, with streams routed across them.

This module is the resource layer of that tier:

* ``device_slices(n, P)`` cuts ``jax.devices()`` into ``n`` disjoint
  ``P``-device slices — executors never share a device, so their sweeps
  genuinely overlap instead of time-slicing one mesh.

* ``ExecutorPool`` owns ``n`` **lanes**. A lane is one ``HooiExecutor``
  (mesh pinned to its slice, its own compiled-step and upload caches) plus
  one ``StreamScheduler`` (its own producer pool and consumer thread) —
  the per-lane pipeline is exactly the single-executor pipeline, so every
  scheduler contract (submission order, refresh ladder, rerun = 0 new jit
  / 0 new uploads) holds per lane unchanged.

* ``PoolStats`` aggregates the per-stream accounting every run already
  lands in ``DistHooiStats`` (queue wait, prepare/sweep seconds, SLO
  hit/miss) across lanes, and carries the router-level admission counters
  when read through ``repro.engine.router.StreamRouter.stats()``.

Routing policy (priority classes, modeled cost, admission control,
backpressure, warm-start reroutes) lives above this layer in
``repro.engine.router`` — the pool itself is deliberately policy-free.
See docs/scheduler.md ("Pool & routing").
"""

from __future__ import annotations

import collections
import dataclasses
from typing import TYPE_CHECKING, Sequence

from repro.engine.scheduler import StreamScheduler
from repro.jax_compat import make_mesh_auto

if TYPE_CHECKING:  # runtime import is deferred: executor imports repro.engine
    from repro.distributed.executor import HooiExecutor

__all__ = ["ExecutorPool", "PoolLane", "PoolStats", "device_slices"]


def device_slices(n_executors: int, P_ranks: int, devices=None) -> list:
    """Cut the device list into ``n_executors`` disjoint ``P_ranks``-slices.

    Raises when the host cannot supply ``n_executors * P_ranks`` devices —
    a pool whose executors silently shared devices would report overlap
    that the hardware never delivers.
    """
    import jax

    n, P = int(n_executors), int(P_ranks)
    if n < 1 or P < 1:
        raise ValueError(f"need n_executors >= 1 and P_ranks >= 1, "
                         f"got {n_executors} x {P_ranks}")
    devs = list(jax.devices() if devices is None else devices)
    need = n * P
    if len(devs) < need:
        raise ValueError(
            f"pool of {n} executors x P={P} needs {need} devices, have "
            f"{len(devs)} — set XLA_FLAGS=--xla_force_host_platform_"
            "device_count or shrink the pool")
    return [devs[i * P:(i + 1) * P] for i in range(n)]


@dataclasses.dataclass
class PoolLane:
    """One executor + its scheduler pipeline, pinned to a device slice."""

    index: int
    executor: HooiExecutor
    scheduler: StreamScheduler
    devices: tuple


@dataclasses.dataclass
class PoolStats:
    """Aggregate serving-tier accounting (lanes + router admission).

    Read via ``ExecutorPool.stats()`` (router fields zero) or
    ``StreamRouter.stats()`` (router fields filled in). Per-lane raw dicts
    are kept so dashboards can drill down without re-walking the pool.
    """

    n_lanes: int
    # ---- lane aggregates (summed StreamScheduler totals) ----
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    host_s: float = 0.0
    device_s: float = 0.0
    queue_wait_s: float = 0.0
    slo_hit: int = 0
    slo_miss: int = 0
    decisions: dict = dataclasses.field(default_factory=dict)
    lane_stats: tuple = ()  # per-lane StreamScheduler.stats() dicts
    lane_executors: tuple = ()  # per-lane HooiExecutor.stats() snapshots
    # ---- router-level counters (admission/backpressure/affinity) ----
    rejected: int = 0  # submissions refused admission (PoolSaturated)
    rejected_by_priority: dict = dataclasses.field(default_factory=dict)
    rerouted: int = 0  # warm-start stream transfers between lanes
    backlog_s: tuple = ()  # modeled pending seconds per lane at read time

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ExecutorPool:
    """``n_executors`` scheduler-fronted executors on disjoint device slices.

    Construction kwargs after ``core_dims`` are forwarded to every lane's
    ``StreamScheduler`` (scheme, path, n_invocations, drift_tol,
    pad_geometric, ...), so a pool is configured exactly like a single
    scheduler. Use as a context manager (or call ``close``) to stop every
    lane's worker threads.

    The pool is policy-free: ``lane(i).scheduler.submit`` is the raw
    per-lane entry point. Almost all callers want
    ``repro.engine.router.StreamRouter`` on top — it owns lane choice,
    admission control and backpressure.
    """

    def __init__(
        self,
        n_executors: int,
        P_ranks: int,
        core_dims: Sequence[int],
        *,
        devices=None,
        workers: int = 2,
        **scheduler_kw,
    ):
        from repro.distributed.executor import HooiExecutor

        self.P = int(P_ranks)
        self.core_dims = tuple(int(k) for k in core_dims)
        slices = device_slices(n_executors, P_ranks, devices)
        self.lanes: list[PoolLane] = []
        for i, sl in enumerate(slices):
            mesh = make_mesh_auto((self.P,), ("ranks",), devices=sl)
            ex = HooiExecutor(self.P, mesh=mesh)
            sched = StreamScheduler(ex, self.core_dims, lane=i,
                                    workers=workers, **scheduler_kw)
            self.lanes.append(PoolLane(index=i, executor=ex,
                                       scheduler=sched, devices=tuple(sl)))

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "ExecutorPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Drain and stop every lane's worker threads (idempotent)."""
        for lane in self.lanes:
            lane.scheduler.close()

    # -------------------------------------------------------------- access
    @property
    def n_lanes(self) -> int:
        return len(self.lanes)

    def lane(self, i: int) -> PoolLane:
        return self.lanes[i]

    # ---------------------------------------------------------------- stats
    def stats(self) -> PoolStats:
        """Aggregated lane accounting (router counters zero at this layer)."""
        lane_stats = tuple(l.scheduler.stats() for l in self.lanes)
        lane_execs = tuple(l.executor.stats() for l in self.lanes)
        decisions: collections.Counter = collections.Counter()
        agg = {"submitted": 0, "completed": 0, "failed": 0,
               "host_s": 0.0, "device_s": 0.0, "queue_wait_s": 0.0,
               "slo_hit": 0, "slo_miss": 0}
        for ls in lane_stats:
            for k in agg:
                agg[k] += ls[k]
            decisions.update(ls["decisions"])
        return PoolStats(
            n_lanes=self.n_lanes,
            decisions=dict(decisions),
            lane_stats=lane_stats,
            lane_executors=lane_execs,
            **agg,
        )
