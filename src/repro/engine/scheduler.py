"""StreamScheduler: overlap host-side planning with device-side sweeps.

The paper's headline is that the lightweight distribution step costs less
than one HOOI iteration. On a single tensor that amortizes *within* a run;
when many tensors (or many versions of a streaming tensor) flow through one
executor, it can amortize to **zero visible cost**: while the device sweeps
tensor *k*, a producer thread partitions and stages tensor *k+1*. This
module is that two-stage pipeline:

::

    submit(t_1) submit(t_2) submit(t_3) ...
        |            |           |
    [producer pool: host work]         [consumer thread: device work]
      snapshot -> refresh decision        run_hooi_sweeps(t_1)
      -> PartitionPlan (auto / extend)    run_hooi_sweeps(t_2)    time
      -> stage_upload (host->device)      run_hooi_sweeps(t_3)      |
                                                                    v

Stage 1 (producer, ``HooiExecutor.prepare``): COO snapshot, plan
construction or refresh, upload staging — numpy + device puts, no
compilation, no sweep. Stage 2 (consumer, ``HooiExecutor.run``): the pure
device hot path, in submission order. One consumer thread keeps all jit
tracing and sweep execution single-threaded, so the executor's calibration
samples stay meaningful.

Streaming refresh ladder (per submitted batch of a ``StreamingTensor``):

* **reuse** — the stream version is unchanged since the adopted plan:
  same plan object, resident uploads, compiled steps -> the run reports 0
  new compilations and 0 new uploads (the executor rerun contract,
  extended to the scheduler path).
* **stochastic-refine** — sampling is enabled (``sample_fraction`` /
  ``REPRO_SAMPLE_FRACTION``), the drift is below the (tighter) stochastic
  tolerance, and the modeled sampled pass undercuts a full sweep: keep
  the adopted plan *untouched* and update the carried factors from a
  deterministic splitmix64-keyed minibatch of the appended elements plus
  a replay reservoir (``HooiExecutor.run_stochastic``) — O(batch) device
  work to match ``extend_scheme``'s O(batch) host work. A periodic full
  correction sweep (``correction_every``) bounds the rung's fit error;
  ``DistHooiStats.fit_delta`` observes it.
* **repartition** — new elements arrived but the projected §4 load
  imbalance stays within ``drift_tol`` of the imbalance the plan was
  selected at: keep the scheme, extend its policies to the appended
  elements in O(batch) (``repro.core.plan.extend_scheme``) and rebuild
  partitions. With geometric pad quantization (``pad_geometric=True``,
  the default here) the padded shapes usually survive, so no new
  compilations either. The refreshed plan's device arrays are re-uploaded
  in full (uploads are per-plan, not incremental) — what the pipeline
  saves is their *placement*: the producer stages them off the hot path.
* **reselect** — the appends skewed some mode beyond the tolerance: rerun
  the real-time ``auto`` selector from scratch.

The decision and the drift that drove it are surfaced on
``DistHooiStats.stream_decision`` / ``stream_drift``. See
docs/scheduler.md.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
import weakref
from concurrent.futures import (
    CancelledError,
    Future,
    InvalidStateError,
    ThreadPoolExecutor,
    wait as futures_wait,
)
from typing import Sequence

import numpy as np

from repro.core.coo import SparseTensor
from repro.core.metrics import MetricsExtender
from repro.core.plan import (
    PartitionPlan,
    extend_scheme,
    refresh_decision,
    rescore_plan,
    slice_owner_maps,
)
from repro.core.sketch import adapt_rank
from repro import envknobs
from repro.engine.objective import resolve_objective
from repro.engine.oracle import resolve_warm_start
from repro.streaming import StreamingTensor

__all__ = ["StreamScheduler", "ScheduledResult"]

DECISIONS = ("plan", "reuse", "stochastic-refine", "repartition", "reselect")

# resolved futures retained for drain(); beyond this, the oldest resolved
# ones are released so a drain-less serving loop cannot pin every result
# it ever produced
MAX_RETAINED_FUTURES = 4096


@dataclasses.dataclass
class ScheduledResult:
    """What one scheduled decomposition produced, with pipeline provenance."""

    name: str
    seq: int  # submission order
    decomposition: object  # repro.core.hooi.Decomposition
    stats: object  # DistHooiStats (stream_decision/_drift/prepare_s set)
    plan: PartitionPlan
    decision: str  # one of DECISIONS
    drift: dict | None  # refresh_decision output (appends only)
    prepare_s: float  # host stage: snapshot + decision + plan + staging
    run_s: float  # device stage: sweeps (consumer thread)
    stream_version: int | None  # version decomposed (streams only)
    # serving-tier accounting (defaults keep pre-pool callers working):
    # time spent waiting in queues — submit -> sweep start, minus the
    # prepare work itself (which overlaps earlier sweeps by design)
    queue_wait_s: float = 0.0
    # None when no deadline was given; else whether submit -> result
    # latency met it (mirrored on stats.slo_met)
    slo_met: bool | None = None

    @property
    def fits(self):
        return self.stats.fits


@dataclasses.dataclass
class _StreamState:
    """Scheduler-side memory of one StreamingTensor's adopted plan."""

    plan: PartitionPlan
    version: int  # stream version the plan's policies cover
    owner_maps: tuple  # per-mode slice -> rank (adoption-time majority)
    loads: list  # per-mode per-rank element counts at `version`
    # per-mode imbalance at *adoption* (selection) time — the fixed drift
    # baseline. Repartitions must not ratchet it: a stream skewing a
    # little per batch still has to compare against the imbalance the
    # scheme was actually selected at, or it would never reselect.
    baseline: tuple
    # cache token of the objective the plan was built under: a submit with
    # a different objective sees a different training view, so the state
    # is stale for it and the stream replans from scratch
    objective: tuple = ("tucker",)
    # incremental SchemeMetrics state (built lazily at first repartition,
    # on the covered prefix of the view) — keeps the repartition path's
    # metrics in O(batch) instead of an O(nnz) recompute
    extender: MetricsExtender | None = None
    # ---- sketch warm start / adaptive rank ----
    # the stream's *current* per-mode ranks (adaptive rank mutates these;
    # None = the scheduler default)
    core_dims: tuple | None = None
    # last run's factor matrices — the next run's init_factors, so the
    # factor-seeded sketch warm start carries across runs and across the
    # reselect rung (None until a run completes, or when warm_start
    # resolves to "none" — carrying factors would change trajectories)
    factors: object = None
    # [(stream_version, core_dims, modeled_total_s), ...] — the adaptive
    # rank trace, mirrored onto DistHooiStats.rank_trajectory
    rank_trajectory: list = dataclasses.field(default_factory=list)
    # ---- stochastic-refine rung ----
    # leading view elements already *incorporated into the factors* (by a
    # full sweep or a stochastic refine). Deliberately separate from the
    # plan-coverage bookkeeping above: a refine leaves plan/version/loads/
    # extender untouched (its partitions still describe exactly the
    # pre-append prefix, keeping the repartition path's covered-slicing and
    # load projection exact), and tracks incorporation here instead
    refined_nnz: int = 0
    # stream version whose appends are all incorporated — the eligibility
    # gate that makes "stochastic-refine never fires on an unchanged
    # stream version" structural
    refined_version: int = -1
    # consecutive refines since the last full sweep (drives the step-size
    # decay and the correction_every full-sweep cadence)
    stoch_count: int = 0
    # final fit of the last *full* run — the reference fit_delta is
    # measured against
    last_full_fit: float | None = None
    # a refine died mid-run (chaos, OOM, ...): its sampled elements were
    # marked incorporated at prepare time but never reached the factors.
    # The flag forces the next submit down a full (correction) path, which
    # re-anchors everything; any successful run clears it
    stoch_failed: bool = False


@dataclasses.dataclass
class _Job:
    seq: int
    name: str
    source: object  # SparseTensor | StreamingTensor
    seed: int
    n_invocations: int
    future: Future
    objective: object = None  # resolved engine.objective.Objective
    # the per-stream ranks this job plans and runs with (adaptive rank may
    # differ from the scheduler default); None = scheduler core_dims
    core_dims: tuple | None = None
    submit_t: float = 0.0  # perf_counter at submit (queue-wait/SLO clock)
    deadline_s: float | None = None  # submit -> result SLO budget
    # per-stream prepare ordering: wait for the previous submit of the same
    # stream, signal the next (None for plain tensors / first submit)
    wait_event: threading.Event | None = None
    done_event: threading.Event | None = None
    # filled by the producer stage
    tensor: SparseTensor | None = None
    plan: PartitionPlan | None = None
    decision: str = "plan"
    drift: dict | None = None
    prepare_s: float = 0.0
    stream_version: int | None = None
    # stochastic-refine routing: {"covered_nnz", "step_index"} when the
    # consumer should run the sampled pass instead of a full sweep
    stoch: dict | None = None


class StreamScheduler:
    """Asynchronous multi-tensor front end for one ``HooiExecutor``.

    ``submit`` returns a ``concurrent.futures.Future`` resolving to a
    ``ScheduledResult``; device runs happen in submission order. Use as a
    context manager (or call ``close``) to stop the worker threads.

    The executor is owned by the caller but must not be driven from other
    threads while a scheduler is attached — the scheduler's consumer
    thread is the single device driver.
    """

    def __init__(
        self,
        executor,
        core_dims: Sequence[int],
        *,
        scheme: str = "auto",
        path: str = "liteopt",
        n_invocations: int = 2,
        drift_tol: float = 0.25,
        workers: int = 2,
        pad_geometric: bool = True,
        plan_seed: int = 0,
        use_kernel: bool | None = None,
        use_fused_oracle: bool | None = None,
        lane: int | None = None,
        objective=None,
        warm_start: str | None = None,
        adaptive_rank: bool = False,
        rank_policy: dict | None = None,
        sample_fraction: float | None = None,
        sample_seed: int = 0,
        replay_nnz: int = 1024,
        correction_every: int = 4,
        stochastic_tol: float | None = None,
        step_size: float = 0.5,
        step_decay: float = 0.5,
    ):
        self.executor = executor
        # pool-lane label stamped on every run's stats (None standalone)
        self.lane = lane
        # default sweep objective for submissions that don't override it
        # (None honors REPRO_OBJECTIVE; resolved once, here)
        self.objective = resolve_objective(objective)
        self.core_dims = tuple(int(k) for k in core_dims)
        self.scheme = scheme
        self.path = path
        self.n_invocations = int(n_invocations)
        self.drift_tol = float(drift_tol)
        self.pad_geometric = bool(pad_geometric)
        self.plan_seed = int(plan_seed)
        self.use_kernel = use_kernel
        self.use_fused_oracle = use_fused_oracle
        # oracle warm start (None honors REPRO_WARM_START). Resolved once:
        # under "none" no factors are carried either, so the scheduler path
        # reproduces its historical trajectories bitwise.
        self.warm_start = warm_start
        self._warm_resolved = resolve_warm_start(warm_start)
        # adaptive per-mode rank: after each stream run, adapt_rank reads
        # the sketch/GK tail spectrum and may grow/shrink the stream's
        # core_dims; the plan is re-scored in place (rescore_plan — same
        # parts tuple, so the executor's upload cache stays hot)
        self.adaptive_rank = bool(adaptive_rank)
        self.rank_policy = dict(rank_policy or {})
        # without an explicit cap a mode could never grow (adapt_rank
        # clamps to k when k_max is None) — default to 2x the initial rank
        self.rank_policy.setdefault(
            "k_max", 2 * max(self.core_dims))
        # stochastic-refine rung: None honors REPRO_SAMPLE_FRACTION; 0 (or
        # an unset knob) disables the rung and the ladder is exactly the
        # historical three rungs
        if sample_fraction is None:
            sample_fraction = envknobs.sample_fraction()
        if sample_fraction is not None and not sample_fraction:
            sample_fraction = None  # explicit 0 = off
        if sample_fraction is not None \
                and not 0.0 < float(sample_fraction) <= 1.0:
            raise ValueError(
                f"sample_fraction must be in (0, 1], got {sample_fraction}")
        self.sample_fraction = None if sample_fraction is None \
            else float(sample_fraction)
        self.sample_seed = int(sample_seed)
        self.replay_nnz = int(replay_nnz)
        # every correction_every-th append runs a full (correction) sweep;
        # 0 = never correct (property tests only — unbounded fit drift)
        self.correction_every = int(correction_every)
        # drift ceiling for sampling; None = refresh_decision's drift_tol/2
        self.stochastic_tol = None if stochastic_tol is None \
            else float(stochastic_tol)
        self.step_size = float(step_size)
        self.step_decay = float(step_decay)

        self._pool = ThreadPoolExecutor(
            max_workers=max(int(workers), 1),
            thread_name_prefix="sched-prepare")
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        # adopted-plan state and prepare-order tails, keyed weakly on the
        # stream OBJECT: a dead stream's state is evicted with it (a
        # long-lived scheduler must not accumulate every stream it ever
        # served), and — unlike id() keys — a new stream allocated at a
        # recycled address can never inherit a dead stream's plan
        self._streams: "weakref.WeakKeyDictionary[StreamingTensor, _StreamState]" \
            = weakref.WeakKeyDictionary()
        self._stream_tail: "weakref.WeakKeyDictionary[StreamingTensor, threading.Event]" \
            = weakref.WeakKeyDictionary()
        self._futures: list[Future] = []  # submitted since the last drain()
        self._ready: dict[int, _Job] = {}  # prepared, awaiting the consumer
        self._next_seq = 0  # next submission number
        self._next_run = 0  # next seq the consumer will execute
        self._closed = False
        # busy-window accounting: wall time only accrues while work is in
        # flight, so idle gaps between bursts do not dilute the overlap
        # numbers of a long-lived scheduler
        self._busy_wall = 0.0
        self._burst_start: float | None = None
        self._totals = {
            "submitted": 0, "completed": 0, "failed": 0,
            "host_s": 0.0, "device_s": 0.0,
            # serving-tier aggregates (per-stream values on DistHooiStats)
            "queue_wait_s": 0.0, "slo_hit": 0, "slo_miss": 0,
        }
        self._decisions = collections.Counter()
        self._consumer = threading.Thread(
            target=self._consume, name="sched-run", daemon=True)
        self._consumer.start()

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "StreamScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Drain outstanding work, then stop the worker threads."""
        self._pool.shutdown(wait=True)
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._consumer.join()

    # --------------------------------------------------------------- submit
    def submit(
        self,
        source: SparseTensor | StreamingTensor,
        *,
        name: str | None = None,
        seed: int = 0,
        n_invocations: int | None = None,
        deadline_s: float | None = None,
        objective=None,
    ) -> Future:
        """Queue one decomposition of ``source``'s current state.

        For a ``StreamingTensor`` the state is snapshotted by the producer
        stage — an append racing a submit is picked up by the prepare that
        runs after it (bounded staleness; submits of one stream are
        prepared strictly in submission order).

        ``deadline_s`` is an SLO budget on submit -> result latency: the
        run still completes past it, but ``stats.slo_met`` (and the
        ``slo_hit``/``slo_miss`` totals) record whether it was honored.

        ``objective`` overrides the scheduler's default sweep objective for
        this submission (a name or an ``engine.objective.Objective``). A
        stream's adopted plan is per-objective: switching objectives on the
        same stream replans from scratch on first sight of the new one.
        """
        if name is None:
            name = getattr(source, "name", None) or "tensor"
        fut: Future = Future()
        with self._lock:
            # _closed check and pool hand-off both under the lock: the
            # wait_event chain relies on the pool receiving same-stream
            # jobs in submission order, and a close() racing this submit
            # must not leave an unresolvable future in _futures
            if self._closed:
                raise RuntimeError("scheduler is closed")
            job = _Job(
                seq=self._next_seq,
                name=str(name),
                source=source,
                seed=int(seed),
                n_invocations=self.n_invocations
                if n_invocations is None else int(n_invocations),
                future=fut,
                objective=self.objective if objective is None
                else resolve_objective(objective),
                submit_t=time.perf_counter(),
                deadline_s=None if deadline_s is None else float(deadline_s),
            )
            if isinstance(source, StreamingTensor):
                # chain per-stream prepares: FIFO pool order (enqueue under
                # this lock) guarantees the predecessor was dequeued first,
                # so waiting on it cannot deadlock the worker pool
                job.wait_event = self._stream_tail.get(source)
                job.done_event = threading.Event()
                self._stream_tail[source] = job.done_event
            try:
                self._pool.submit(self._prepare_safely, job)
            except RuntimeError as e:  # pool shut down under us
                if job.done_event is not None:
                    job.done_event.set()  # unblock any chained successor
                raise RuntimeError("scheduler is closed") from e
            self._next_seq += 1
            self._futures.append(fut)
            # bound retention: callers consuming results future-by-future
            # (never draining) must not accumulate one ScheduledResult per
            # submission forever; pending futures are never dropped
            while len(self._futures) > MAX_RETAINED_FUTURES \
                    and self._futures[0].done():
                self._futures.pop(0)
            self._totals["submitted"] += 1
            if self._burst_start is None:
                self._burst_start = time.perf_counter()
        return fut

    def drain(self, *, return_exceptions: bool = False) -> list:
        """Block until everything submitted since the last ``drain``
        finished; results in submission order.

        All jobs are waited on *before* any failure is raised, so one bad
        job never aborts the batch mid-flight. With the default
        ``return_exceptions=False`` the first failure re-raises and the
        batch's other results are discarded with the drained futures —
        when partial results matter, pass ``return_exceptions=True``
        (exceptions appear in-place, like ``asyncio.gather``) or keep the
        ``submit()``-returned futures yourself.

        Consuming: drained futures are released. Retention between drains
        is bounded (``MAX_RETAINED_FUTURES``) — drain at least that often,
        or hold the futures yourself."""
        with self._lock:
            futs = list(self._futures)
            self._futures.clear()
        futures_wait(futs)
        if return_exceptions:
            out = []
            for f in futs:
                if f.cancelled():
                    out.append(CancelledError())
                else:
                    e = f.exception()
                    out.append(e if e is not None else f.result())
            return out
        return [f.result() for f in futs]

    # ------------------------------------------------------- pool interface
    def pending(self) -> int:
        """Jobs submitted but not yet finished (router backlog signal)."""
        with self._lock:
            return (self._totals["submitted"] - self._totals["completed"]
                    - self._totals["failed"])

    def adopted_plan(self, src: StreamingTensor) -> PartitionPlan | None:
        """The plan this scheduler currently holds for ``src`` (or None)."""
        with self._lock:
            state = self._streams.get(src)
            return None if state is None else state.plan

    def adopt(self, src: StreamingTensor, pl: PartitionPlan,
              objective=None) -> bool:
        """Warm-start: adopt an externally built plan for ``src``.

        The router's reroute path hands a ``PartitionPlan.save()``/
        ``load()`` round-tripped plan from another lane here, so the first
        submit on this lane replays the stream's refresh ladder (``reuse``
        / ``repartition``) instead of rerunning the full selector. The
        plan must describe ``src``'s *current* snapshot — on a fingerprint
        mismatch (the stream grew since serialization) or an objective
        mismatch adoption is refused and the caller falls back to a cold
        plan. Uploads are staged immediately so the adopting lane's first
        run finds its device arrays resident.
        """
        obj = self.objective if objective is None \
            else resolve_objective(objective)
        if pl.objective != obj.name:
            return False
        t = obj.prepare_tensor(src.snapshot())
        if pl.fingerprint is None or pl.fingerprint != t.fingerprint():
            return False
        version = getattr(t, "_stream_version", src.version)
        self._adopt(src, pl, t, version, obj)
        self.executor.stage_upload(pl, t)
        return True

    # ------------------------------------------------------ result delivery
    @staticmethod
    def _deliver(fut: Future, *, result=None, exc=None) -> None:
        """Resolve a job's future, tolerating caller-side cancellation.

        ``Future.cancel()`` can win on a still-pending job; ``set_result``
        then raises ``InvalidStateError``, which must not kill the worker
        threads — the job's slot bookkeeping (``_ready``/counters) is what
        keeps the pipeline advancing, not the future itself.
        """
        try:
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.set_result(result)
        except InvalidStateError:
            pass  # cancelled by the caller; the work is simply dropped

    def _note_finished(self, failed: bool) -> None:
        """Completion bookkeeping (under ``_cv``): close the busy window
        when the last in-flight job finishes."""
        self._totals["failed" if failed else "completed"] += 1
        done = self._totals["completed"] + self._totals["failed"]
        if done >= self._totals["submitted"] and self._burst_start is not None:
            self._busy_wall += time.perf_counter() - self._burst_start
            self._burst_start = None

    # -------------------------------------------------------- producer side
    def _prepare_safely(self, job: _Job) -> None:
        try:
            if job.wait_event is not None:
                job.wait_event.wait()
            try:
                t0 = time.perf_counter()
                if isinstance(job.source, StreamingTensor):
                    self._prepare_stream(job, job.source)
                else:
                    # the objective's training view is what gets planned,
                    # uploaded AND swept — prepare_tensor is idempotent on
                    # its own output, so the executor sees the same object
                    job.tensor = job.objective.prepare_tensor(job.source)
                    job.decision = "plan"
                    job.core_dims = self.core_dims
                    job.plan, _ = self.executor.prepare(
                        job.tensor, self.core_dims, self.scheme,
                        path=self.path, plan_seed=self.plan_seed,
                        pad_geometric=self.pad_geometric,
                        objective=job.objective)
                job.prepare_s = time.perf_counter() - t0
            finally:
                if job.done_event is not None:
                    job.done_event.set()
        except BaseException as e:  # noqa: BLE001 — delivered via the future
            job.plan = None  # consumer skips it
            with self._cv:
                self._note_finished(failed=True)
                self._ready[job.seq] = job
                self._cv.notify_all()
            self._deliver(job.future, exc=e)
            return
        with self._cv:
            self._ready[job.seq] = job
            self._cv.notify_all()

    def _prepare_stream(self, job: _Job, src: StreamingTensor) -> None:
        """Stage 1 for a stream: snapshot, refresh ladder, plan, stage."""
        ex = self.executor
        obj = job.objective
        # the refresh ladder runs on the objective's training VIEW of the
        # snapshot: completion's per-element holdout hash is append-stable,
        # so view(k+1) = view(k) + the appended batch's training entries in
        # order — exactly the prefix property extend_scheme relies on
        t = obj.prepare_tensor(src.snapshot())
        version = getattr(t, "_stream_version", src.version)
        job.tensor = t
        job.stream_version = version
        with self._lock:
            state = self._streams.get(src)
            if state is not None and state.objective != obj.cache_token():
                state = None  # other-objective plan: stale view, replan
        # adaptive rank: the stream's current ranks, not the scheduler
        # default — the post-run policy mutates state.core_dims
        dims = self.core_dims if state is None or state.core_dims is None \
            else state.core_dims
        job.core_dims = dims

        if state is None:
            # first sight of this stream (under this objective): full
            # real-time selection
            pl, _ = ex.prepare(t, dims, self.scheme,
                               path=self.path, plan_seed=self.plan_seed,
                               pad_geometric=self.pad_geometric,
                               objective=obj)
            job.decision = "plan"
            self._adopt(src, pl, t, version, obj)
            job.plan = pl
            return

        if state.version == version:
            # nothing appended: the plan (and its resident uploads) stand
            job.decision = "reuse"
            job.plan = state.plan
            ex.stage_upload(state.plan, t)  # idempotent; 0 transfers
            return

        # appended batches: project them onto the adopted owner maps and
        # ask the invalidation predicate (§4 imbalance drift). The batch
        # is sliced out of the *snapshot view* (appends are concatenated in
        # order), not re-read from the stream — an append racing this
        # prepare lands in the next submit's snapshot, never in a policy
        # extension longer than the tensor it extends
        covered = len(state.plan.scheme.policy(0))
        new_coords = t.coords[covered:]
        loads = [
            state.loads[n] + np.bincount(
                np.asarray(state.owner_maps[n])[new_coords[:, n]],
                minlength=state.plan.P)
            for n in range(t.ndim)
        ]
        # fourth-rung eligibility: sampling on, carried factors to refine,
        # genuinely new data since the last refine (never fires on an
        # unchanged stream version), no failed refine awaiting correction,
        # and the correction cadence not yet due. Eligibility only *offers*
        # the rung; refresh_decision still demands low drift and a modeled
        # cost win before picking it.
        nnz = int(t.nnz)
        stoch = None
        if self.sample_fraction is not None:
            with self._lock:
                eligible = (state.factors is not None
                            and not state.stoch_failed
                            and nnz > state.refined_nnz
                            and (self.correction_every <= 0
                                 or state.stoch_count + 1
                                 < self.correction_every))
                refined = state.refined_nnz
            if eligible:
                stoch = {
                    "sampled_nnz": min(self.replay_nnz, refined)
                    + int(self.sample_fraction * (nnz - refined)),
                    "total_nnz": nnz,
                }
                if self.stochastic_tol is not None:
                    stoch["tol"] = self.stochastic_tol
        decision, drift = refresh_decision(state.plan, loads,
                                           tol=self.drift_tol,
                                           baseline=state.baseline,
                                           stochastic=stoch)
        job.drift = drift
        job.decision = decision
        if decision == "stochastic-refine":
            # the adopted plan stands untouched — version/loads/extender
            # still describe exactly the pre-append prefix, so a later
            # repartition's covered-slicing stays exact. Incorporation is
            # tracked at prepare time (the next submit's prepare may run
            # before this refine's sweep — same pipeline discipline as
            # state.version); a failed run flips stoch_failed in _consume
            # and the next submit takes the full correction path.
            job.plan = state.plan
            with self._lock:
                job.stoch = {"covered_nnz": state.refined_nnz,
                             "step_index": state.stoch_count}
                state.refined_nnz = nnz
                state.refined_version = version
                state.stoch_count += 1
            return
        if decision == "repartition":
            # keep the selected scheme; extend its policies to the appended
            # elements (O(batch)) and rebuild the padded partitions. The §4
            # metrics extend incrementally too (O(batch), same numbers as a
            # recompute); the extender state is built once, on the covered
            # prefix of the view, the first time this path runs
            if state.extender is not None and state.extender.nnz != covered:
                # extend() mutates before ex.prepare() can fail (e.g. a
                # killed prepare): the incremental state ran ahead of the
                # still-adopted plan — discard and rebuild on the prefix
                state.extender = None
            if state.extender is None:
                prefix = SparseTensor(coords=t.coords[:covered],
                                      values=t.values[:covered],
                                      shape=t.shape)
                state.extender = MetricsExtender(
                    prefix, state.plan.scheme, dims)
            scheme2 = extend_scheme(state.plan.scheme, state.owner_maps,
                                    new_coords)
            metrics = state.extender.extend(new_coords, scheme2)
            pl, _ = ex.prepare(t, dims, scheme2, path=self.path,
                               pad_geometric=self.pad_geometric,
                               objective=obj, metrics=metrics)
            with self._lock:
                state.plan = pl
                state.version = version
                state.loads = [np.asarray(mp.e_per_rank).copy()
                               for mp in pl.parts]
                # owner maps AND the drift baseline are kept: existing
                # slices' majority owners are what the extension just
                # reinforced, and drift stays measured against the
                # imbalance at *selection* (no ratcheting via repeated
                # repartitions)
                # a full sweep will (re)incorporate every view element —
                # reset the stochastic rung's cadence and coverage
                state.refined_nnz = nnz
                state.refined_version = version
                state.stoch_count = 0
        else:
            pl, _ = ex.prepare(t, dims, self.scheme,
                               path=self.path, plan_seed=self.plan_seed,
                               pad_geometric=self.pad_geometric,
                               objective=obj)
            self._adopt(src, pl, t, version, obj)
        job.plan = pl

    def _adopt(self, src: StreamingTensor, pl: PartitionPlan,
               t: SparseTensor, version: int, obj=None) -> None:
        """Make ``pl`` the stream's reference plan for drift tracking."""
        obj = self.objective if obj is None else obj
        state = _StreamState(
            plan=pl,
            version=version,
            owner_maps=slice_owner_maps(pl, t),
            loads=[np.asarray(mp.e_per_rank).copy() for mp in pl.parts],
            baseline=tuple(max(float(m.ttm_imbalance), 1.0)
                           for m in pl.metrics.per_mode),
            objective=obj.cache_token(),
            core_dims=tuple(pl.core_dims),
            refined_nnz=int(t.nnz),
            refined_version=version,
        )
        with self._lock:
            # carry the warm-start factors and rank trace across the
            # reselect rung: a fresh selection changes the *distribution*,
            # not the decomposition the stream has converged toward
            prev = self._streams.get(src)
            if prev is not None and prev.objective == state.objective:
                state.factors = prev.factors
                state.rank_trajectory = prev.rank_trajectory
                state.last_full_fit = prev.last_full_fit
            self._streams[src] = state

    def _after_stream_run(self, job: _Job, src: StreamingTensor,
                          dims: Sequence[int], dec, stats) -> None:
        """Post-run stream bookkeeping: factor carry + adaptive rank.

        Runs on the consumer thread right after the sweep. Stores the
        decomposition's factors as the stream's next ``init_factors`` (the
        sketch warm start seeds from them), and — with ``adaptive_rank`` —
        feeds the run's tail spectra to ``adapt_rank``: a changed rank
        re-scores the adopted plan in place via ``rescore_plan`` (same
        ``parts`` tuple → the executor's resident uploads survive; only
        genuinely new step signatures compile). The trace lands on
        ``stats.rank_trajectory``.
        """
        with self._lock:
            state = self._streams.get(src)
        if state is None or state.objective != job.objective.cache_token():
            return
        # the stochastic rung *requires* carried factors (it refines them),
        # so sampling keeps them even when the warm start is off
        if self._warm_resolved != "none" or self.sample_fraction is not None:
            state.factors = dec.factors
        with self._lock:
            state.stoch_failed = False  # any successful run re-anchors
            if job.decision == "stochastic-refine":
                if state.last_full_fit is not None and stats.fits:
                    stats.fit_delta = float(stats.fits[-1]) \
                        - float(state.last_full_fit)
            elif stats.fits:
                state.last_full_fit = float(stats.fits[-1])
        if job.decision == "stochastic-refine":
            # no adaptive rank off a minibatch spectrum — and rescore_plan
            # would rightly refuse the grown snapshot anyway
            return
        if not self.adaptive_rank or not stats.mode_spectra:
            return
        new_dims = tuple(
            adapt_rank(stats.mode_spectra[n], int(dims[n]),
                       **self.rank_policy)
            for n in range(len(dims)))
        pl2 = job.plan
        if new_dims != tuple(dims):
            pl2 = rescore_plan(job.plan, job.tensor, new_dims,
                               objective=job.objective)
        with self._lock:
            state.core_dims = new_dims
            if pl2 is not job.plan and state.plan is job.plan:
                # adopt the rescored plan for the refresh ladder; the
                # incremental metrics state was rank-parameterized, rebuild
                # it lazily at the next repartition
                state.plan = pl2
                state.extender = None
            state.rank_trajectory.append({
                "stream_version": job.stream_version,
                "core_dims": tuple(int(k) for k in new_dims),
                "modeled_total_s": float(pl2.cost.total_s),
            })
            stats.rank_trajectory = list(state.rank_trajectory)

    # -------------------------------------------------------- consumer side
    def _consume(self) -> None:
        while True:
            with self._cv:
                while self._next_run not in self._ready and not self._closed:
                    self._cv.wait()
                if self._next_run not in self._ready:
                    return  # closed and drained
                job = self._ready.pop(self._next_run)
                self._next_run += 1
            if job.plan is None:  # producer failed; future already set
                continue
            if job.future.cancelled():  # caller gave up before the sweep
                with self._cv:
                    self._note_finished(failed=True)
                continue
            try:
                dims = job.core_dims or self.core_dims
                src = job.source \
                    if isinstance(job.source, StreamingTensor) else None
                init = None
                if src is not None and (self._warm_resolved != "none"
                                        or self.sample_fraction is not None):
                    with self._lock:
                        state = self._streams.get(src)
                        facs = None if state is None else state.factors
                    # factors only carry onto the same mode sizes (streams
                    # append elements, not rows — but stay defensive)
                    if facs is not None and all(
                            int(f.shape[0]) == s
                            for f, s in zip(facs, job.tensor.shape)):
                        init = facs
                t0 = time.perf_counter()
                if job.stoch is not None:
                    # the rung's budget is ONE pass — O(batch) device work
                    # regardless of the scheduler's full-sweep invocation
                    # count (the periodic correction sweep is what restores
                    # full-accuracy fits)
                    dec, stats = self.executor.run_stochastic(
                        job.tensor, dims, job.plan,
                        init_factors=init,
                        covered_nnz=job.stoch["covered_nnz"],
                        sample_fraction=self.sample_fraction,
                        sample_seed=self.sample_seed,
                        replay_nnz=self.replay_nnz,
                        step_size=self.step_size,
                        step_decay=self.step_decay,
                        step_index=job.stoch["step_index"],
                        n_invocations=1,
                        seed=job.seed, use_kernel=self.use_kernel,
                        objective=job.objective)
                else:
                    dec, stats = self.executor.run(
                        job.tensor, dims, job.plan,
                        n_invocations=job.n_invocations, path=self.path,
                        seed=job.seed, use_kernel=self.use_kernel,
                        use_fused_oracle=self.use_fused_oracle,
                        objective=job.objective,
                        warm_start=self.warm_start, init_factors=init)
                t1 = time.perf_counter()
                run_s = t1 - t0
                if src is not None:
                    self._after_stream_run(job, src, dims, dec, stats)
                stats.stream_decision = job.decision
                stats.stream_drift = job.drift
                stats.prepare_s = job.prepare_s
                # serving-tier accounting: wait = everything between submit
                # and sweep start that was not the prepare work itself; the
                # SLO clock is the caller-visible submit -> result latency
                queue_wait = max(0.0, (t0 - job.submit_t) - job.prepare_s)
                slo_met = None if job.deadline_s is None \
                    else (t1 - job.submit_t) <= job.deadline_s
                stats.queue_wait_s = queue_wait
                stats.run_s = run_s
                stats.slo_deadline_s = job.deadline_s
                stats.slo_met = slo_met
                stats.lane = self.lane
                res = ScheduledResult(
                    name=job.name, seq=job.seq, decomposition=dec,
                    stats=stats, plan=job.plan, decision=job.decision,
                    drift=job.drift, prepare_s=job.prepare_s, run_s=run_s,
                    stream_version=job.stream_version,
                    queue_wait_s=queue_wait, slo_met=slo_met)
                with self._cv:
                    self._note_finished(failed=False)
                    self._totals["host_s"] += job.prepare_s
                    self._totals["device_s"] += run_s
                    self._totals["queue_wait_s"] += queue_wait
                    if slo_met is not None:
                        self._totals["slo_hit" if slo_met else
                                      "slo_miss"] += 1
                    self._decisions[job.decision] += 1
                self._deliver(job.future, result=res)
            except BaseException as e:  # noqa: BLE001
                if job.stoch is not None \
                        and isinstance(job.source, StreamingTensor):
                    # the refine marked its elements incorporated at
                    # prepare time but died before touching the factors:
                    # force the next submit down a full correction path
                    with self._lock:
                        state = self._streams.get(job.source)
                        if state is not None:
                            state.stoch_failed = True
                with self._cv:
                    self._note_finished(failed=True)
                self._deliver(job.future, exc=e)

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Pipeline totals: the overlap proof in numbers.

        ``wall_s`` is the accumulated *busy* wall time — each window runs
        from a submit into an idle pipeline until its last in-flight job
        finishes, so idle gaps between bursts do not dilute it. ``host_s``
        and ``device_s`` are the summed stage times. ``overlap_s = host_s
        + device_s - wall_s`` is the wall time the pipeline *hid* — what
        sequential plan-then-sweep execution would have paid on top.
        """
        with self._lock:
            out = dict(self._totals)
            out["decisions"] = dict(self._decisions)
            wall = self._busy_wall
            if self._burst_start is not None:  # burst still in flight
                wall += time.perf_counter() - self._burst_start
            out["wall_s"] = wall
            out["overlap_s"] = max(
                0.0, out["host_s"] + out["device_s"] - wall) if wall else 0.0
            return out
