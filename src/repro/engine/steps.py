"""Mode steps composed from the three engine stages.

A HOOI mode step is exactly: **Z-build** (``engine.zbuild``) -> **oracle**
(``engine.oracle``: per-device Z products + the one shared Lanczos body) ->
**comm backend** (``engine.comm``: how the products cross the mesh). This
module is the only place the stages meet:

* ``make_mode_step_fn`` — the function ``HooiExecutor`` wraps in
  ``shard_map``/``jit`` (one per static step signature). Its positional
  layout (8 sharded per-device arrays, then replicated factors + key) is
  part of the executor's upload-cache contract.
* ``make_zbuild_step_fn`` — the Z-build-only probe for per-phase
  calibration.
* ``local_mode_step`` — the same composition with the identity partition
  and the ``local`` backend semantics, no ``shard_map``: this is what
  ``repro.core.hooi`` runs, making the single-process reference the P=1
  instantiation of the engine rather than a second implementation.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.lanczos import (block_start_panel, gk_block_bidiag,
                                lanczos_bidiag, lanczos_niter,
                                svd_from_bidiag)
from repro.core.sketch import (DEFAULT_POWER_ITERS, power_refine,
                               seeded_start_panel, sketch_block_size,
                               sketch_niter)
from .comm import AXIS, make_comm_space
from .oracle import solve_oracle, solve_oracle_block, z_products
from .zbuild import build_local_z, build_local_z_oracle

__all__ = ["make_mode_step_fn", "make_zbuild_step_fn", "local_mode_step",
           "make_stochastic_step_fn", "ARRAY_FIELDS"]

# the per-device ModePartition arrays a distributed step consumes, in the
# positional order the step functions (and the executor's uploads) use
ARRAY_FIELDS = ("coords", "values", "local_rows", "row_gid", "row_owned",
                "bnd_slot", "own_bnd_slot", "own_bnd_off")


def make_zbuild_step_fn(ms: dict, use_kernel: bool, precision: str = "f32"):
    """TTM-only step: just the local Z build (per-phase calibration probe)."""

    def fn(coords, values, local_rows, factors):
        # shard_map keeps a leading size-1 'ranks' axis on sharded operands
        coords, values, local_rows = (
            x[0] for x in (coords, values, local_rows))
        Z = build_local_z(coords, values, local_rows, factors,
                          ms["mode"], ms["R_pad"], use_kernel=use_kernel,
                          precision=precision)
        return Z[None]

    return fn


def make_mode_step_fn(ms: dict, backend: str, K_n: int, niter: int):
    """One distributed mode step for ``shard_map`` over the 'ranks' axis.

    ``ms`` is the static partition signature (mode, R_pad, Lp, S_pad, P,
    use_kernel, use_fused, precision, block_size, fused_zbuild, warm_start);
    ``backend`` one of ``engine.comm``'s names. All of these are baked into
    the trace — the executor keys its compiled-step cache on them. ``niter``
    counts block iterations when ``block_size > 1``.

    ``warm_start="sketch"`` replaces the key-derived start panel with the
    factor-seeded range-finder sketch: each device recovers the original
    row id of every local Z row from its coords, contracts ``Z_pᵀ`` against
    the gathered rows of the incoming factor (partial sums psum to the
    exact global ``Zᵀ F``), orthonormalizes, and power-iterates through the
    comm space — so the block driver refines an already-good subspace under
    the reduced ``sketch_niter`` budget. The sketch panel depends on Z, so
    it cannot be served by the fused build's pre-Z first product —
    ``fused_zbuild`` is structurally off for sketch modes (the spec builder
    normalizes it; asserted here).
    """
    precision = ms.get("precision", "f32")
    block_size = int(ms.get("block_size", 1))
    fused_zbuild = bool(ms.get("fused_zbuild", False))
    warm_start = ms.get("warm_start", "none")
    assert not (fused_zbuild and warm_start == "sketch"), \
        "sketch warm start excludes the fused first product (spec builder)"

    def fn(coords, values, local_rows, row_gid, row_owned, bnd_slot,
           own_bnd_slot, own_bnd_off, factors, key):
        (coords, values, local_rows, row_gid, row_owned, bnd_slot,
         own_bnd_slot, own_bnd_off) = (
            x[0] for x in (coords, values, local_rows, row_gid, row_owned,
                           bnd_slot, own_bnd_slot, own_bnd_off))
        arrs = dict(row_gid=row_gid, row_owned=row_owned, bnd_slot=bnd_slot,
                    own_bnd_slot=own_bnd_slot, own_bnd_off=own_bnd_off)
        use_kernel = ms.get("use_kernel", False)
        first_panel = first_product = None
        if fused_zbuild:
            Khat = 1
            for j, f in enumerate(factors):
                if j != ms["mode"]:
                    Khat *= int(f.shape[1])
            first_panel = block_start_panel(key, Khat, block_size)
            Z, ZV1 = build_local_z_oracle(
                coords, values, local_rows, factors, ms["mode"], ms["R_pad"],
                first_panel, use_kernel=use_kernel, precision=precision)
        else:
            Z = build_local_z(coords, values, local_rows, factors,
                              ms["mode"], ms["R_pad"], use_kernel=use_kernel,
                              precision=precision)
        zmv, zrmv = z_products(Z, fused=ms.get("use_fused", False))
        space = make_comm_space(backend, ms, arrs, zmv, zrmv)
        if warm_start == "sketch":
            # original row id per local Z row, recovered from the element
            # coords (padding elements carry coord 0 and land on the last
            # real row's slot, where max() keeps the real id; element-free
            # rows stay 0 — their Z row is zero, so the gathered factor row
            # contributes nothing either way)
            F_n = factors[ms["mode"]]
            orig = jnp.zeros((ms["R_pad"],), jnp.int32).at[local_rows].max(
                coords[:, ms["mode"]])
            w = min(block_size, int(F_n.shape[1]))
            seed = Z.T @ F_n.at[orig].get(mode="fill", fill_value=0.0)[:, :w]
            if backend != "local":
                seed = jax.lax.psum(seed, AXIS)
            first_panel = seeded_start_panel(seed, key, Z.shape[1],
                                             block_size)
            first_panel = power_refine(space.matvec, space.rmatvec,
                                       first_panel, DEFAULT_POWER_ITERS)
        if warm_start == "sketch" or fused_zbuild or block_size > 1:
            if fused_zbuild:
                first_product = space.wrap_matvec_out(ZV1)
            left, S = solve_oracle_block(
                space.matvec, space.rmatvec, space.dim_u, Z.shape[1], K_n,
                niter, block_size, key, axis=space.axis,
                first_panel=first_panel, first_product=first_product)
        else:
            left, S = solve_oracle(space.matvec, space.rmatvec, space.dim_u,
                                   Z.shape[1], K_n, niter, key,
                                   axis=space.axis)
        return space.finalize(left), S

    return fn


def make_stochastic_step_fn(mode: int, num_rows: int, K_n: int, niter: int,
                            block_size: int, use_kernel: bool = False,
                            precision: str = "f32"):
    """One minibatch mode step for the stochastic-refine rung.

    Same Z-build → oracle composition as ``local_mode_step``'s sketch path
    — the sampled elements go through the identical ``build_local_z``
    kernel/reference seam, and the carried factor seeds the range-finder
    panel so the solve *refines* the adopted subspace instead of
    rediscovering it — but shaped for ``jax.jit`` with everything static
    closed over. No ``shard_map``: a minibatch is a few thousand elements,
    far below the scale where sharding over host devices pays for its
    collectives, so the rung's device work is a single-device O(batch)
    step by design (matching ``extend_scheme``'s O(batch) host work).

    ``fn(coords, values, factors, key) -> (left, S)``: ``coords`` are the
    sampled elements' *original* coordinates zero-padded to a power of two
    (padding rows carry coord 0 / value 0, contributing nothing to the
    scatter-add Z build), ``factors`` the full carried factors, and the
    returned ``left`` an orthonormal (num_rows, K_n) basis the caller
    blends into the carried factor (``core.stochastic.blend_factor``) and
    hands to ``Objective.refine_factor`` — outside the trace, matching the
    distributed step's refine-after-finalize discipline.
    """

    def fn(coords, values, factors, key):
        Z = build_local_z(coords, values, coords[:, mode], factors, mode,
                          num_rows, use_kernel=use_kernel, sorted_rows=False,
                          precision=precision)
        matvec, rmatvec = z_products(Z)
        Khat = int(Z.shape[1])
        seed = Z.T @ factors[mode][:, :min(int(block_size), K_n)]
        first_panel = seeded_start_panel(seed, key, Khat, block_size)
        first_panel = power_refine(matvec, rmatvec, first_panel,
                                   DEFAULT_POWER_ITERS)
        U, B = gk_block_bidiag(matvec, rmatvec, num_rows, Khat, niter,
                               block_size, key, axis=None,
                               first_panel=first_panel)
        return svd_from_bidiag(U, B, K_n, key, axis=None)

    return fn


def local_mode_step(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_rows: int,
    key: jax.Array,
    *,
    k: int | None = None,
    niter: int | None = None,
    use_kernel: bool = False,
    use_fused_oracle: bool = False,
    precision: str = "f32",
    block_size: int = 1,
    fused_zbuild: bool = False,
    warm_start: str = "none",
    timings: dict | None = None,
    objective=None,
) -> jnp.ndarray:
    """One single-process mode step (identity partition, local backend).

    Returns the refined factor (num_rows, k). ``timings`` (optional)
    accumulates blocking per-phase wall times under ``"ttm"``/``"svd"`` —
    the instrumentation ``hooi_invocation`` has always offered.

    ``block_size``/``fused_zbuild`` route through the same block driver and
    fused build stage the distributed steps use, with the identity
    partition — so ``hooi`` and ``dist_hooi(P=1)`` stay trajectory-identical
    on every variant. ``block_size`` here is the *effective* (pre-clamped)
    panel width; callers resolve requests via ``effective_block_size``.

    ``objective`` (an ``engine.objective.Objective``) post-processes the
    oracle solve via ``refine_factor(left, S)`` — identity for the standard
    objective, ADMM projection for NN. The distributed path applies the
    same refine after its row-perm restore, so P=1 parity covers every
    objective.

    ``warm_start="sketch"`` routes through the block driver with the
    factor-seeded range-finder panel (``core.sketch``) and — when ``niter``
    is not given — the reduced ``sketch_niter`` refinement budget. The
    current factor seeds the sketch, so the warm start carries across
    sweeps for free. Sketch excludes ``fused_zbuild`` (the panel depends on
    Z, which the fused first product must precede).
    """
    import time

    k = int(factors[mode].shape[1]) if k is None else int(k)
    Khat = 1
    for j, f in enumerate(factors):
        if j != mode:
            Khat *= int(f.shape[1])
    block_size = int(block_size)
    if warm_start == "sketch":
        fused_zbuild = False
        # the seeded panel must span the whole previous subspace (idempotent
        # for callers that already widened via sketch_block_size)
        block_size = sketch_block_size(k, num_rows, Khat, block_size)
    blockish = fused_zbuild or block_size > 1 or warm_start == "sketch"
    t0 = time.perf_counter()
    first_panel = first_product = None
    if fused_zbuild:
        first_panel = block_start_panel(key, Khat, block_size)
        Z, first_product = build_local_z_oracle(
            coords, values, coords[:, mode], factors, mode, num_rows,
            first_panel, use_kernel=use_kernel, sorted_rows=False,
            precision=precision)
    else:
        Z = build_local_z(coords, values, coords[:, mode], factors, mode,
                          num_rows, use_kernel=use_kernel, sorted_rows=False,
                          precision=precision)
    if timings is not None:
        Z.block_until_ready()
    t1 = time.perf_counter()
    matvec, rmatvec = z_products(Z, fused=use_fused_oracle)
    if niter is None:
        niter = (sketch_niter(k, num_rows, Khat, block_size)
                 if warm_start == "sketch"
                 else lanczos_niter(k, num_rows, Khat,
                                    block_size if blockish else 1))
    if warm_start == "sketch":
        seed = Z.T @ factors[mode][:, :min(block_size, k)]
        first_panel = seeded_start_panel(seed, key, Khat, block_size)
        first_panel = power_refine(matvec, rmatvec, first_panel,
                                   DEFAULT_POWER_ITERS)
    if blockish:
        U, B = gk_block_bidiag(matvec, rmatvec, num_rows, Khat, niter,
                               block_size, key, axis=None,
                               first_panel=first_panel,
                               first_product=first_product)
        left, S = svd_from_bidiag(U, B, k, key, axis=None)
    else:
        res = lanczos_bidiag(matvec, rmatvec, num_rows, Khat, k,
                             niter=niter, key=key)
        left, S = res.left_vectors, res.singular_values
    if objective is not None:
        left = objective.refine_factor(left, S)
    if timings is not None:
        left.block_until_ready()
        t2 = time.perf_counter()
        timings["ttm"] = timings.get("ttm", 0.0) + (t1 - t0)
        timings["svd"] = timings.get("svd", 0.0) + (t2 - t1)
    return left
