"""Oracle stage: how a mode step answers the Lanczos products for its Z.

The SVD component only ever consumes Z through the two oracle products
``Z @ x`` and ``Zᵀ @ y`` (paper §3). This module is the seam where the
*compute implementation* of those products is chosen, independently of the
comm backend that wraps them with collectives:

* ``fused=False`` — plain jnp matmuls (the reference; XLA fuses these fine
  on CPU/GPU).
* ``fused=True`` — the Pallas ``oracle_pair`` kernel
  (``repro.kernels.oracle_fused``): Z is streamed through VMEM in 128-row
  blocks and both products are produced in one pass. GK bidiagonalization's
  full reorthogonalization makes the two products of one iteration data-
  dependent (u = f(Z v) before Zᵀ u), so each product discards the kernel's
  companion output — HBM traffic (the memory-bound term) is still one pass
  of Z per product, identical to the unfused matvec, and the kernel path
  becomes reachable/testable from every HOOI entry point. A paired-query
  algorithm (block or s-step Lanczos) that consumes both outputs is the
  ROADMAP follow-up.

``solve_oracle`` is the shared postlude used by every backend: the one GK
body (``repro.core.lanczos.gk_bidiag``) plus the small-SVD/completion step,
space-aware via the optional mesh ``axis``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import envknobs
from repro.core.lanczos import (
    gk_bidiag,
    gk_block_bidiag,
    lanczos_niter,
    svd_from_bidiag,
)
from repro.core.sketch import (
    DEFAULT_POWER_ITERS,
    sketch_block_size,
    sketch_niter,
)
from repro.kernels import ops as kernel_ops

__all__ = ["z_products", "solve_oracle", "solve_oracle_block",
           "resolve_block_size", "resolve_warm_start", "choose_warm_start",
           "count_z_passes"]


def resolve_block_size(block_size: int | None) -> int:
    """Static Lanczos panel width for a mode step (1 = the vector driver).

    ``None`` honors ``REPRO_LANCZOS_BLOCK`` (CI's block leg; parsed and
    validated by ``repro.envknobs``), else 1. The value is a *request*:
    mode steps clamp it to the operator's rank cap via
    ``effective_block_size`` before it enters any trace or cache key.
    """
    if block_size is None:
        block_size = envknobs.lanczos_block() or 1
    block_size = int(block_size)
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    return block_size


def resolve_warm_start(warm_start: str | None) -> str:
    """Static oracle warm-start mode: ``"none"``, ``"sketch"`` or ``"auto"``.

    ``None`` honors ``REPRO_WARM_START`` (CI's sketch leg; parsed and
    validated by ``repro.envknobs``), else ``"none"`` — so existing callers
    reproduce their historical trajectories bitwise. ``"auto"`` is resolved
    per mode by ``choose_warm_start`` before it enters any trace or cache
    key.
    """
    if warm_start is None:
        warm_start = envknobs.warm_start() or "none"
    if warm_start not in envknobs.WARM_STARTS:
        raise ValueError(f"unknown warm_start {warm_start!r} "
                         f"(expected one of {envknobs.WARM_STARTS})")
    return warm_start


def choose_warm_start(
    warm_start: str,
    k: int,
    nrows: int,
    ncols: int,
    block_size: int = 1,
    fused_zbuild: bool = False,
    power_iters: int = DEFAULT_POWER_ITERS,
) -> str:
    """Per-mode static resolution of ``warm_start="auto"``.

    Picks the sketch exactly when it strictly reduces counted Z passes for
    this mode's geometry (seed + power passes included; the sketch path
    forgoes the fused first-product discount and runs the widened
    ``sketch_block_size`` panel). Deterministic in the static shape
    arguments, so the executor and the single-process path agree.
    """
    if warm_start != "auto":
        return warm_start
    full = count_z_passes(
        lanczos_niter(k, nrows, ncols, block_size), fused_zbuild)
    s_sk = sketch_block_size(k, nrows, ncols, block_size)
    sk = count_z_passes(
        sketch_niter(k, nrows, ncols, s_sk),
        False, warm_start="sketch", power_iters=power_iters)
    return "sketch" if sk < full else "none"


def count_z_passes(niter: int, fused_zbuild: bool = False, *,
                   warm_start: str = "none",
                   power_iters: int = 0) -> int:
    """Counted HBM passes over Z for one mode step.

    One write at build time plus two reads (matvec + rmatvec) per oracle
    iteration — ``niter`` is in *block* iterations under block Lanczos, so
    panels divide the read count by ``s`` structurally. The fused
    Z-build→oracle pipeline serves the first matvec from the VMEM-resident
    tile, saving one read. A sketched warm start adds one read for the
    factor-seeded sketch ``Zᵀ F`` plus two per power iteration — but runs
    ``sketch_niter`` (≈ half) refinement iterations, so the total drops.
    """
    passes = 1 + 2 * int(niter) - (1 if fused_zbuild else 0)
    if warm_start == "sketch":
        passes += 1 + 2 * int(power_iters)
    return passes


def z_products(
    Z: jnp.ndarray, *, fused: bool = False, interpret: bool | None = None
) -> tuple[Callable, Callable]:
    """(matvec, rmatvec) for an explicit per-device Z.

    matvec : x (K_hat,)|(K_hat, s) -> Z @ x;  rmatvec: y -> Zᵀ @ y. Both
    accept width-``s`` panels (block Lanczos) as well as vectors.
    ``fused`` is static — executors must key compiled steps on it.
    """
    if not fused:
        # vector rmatvec keeps the historical ``y @ Z`` contraction (bitwise
        # trajectory stability for the seed paths); panels need the explicit
        # transpose form
        return ((lambda x: Z @ x),
                (lambda y: y @ Z if y.ndim == 1 else Z.T @ y))

    def matvec(x):
        zero_r = jnp.zeros((Z.shape[0],) + x.shape[1:], Z.dtype)
        return kernel_ops.oracle_pair(Z, x, zero_r, interpret=interpret)[0]

    def rmatvec(y):
        zero_k = jnp.zeros((Z.shape[1],) + y.shape[1:], Z.dtype)
        return kernel_ops.oracle_pair(Z, zero_k, y, interpret=interpret)[1]

    return matvec, rmatvec


def solve_oracle(
    matvec: Callable,
    rmatvec: Callable,
    dim_u: int,
    ncols: int,
    k: int,
    niter: int,
    key: jax.Array,
    axis: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Leading-k left singular vectors of the (possibly distributed) oracle.

    One GK sweep + small-SVD projection; ``axis`` shards the u-space. This
    is the only SVD driver the engine's mode steps call — the local path's
    ``svd_via_lanczos`` is the same two calls through ``lanczos_bidiag``.
    """
    U, B = gk_bidiag(matvec, rmatvec, dim_u, ncols, niter, key, axis=axis)
    return svd_from_bidiag(U, B, k, key, axis=axis)


def solve_oracle_block(
    matvec: Callable,
    rmatvec: Callable,
    dim_u: int,
    ncols: int,
    k: int,
    niter: int,
    block_size: int,
    key: jax.Array,
    axis: str | None = None,
    first_panel: jnp.ndarray | None = None,
    first_product: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Block-Lanczos counterpart of ``solve_oracle``.

    ``niter`` counts block iterations; matvec/rmatvec must accept
    ``(., block_size)`` panels (every comm backend's ``OracleSpace`` does).
    ``first_panel``/``first_product`` come from the fused Z-build stage —
    the start panel and its already-computed global product — hoisting the
    first oracle pass into the build kernel.
    """
    U, B = gk_block_bidiag(matvec, rmatvec, dim_u, ncols, niter, block_size,
                           key, axis=axis, first_panel=first_panel,
                           first_product=first_product)
    return svd_from_bidiag(U, B, k, key, axis=axis)
