"""Oracle stage: how a mode step answers the Lanczos products for its Z.

The SVD component only ever consumes Z through the two oracle products
``Z @ x`` and ``Zᵀ @ y`` (paper §3). This module is the seam where the
*compute implementation* of those products is chosen, independently of the
comm backend that wraps them with collectives:

* ``fused=False`` — plain jnp matmuls (the reference; XLA fuses these fine
  on CPU/GPU).
* ``fused=True`` — the Pallas ``oracle_pair`` kernel
  (``repro.kernels.oracle_fused``): Z is streamed through VMEM in 128-row
  blocks and both products are produced in one pass. GK bidiagonalization's
  full reorthogonalization makes the two products of one iteration data-
  dependent (u = f(Z v) before Zᵀ u), so each product discards the kernel's
  companion output — HBM traffic (the memory-bound term) is still one pass
  of Z per product, identical to the unfused matvec, and the kernel path
  becomes reachable/testable from every HOOI entry point. A paired-query
  algorithm (block or s-step Lanczos) that consumes both outputs is the
  ROADMAP follow-up.

``solve_oracle`` is the shared postlude used by every backend: the one GK
body (``repro.core.lanczos.gk_bidiag``) plus the small-SVD/completion step,
space-aware via the optional mesh ``axis``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.lanczos import gk_bidiag, svd_from_bidiag
from repro.kernels import ops as kernel_ops

__all__ = ["z_products", "solve_oracle"]


def z_products(
    Z: jnp.ndarray, *, fused: bool = False, interpret: bool | None = None
) -> tuple[Callable, Callable]:
    """(matvec, rmatvec) for an explicit per-device Z.

    matvec : x (K_hat,) -> Z @ x (R,);  rmatvec: y (R,) -> Zᵀ @ y (K_hat,).
    ``fused`` is static — executors must key compiled steps on it.
    """
    if not fused:
        return (lambda x: Z @ x), (lambda y: y @ Z)

    zero_r = jnp.zeros((Z.shape[0],), Z.dtype)
    zero_k = jnp.zeros((Z.shape[1],), Z.dtype)

    def matvec(x):
        return kernel_ops.oracle_pair(Z, x, zero_r, interpret=interpret)[0]

    def rmatvec(y):
        return kernel_ops.oracle_pair(Z, zero_k, y, interpret=interpret)[1]

    return matvec, rmatvec


def solve_oracle(
    matvec: Callable,
    rmatvec: Callable,
    dim_u: int,
    ncols: int,
    k: int,
    niter: int,
    key: jax.Array,
    axis: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Leading-k left singular vectors of the (possibly distributed) oracle.

    One GK sweep + small-SVD projection; ``axis`` shards the u-space. This
    is the only SVD driver the engine's mode steps call — the local path's
    ``svd_via_lanczos`` is the same two calls through ``lanczos_bidiag``.
    """
    U, B = gk_bidiag(matvec, rmatvec, dim_u, ncols, niter, key, axis=axis)
    return svd_from_bidiag(U, B, k, key, axis=axis)
