"""Grouped-query attention: training (full causal / sliding window) + decode.

Layout conventions:
  activations: (B, S, d_model)
  q: (B, S, H, Dh); k/v: (B, S, KV, Dh); GQA groups G = H // KV.
KV heads are kept un-replicated — scores are computed with the grouped
einsum (B,S,KV,G,Dh) x (B,T,KV,Dh) so no (B,S,H,Dh) copy of K/V ever
materializes (matters at 32k prefill).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense, init_dense, rope_freqs

__all__ = ["init_attention", "attention", "attention_decode", "KVCache",
           "init_kv_cache"]

_NEG_INF = -1e30


def init_attention(key, d: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_dense(ks[1], d, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_dense(ks[2], d, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_dense(ks[3], n_heads * head_dim, d, dtype=dtype),
    }


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S_max, KV, Dh)
    v: jnp.ndarray  # (B, S_max, KV, Dh)


def init_kv_cache(batch: int, s_max: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, s_max, n_kv, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _qkv(params, x, n_heads, n_kv, head_dim, positions, inv_freq):
    B, S, _ = x.shape
    q = dense(params["wq"], x).reshape(B, S, n_heads, head_dim)
    k = dense(params["wk"], x).reshape(B, S, n_kv, head_dim)
    v = dense(params["wv"], x).reshape(B, S, n_kv, head_dim)
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    return q, k, v


BLOCKED_THRESHOLD = 2048  # use online-softmax blocked attention above this
Q_BLOCK = 512
KV_BLOCK = 1024


def attention(params: dict, x: jnp.ndarray, *, n_heads: int, n_kv: int,
              head_dim: int, inv_freq: jnp.ndarray | None,
              positions: jnp.ndarray | None = None,
              window: int | None = None, hint=None) -> jnp.ndarray:
    """Training-time causal attention (optionally sliding-window).

    For S > BLOCKED_THRESHOLD the blocked flash-style path runs: online
    softmax over KV chunks inside a scan over Q chunks, so the (S x S)
    score matrix never materializes — at 32k x 32 heads the dense scores
    are O(100 GB)/device; blocked peaks at O(Q_BLOCK x KV_BLOCK).

    ``hint``: under sequence parallelism, q/k/v are re-gathered to full
    sequence ONCE here (role 'attn_full') so the blocked scan does not
    trigger per-block all-gathers (the Megatron-SP schedule).
    """
    B, S, _ = x.shape
    hint = hint or (lambda t, role: t)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, x, n_heads, n_kv, head_dim, positions, inv_freq)
    G = n_heads // n_kv
    q = q.reshape(B, S, n_kv, G, head_dim)
    q = hint(q, "attn_full")
    k = hint(k, "attn_full")
    v = hint(v, "attn_full")
    if S > BLOCKED_THRESHOLD and S % Q_BLOCK == 0 and S % KV_BLOCK == 0:
        out = _blocked_attention(q, k, v, window=window)
    else:
        out = _dense_attention(q, k, v, window=window)
    out = out.reshape(B, S, n_heads * head_dim)
    return dense(params["wo"], out)


def _dense_attention(q, k, v, window=None):
    B, S, KV, G, Dh = q.shape
    scale = Dh ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k) * scale
    ii = jnp.arange(S)[:, None]
    jj = jnp.arange(S)[None, :]
    mask = jj <= ii
    if window is not None:
        mask &= jj > ii - window
    scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    attn = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", attn, v)


def _blocked_attention(q, k, v, window=None):
    """Flash-style: scan over Q blocks; online softmax over KV blocks.

    q: (B,S,KV,G,Dh); k/v: (B,S,KV,Dh). Returns (B,S,KV,G,Dh).
    """
    B, S, KV, G, Dh = q.shape
    scale = Dh ** -0.5
    nq = S // Q_BLOCK
    nk = S // KV_BLOCK
    qb = jnp.moveaxis(q.reshape(B, nq, Q_BLOCK, KV, G, Dh), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, KV_BLOCK, KV, Dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, KV_BLOCK, KV, Dh), 1, 0)

    def q_step(_, qi_idx):
        qi, iq = qi_idx  # (B,Qb,KV,G,Dh), scalar q-block index
        q_pos = iq * Q_BLOCK + jnp.arange(Q_BLOCK)

        def kv_step(carry, kv_idx):
            m, l, o = carry  # (B,Qb,KV,G), (B,Qb,KV,G), (B,Qb,KV,G,Dh)
            kj, vj, jk = kv_idx
            k_pos = jk * KV_BLOCK + jnp.arange(KV_BLOCK)
            s = jnp.einsum("bqkgd,btkd->bqkgt", qi, kj) * scale
            mask = k_pos[None, :] <= q_pos[:, None]  # (Qb, KVb)
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, :, None, None, :], s.astype(jnp.float32),
                          _NEG_INF)
            m_blk = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, m_blk)
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            # o accumulates in f32 (stable + keeps the scan carry dtype fixed)
            o_new = (o * alpha[..., None]
                     + jnp.einsum("bqkgt,btkd->bqkgd", p.astype(qi.dtype),
                                  vj).astype(jnp.float32))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Q_BLOCK, KV, G), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Q_BLOCK, KV, G), jnp.float32)
        o0 = jnp.zeros((B, Q_BLOCK, KV, G, Dh), jnp.float32)
        # checkpointed body: backward recomputes each block's probabilities
        # instead of saving them (flash-attention backward memory law —
        # without this the scan residuals re-materialize the S^2 scores).
        (m, l, o), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, o0), (kb, vb, jnp.arange(nk)))
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(qi.dtype)
        return None, out

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None,
                           (qb, jnp.arange(nq)))
    # outs: (nq, B, Qb, KV, G, Dh) -> (B, S, KV, G, Dh)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, KV, G, Dh)


def attention_decode(params: dict, x: jnp.ndarray, cache: KVCache, pos,
                     *, n_heads: int, n_kv: int, head_dim: int,
                     inv_freq: jnp.ndarray | None,
                     window: int | None = None
                     ) -> tuple[jnp.ndarray, KVCache]:
    """One-token decode: x (B, 1, d), cache holds S_max positions; ``pos`` is
    the (scalar) index of the new token."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(params, x, n_heads, n_kv, head_dim, positions,
                           inv_freq)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), pos, axis=1)
    S_max = k.shape[1]
    G = n_heads // n_kv
    q = q.reshape(B, 1, n_kv, G, head_dim)
    scale = head_dim ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k.astype(x.dtype)) * scale
    jj = jnp.arange(S_max)[None, :]
    mask = jj <= pos
    if window is not None:
        mask &= jj > pos - window
    scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    attn = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", attn, v.astype(x.dtype))
    out = out.reshape(B, 1, n_heads * head_dim)
    return dense(params["wo"], out), KVCache(k, v)
