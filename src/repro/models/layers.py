"""Shared neural building blocks: norms, RoPE variants, MLPs, embeddings.

Pure-function style: every layer is ``apply(params, x, ...)`` with params a
dict of jnp arrays, so layers compose under jax.lax.scan (stacked leading
layer axis) and pjit (param shardings attached by launch/sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm", "layer_norm", "init_rms_norm",
    "rope_freqs", "apply_rope",
    "init_dense", "dense",
    "init_mlp", "mlp_swiglu", "mlp_gelu",
    "init_embedding", "embed", "unembed",
]

Dtype = jnp.dtype


# --------------------------------------------------------------------- norms
def init_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


def layer_norm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"] + params.get("bias", 0.0)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0,
               fraction: float = 1.0) -> jnp.ndarray:
    """Inverse frequencies for the rotated sub-dimension.

    fraction < 1 rotates only the first ``fraction * head_dim`` dims
    (stablelm partial rotary, chatglm 2d-RoPE uses fraction=0.5).
    """
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               inv_freq: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: (..., S). Rotates the leading
    2*len(inv_freq) dims of Dh, pass-through for the rest."""
    rot = 2 * inv_freq.shape[0]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    ang = positions[..., :, None, None].astype(jnp.float32) * inv_freq  # (...,S,1,rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, x_pass], axis=-1)


# --------------------------------------------------------------------- dense
def init_dense(key, d_in: int, d_out: int, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> dict:
    std = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ----------------------------------------------------------------------- MLP
def init_mlp(key, d: int, d_ff: int, gated: bool = True,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], d, d_ff, dtype=dtype),
         "down": init_dense(ks[1], d_ff, d, dtype=dtype)}
    if gated:
        p["gate"] = init_dense(ks[2], d, d_ff, dtype=dtype)
    return p


def mlp_swiglu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return dense(params["down"],
                 jax.nn.silu(dense(params["gate"], x)) * dense(params["up"], x))


def mlp_gelu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return dense(params["down"], jax.nn.gelu(dense(params["up"], x)))


# ---------------------------------------------------------------- embeddings
def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(params: dict, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], ids, axis=0)


def unembed(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Logits via (tied or separate) unembedding: (..., d) -> (..., vocab)."""
    return x @ params["table"].T
