"""Mixture-of-Experts layer with capacity-based sort-free dispatch.

Dispatch formulation (chosen for TPU + pjit):
  * top-k routing, then tokens are *gathered* into a dense (E, C, d) expert
    buffer via an argsort-based position-within-expert computation — no
    (tokens x E x C) one-hot einsum (quadratic in tokens) and no ragged
    matmul. FLOPs = the useful expert FLOPs x capacity slack only.
  * capacity C = ceil(tokens * topk / E * capacity_factor): the same
    hard-limit principle as the paper's Lite scheme (E^max <= ceil(|E|/P));
    overflow tokens fall back to the residual stream (dropped), exactly the
    "bin limit" discipline of paper Fig 8 applied to expert bins.

Sharding (launch/sharding.py): expert dim E -> "model" axis (expert
parallelism); the token->expert gather becomes an all-to-all under SPMD.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import init_dense

__all__ = ["MoEConfig", "init_moe", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


def init_moe(key, d: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    E, F = cfg.num_experts, cfg.d_ff_expert
    s_in = 1.0 / jnp.sqrt(d)
    s_out = 1.0 / jnp.sqrt(F)
    return {
        "router": init_dense(ks[0], d, E, dtype=jnp.float32),  # fp32 router
        "w_gate": (jax.random.normal(ks[1], (E, d, F)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, F)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, d)) * s_out).astype(dtype),
    }


def moe_apply(params: dict, x: jnp.ndarray, cfg: MoEConfig, hint=None,
              groups: int = 1) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss). Top-k routing, capacity dispatch.

    GShard-style *grouped* dispatch: tokens are split into ``groups``
    dispatch groups (= the data-parallel shards, threaded in by the
    launcher), each with its own capacity C = ceil(T_g*k/E * cf). The expert
    buffer is (G, E, C, d) with G on the FSDP axes and E on the TP axis
    (hint role 'moe_buf'), so the token->expert movement lowers to an
    all-to-all of just the routed tokens instead of a replicated global
    buffer (grok-1: 32 GB/device without this).

    The capacity discipline is the paper's Lite hard-limit principle applied
    to expert bins (DESIGN.md §3): bins are filled to ceil(load/bins) and
    overflow falls back to the residual stream.
    """
    hint = hint or (lambda t, role: t)
    B, S, d = x.shape
    T = B * S
    k = cfg.top_k
    E = cfg.num_experts
    G = groups if (groups > 0 and T % groups == 0) else 1
    Tg = T // G
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ params["router"]["w"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)  # (E,)
    frac = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        jnp.ones((T * k,), jnp.float32)) / (T * k)
    aux = E * jnp.sum(frac * me)

    # ---- per-group position-within-expert via argsort.
    # The whole dispatch/combine is SCATTER-FREE: batched gathers along the
    # group-local token axis only. XLA SPMD partitions batched gathers on
    # the (sharded) group dim; data-dependent *scatters* fall back to
    # replicate + all-reduce (137 GB/layer at qwen3 scale — measured).
    flat_e = top_e.reshape(G, Tg * k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), k)[None], (G, Tg * k))
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    edges = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E + 1), side="left")
    )(sorted_e)  # (G, E+1): expert segment boundaries in sorted order
    starts, ends = edges[:, :-1], edges[:, 1:]
    pos_sorted = (jnp.arange(Tg * k)[None]
                  - jnp.take_along_axis(starts, sorted_e, axis=1))

    C = int(-(-Tg * k // E) * cfg.capacity_factor)
    C = max(8, -(-C // 8) * 8)  # pad to sublane multiple
    keep = pos_sorted < C  # Lite-style hard bin limit; overflow drops
    tok_sorted = jnp.take_along_axis(flat_tok, order, axis=1)
    xg = xf.reshape(G, Tg, d)

    # dispatch: slot (e, c) reads sorted position starts[e] + c
    src = starts[:, :, None] + jnp.arange(C)[None, None, :]  # (G, E, C)
    valid = src < ends[:, :, None]
    src_cl = jnp.clip(src, 0, Tg * k - 1).reshape(G, E * C)
    tok_slot = jnp.take_along_axis(tok_sorted, src_cl, axis=1)  # (G, E*C)
    gathered = jnp.take_along_axis(xg, tok_slot[:, :, None], axis=1)
    gathered = gathered * valid.reshape(G, E * C, 1).astype(x.dtype)
    h = hint(gathered.reshape(G, E, C, d), "moe_buf")

    # ---- expert SwiGLU (E = EP shard axis; G = FSDP shard axis)
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", h, params["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", h, params["w_up"])
    out = hint(jnp.einsum("gecf,efd->gecd", g * u, params["w_down"]),
               "moe_buf")
    out_flat = out.reshape(G, E * C, d)

    # combine: sorted position p reads slot e_p*C + pos_p, masked by keep,
    # then the inverse permutation (a gather) restores token-major order
    slot_sorted = jnp.clip(sorted_e * C + pos_sorted, 0, E * C - 1)
    vals_sorted = jnp.take_along_axis(out_flat, slot_sorted[:, :, None],
                                      axis=1)
    vals_sorted = vals_sorted * keep[:, :, None].astype(x.dtype)
    inv = jnp.argsort(order, axis=1)
    vals = jnp.take_along_axis(vals_sorted, inv[:, :, None], axis=1)
    w = top_p.reshape(G, Tg, k, 1).astype(x.dtype)
    y = (vals.reshape(G, Tg, k, d) * w).sum(axis=2)
    return y.reshape(B, S, d), aux
