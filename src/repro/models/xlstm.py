"""xLSTM blocks: mLSTM (matrix memory, parallel trainable) and sLSTM
(scalar memory, sequential scan) — arXiv:2405.04517.

mLSTM training uses the stabilized parallel (quadratic-in-chunk) form:
    D[i,j] = exp(F_i - F_j + i_j - m_i),  F = cumsum(log sigmoid(f))
    y = ((Q K^T / sqrt(d)) .* D) V  /  max(|row-sum|, exp(-m))
which is causal linear-attention-with-gates — dense matmuls on the MXU.
Decode keeps the (B, H, Dh, Dh) matrix memory: O(1) per token.

sLSTM is inherently sequential (recurrent gate coupling); training runs a
lax.scan over time, decode is a single cell step. Exponential gating is
stabilized with the running max state m (as in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense, init_dense

__all__ = [
    "XLSTMConfig", "init_mlstm", "mlstm", "mlstm_decode", "MLSTMState",
    "init_mlstm_state", "init_slstm", "slstm", "slstm_decode", "SLSTMState",
    "init_slstm_state",
]


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int = 4

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# ================================================================== mLSTM
def init_mlstm(key, cfg: XLSTMConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    return {
        "wq": init_dense(ks[0], d, d, dtype=dtype),
        "wk": init_dense(ks[1], d, d, dtype=dtype),
        "wv": init_dense(ks[2], d, d, dtype=dtype),
        "wi": init_dense(ks[3], d, cfg.n_heads, dtype=jnp.float32),  # input gate
        "wf": init_dense(ks[4], d, cfg.n_heads, dtype=jnp.float32),  # forget gate
        "wo_gate": init_dense(ks[5], d, d, dtype=dtype),  # output gate (vector)
        "wout": init_dense(jax.random.fold_in(key, 9), d, d, dtype=dtype),
    }


def mlstm_quadratic_ref(params: dict, x: jnp.ndarray,
                        cfg: XLSTMConfig) -> jnp.ndarray:
    """Reference (O(S^2) materialized) stabilized mLSTM — used by tests as
    the oracle for the chunkwise production path below. Do not use at long
    sequence length: it materializes (B, S, S, H)."""
    B, S, d = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    q = dense(params["wq"], x).reshape(B, S, H, Dh)
    k = dense(params["wk"], x).reshape(B, S, H, Dh) * (Dh ** -0.5)
    v = dense(params["wv"], x).reshape(B, S, H, Dh)
    logi = dense(params["wi"], x).astype(jnp.float32)  # (B,S,H)
    logf = jax.nn.log_sigmoid(dense(params["wf"], x).astype(jnp.float32))
    F = jnp.cumsum(logf, axis=1)  # (B,S,H)

    # log D[i,j] = F_i - F_j + logi_j  (j <= i)
    logD = (F[:, :, None, :] - F[:, None, :, :]) + logi[:, None, :, :]
    ii = jnp.arange(S)[:, None]
    jj = jnp.arange(S)[None, :]
    causal = (jj <= ii)[None, :, :, None]
    logD = jnp.where(causal, logD, -1e30)  # finite mask: keeps VJP NaN-free
    m = jnp.max(logD, axis=2, keepdims=True)  # (B,S,1,H) row max
    m = jnp.maximum(m, -1e30)
    D = jnp.exp(logD - m)  # (B,Si,Sj,H)

    scores = jnp.einsum("bihd,bjhd->bijh", q, k) * D
    norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2, keepdims=True)),
                       jnp.exp(-m))  # (B,S,1,H)
    y = jnp.einsum("bijh,bjhd->bihd", (scores / norm).astype(x.dtype), v)
    o = jax.nn.sigmoid(dense(params["wo_gate"], x))
    y = y.reshape(B, S, d) * o
    return dense(params["wout"], y)


def mlstm(params: dict, x: jnp.ndarray, cfg: XLSTMConfig,
          chunk: int = 128) -> jnp.ndarray:
    """Chunkwise-parallel stabilized mLSTM (production path).

    Intra-chunk terms are (Q x Q) masked matmuls computed for all chunks at
    once; the (B, H, Dh, Dh) matrix memory is carried across chunks by a
    short lax.scan. Memory O(S*Q), FLOPs O(S*Q*Dh + S*Dh^2) — versus the
    quadratic reference's O(S^2). Matches mlstm_quadratic_ref to fp32
    tolerance (tests/test_xlstm_chunk.py).
    """
    B, S, d = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q
    q = dense(params["wq"], x).reshape(B, S, H, Dh)
    k = dense(params["wk"], x).reshape(B, S, H, Dh) * (Dh ** -0.5)
    v = dense(params["wv"], x).reshape(B, S, H, Dh)
    logi = dense(params["wi"], x).astype(jnp.float32)  # (B,S,H)
    logf = jax.nn.log_sigmoid(dense(params["wf"], x).astype(jnp.float32))

    # chunked views (B,nc,Q,...)
    qc = q.reshape(B, nc, Q, H, Dh).astype(jnp.float32)
    kc = k.reshape(B, nc, Q, H, Dh).astype(jnp.float32)
    vc = v.reshape(B, nc, Q, H, Dh).astype(jnp.float32)
    li = logi.reshape(B, nc, Q, H)
    lf = logf.reshape(B, nc, Q, H)
    b_cum = jnp.cumsum(lf, axis=2)  # (B,nc,Q,H) inclusive cumsum in chunk
    b_tot = b_cum[:, :, -1, :]  # (B,nc,H)

    # ---- intra-chunk (vectorized over chunks; no carry needed)
    # logw[i,j] = b_i - b_j + logi_j  (j <= i)
    logw = (b_cum[:, :, :, None, :] - b_cum[:, :, None, :, :]
            + li[:, :, None, :, :])  # (B,nc,Qi,Qj,H)
    ii = jnp.arange(Q)[:, None]
    jj = jnp.arange(Q)[None, :]
    causal = (jj <= ii)[None, None, :, :, None]
    logw = jnp.where(causal, logw, -1e30)
    m_intra = jnp.max(logw, axis=3)  # (B,nc,Qi,H)

    # carried-state contribution scale per query: b_i + m_prev (m_prev via scan)
    # chunk-state ingest weights (for the state update at chunk end):
    logu = b_tot[:, :, None, :] - b_cum + li  # (B,nc,Q,H)
    m_state = jnp.max(logu, axis=2)  # (B,nc,H)

    def chunk_step(carry, inp):
        C, n, m_prev = carry  # (B,H,Dh,Dh), (B,H,Dh), (B,H)
        qi, ki, vi, b_i, logw_i, m_intra_i, logu_i, m_state_i, btot_i = inp
        # qi.. : (B,Q,H,*) ; b_i: (B,Q,H); logw_i: (B,Q,Q,H)
        m_inter = b_i + m_prev[:, None, :]  # (B,Q,H)
        m_i = jnp.maximum(m_intra_i, m_inter)  # (B,Q,H)
        w = jnp.exp(logw_i - m_i[:, :, None, :])  # (B,Qi,Qj,H)
        scores = jnp.einsum("bihd,bjhd->bijh", qi, ki) * w
        y_intra = jnp.einsum("bijh,bjhd->bihd", scores, vi)
        n_intra = jnp.einsum("bijh,bjhd->bihd", w, ki)
        scale = jnp.exp(m_inter - m_i)  # (B,Q,H)
        y_inter = jnp.einsum("bihd,bhde->bihe", qi, C) * scale[..., None]
        # normalizer: q . n_comb
        qn_inter = jnp.einsum("bihd,bhd->bih", qi, n) * scale
        qn_intra = jnp.sum(scores, axis=2)  # (B,Qi,H) == q . sum_j w k_j
        den = jnp.maximum(jnp.abs(qn_inter + qn_intra), jnp.exp(-m_i))
        y = (y_intra + y_inter) / den[..., None]

        # ---- state update at chunk end
        m_new = jnp.maximum(btot_i + m_prev, m_state_i)  # (B,H)
        u = jnp.exp(logu_i - m_new[:, None, :])  # (B,Q,H)
        C_new = (C * jnp.exp(btot_i + m_prev - m_new)[..., None, None]
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", u, ki, vi))
        n_new = (n * jnp.exp(btot_i + m_prev - m_new)[..., None]
                 + jnp.einsum("bjh,bjhd->bhd", u, ki))
        return (C_new, n_new, m_new), y

    inps = (
        jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(b_cum, 1, 0), jnp.moveaxis(logw, 1, 0),
        jnp.moveaxis(m_intra, 1, 0), jnp.moveaxis(logu, 1, 0),
        jnp.moveaxis(m_state, 1, 0), jnp.moveaxis(b_tot, 1, 0),
    )
    C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
    n0 = jnp.zeros((B, H, Dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, ys = jax.lax.scan(chunk_step, (C0, n0, m0), inps)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d).astype(x.dtype)
    o = jax.nn.sigmoid(dense(params["wo_gate"], x))
    return dense(params["wout"], y * o)


class MLSTMState(NamedTuple):
    C: jnp.ndarray  # (B, H, Dh, Dh) matrix memory
    n: jnp.ndarray  # (B, H, Dh) normalizer
    m: jnp.ndarray  # (B, H) stabilizer


def init_mlstm_state(batch: int, cfg: XLSTMConfig) -> MLSTMState:
    H, Dh = cfg.n_heads, cfg.head_dim
    return MLSTMState(
        C=jnp.zeros((batch, H, Dh, Dh), jnp.float32),
        n=jnp.zeros((batch, H, Dh), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
    )


def mlstm_decode(params: dict, x: jnp.ndarray, state: MLSTMState,
                 cfg: XLSTMConfig) -> tuple[jnp.ndarray, MLSTMState]:
    """Recurrent mLSTM step. x: (B, 1, d)."""
    B, _, d = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    q = dense(params["wq"], x).reshape(B, H, Dh)
    k = dense(params["wk"], x).reshape(B, H, Dh) * (Dh ** -0.5)
    v = dense(params["wv"], x).reshape(B, H, Dh)
    logi = dense(params["wi"], x)[:, 0].astype(jnp.float32)  # (B,H)
    logf = jax.nn.log_sigmoid(dense(params["wf"], x)[:, 0].astype(jnp.float32))

    m_new = jnp.maximum(logf + state.m, logi)
    i_g = jnp.exp(logi - m_new)
    f_g = jnp.exp(logf + state.m - m_new)
    C = (state.C * f_g[..., None, None]
         + i_g[..., None, None] * jnp.einsum("bhd,bhe->bhde",
                                             k.astype(jnp.float32),
                                             v.astype(jnp.float32)))
    n = state.n * f_g[..., None] + i_g[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32),
                                         n)), jnp.exp(-m_new))
    y = (num / den[..., None]).astype(x.dtype).reshape(B, 1, d)
    o = jax.nn.sigmoid(dense(params["wo_gate"], x))
    return dense(params["wout"], y * o), MLSTMState(C=C, n=n, m=m_new)


# ================================================================== sLSTM
def init_slstm(key, cfg: XLSTMConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    d, H, Dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    # input weights for gates z,i,f,o; recurrent weights per head (block-diag)
    return {
        "w_in": init_dense(ks[0], d, 4 * d, dtype=dtype),
        "r": (jax.random.normal(ks[1], (H, Dh, 4 * Dh)) / jnp.sqrt(Dh)
              ).astype(dtype),
        "wout": init_dense(ks[2], d, d, dtype=dtype),
    }


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, H, Dh)
    n: jnp.ndarray  # (B, H, Dh)
    h: jnp.ndarray  # (B, H, Dh)
    m: jnp.ndarray  # (B, H, Dh)


def init_slstm_state(batch: int, cfg: XLSTMConfig) -> SLSTMState:
    H, Dh = cfg.n_heads, cfg.head_dim
    z = jnp.zeros((batch, H, Dh), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full_like(z, -1e30))


def _slstm_cell(params, xt, state: SLSTMState, cfg: XLSTMConfig):
    """xt: (B, 4*d) pre-projected input gates; recurrent part added here."""
    B = xt.shape[0]
    H, Dh = cfg.n_heads, cfg.head_dim
    rec = jnp.einsum("bhd,hde->bhe", state.h.astype(xt.dtype), params["r"])
    gates = xt.reshape(B, H, 4 * Dh) + rec  # (B,H,4Dh)
    z_r, i_r, f_r, o_r = jnp.split(gates.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    logi = i_r
    logf = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(logf + state.m, logi)
    i_g = jnp.exp(logi - m_new)
    f_g = jnp.exp(logf + state.m - m_new)
    c = f_g * state.c + i_g * z
    n = f_g * state.n + i_g
    h = o * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm(params: dict, x: jnp.ndarray, cfg: XLSTMConfig) -> jnp.ndarray:
    """Sequential sLSTM over the sequence. x: (B, S, d)."""
    B, S, d = x.shape
    xt_all = dense(params["w_in"], x)  # (B, S, 4d)

    def body(state, xt):
        new = _slstm_cell(params, xt, state, cfg)
        return new, new.h

    state0 = init_slstm_state(B, cfg)
    _, hs = jax.lax.scan(body, state0, jnp.moveaxis(xt_all, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    return dense(params["wout"], y)


def slstm_decode(params: dict, x: jnp.ndarray, state: SLSTMState,
                 cfg: XLSTMConfig) -> tuple[jnp.ndarray, SLSTMState]:
    B, _, d = x.shape
    xt = dense(params["w_in"], x)[:, 0]
    new = _slstm_cell(params, xt, state, cfg)
    y = new.h.reshape(B, 1, d).astype(x.dtype)
    return dense(params["wout"], y), new
