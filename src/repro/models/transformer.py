"""Composable decoder stack: init / forward / decode for every arch family.

Params are dicts keyed by segment index with stacked (n_layers_in_segment,
...) leaves; segments execute under jax.lax.scan (O(1) HLO in depth).
``shared_attn`` segments (zamba-style) hold ONE set of weights reused at
every occurrence.

Public API:
    init_params(cfg, key, dtype)            -> params pytree
    forward(params, cfg, tokens|embeds)     -> logits (B, S, vocab)
    lm_loss(params, cfg, batch)             -> (loss, metrics)
    init_cache(cfg, batch, s_max, dtype)    -> decode cache pytree
    decode_step(params, cfg, cache, ...)    -> (logits, cache)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .attention import (KVCache, attention, attention_decode, init_attention,
                        init_kv_cache)
from .layers import (dense, embed, init_dense, init_embedding, init_mlp,
                     init_rms_norm, layer_norm, mlp_gelu, mlp_swiglu,
                     rms_norm, rope_freqs, unembed)
from .mamba2 import (Mamba2Config, Mamba2State, init_mamba2,
                     init_mamba2_state, mamba2, mamba2_decode)
from .moe import init_moe, moe_apply
from .xlstm import (XLSTMConfig, init_mlstm, init_mlstm_state, init_slstm,
                    init_slstm_state, mlstm, mlstm_decode, slstm,
                    slstm_decode)

__all__ = ["init_params", "forward", "lm_loss", "init_cache", "decode_step"]


# ----------------------------------------------------------------- helpers
def _norm_fn(cfg: ArchConfig):
    return rms_norm if cfg.norm == "rms" else layer_norm


def _init_norm(cfg: ArchConfig, d: int):
    p = init_rms_norm(d)
    if cfg.norm == "ln":
        p = {"scale": p["scale"], "bias": jnp.zeros((d,), jnp.float32)}
    return p


def _mlp_fn(cfg: ArchConfig):
    return mlp_swiglu if cfg.mlp == "swiglu" else mlp_gelu


def _mamba_cfg(cfg: ArchConfig) -> Mamba2Config:
    return Mamba2Config(d_model=cfg.d_model, d_state=cfg.ssm_state,
                        head_dim=cfg.mamba_headdim)


def _xlstm_cfg(cfg: ArchConfig) -> XLSTMConfig:
    return XLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads)


def _inv_freq(cfg: ArchConfig):
    if cfg.positions != "rope" or cfg.rope_fraction <= 0:
        return None
    return rope_freqs(cfg.resolved_head_dim, cfg.rope_theta,
                      cfg.rope_fraction)


def _sinusoidal(S: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d - d // 2)]))
    return pe.astype(dtype)


# -------------------------------------------------------------------- init
def _init_block(cfg: ArchConfig, kind: str, key) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    hd = cfg.resolved_head_dim
    if kind in ("dense", "moe", "shared_attn"):
        p = {
            "norm1": _init_norm(cfg, d),
            "attn": init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads, hd,
                                   qkv_bias=cfg.qkv_bias),
            "norm2": _init_norm(cfg, d),
        }
        if kind == "moe":
            p["moe"] = init_moe(ks[1], d, cfg.moe)
        else:
            p["mlp"] = init_mlp(ks[1], d, cfg.d_ff,
                                gated=(cfg.mlp == "swiglu"))
        return p
    if kind == "mamba2":
        return {"norm1": _init_norm(cfg, d),
                "mamba": init_mamba2(ks[0], _mamba_cfg(cfg))}
    if kind == "mlstm":
        return {"norm1": _init_norm(cfg, d),
                "mlstm": init_mlstm(ks[0], _xlstm_cfg(cfg))}
    if kind == "slstm":
        return {"norm1": _init_norm(cfg, d),
                "slstm": init_slstm(ks[0], _xlstm_cfg(cfg))}
    raise ValueError(f"unknown block kind {kind!r}")


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, len(cfg.layout) + 3)
    params: dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": _init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(ks[1], cfg.padded_vocab,
                                           cfg.d_model)
    shared_done = False
    for si, (kind, cnt) in enumerate(cfg.layout):
        if kind == "shared_attn":
            if not shared_done:  # ONE copy, reused at every occurrence
                params["shared"] = _init_block(cfg, kind, ks[si + 2])
                shared_done = True
            continue
        # stacked params for the scanned segment
        stack = [
            _init_block(cfg, kind, jax.random.fold_in(ks[si + 2], i))
            for i in range(cnt)
        ]
        params[f"seg{si}"] = jax.tree.map(
            lambda *xs: jnp.stack(xs, 0), *stack)
    if dtype != jnp.float32:
        params = jax.tree.map(lambda x: x.astype(dtype)
                              if x.dtype == jnp.float32 else x, params)
    return params


# ----------------------------------------------------------------- forward
def _block_apply(cfg: ArchConfig, kind: str, p: dict, x: jnp.ndarray,
                 inv_freq, hint=None,
                 moe_groups: int = 1) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One block forward; returns (x, aux_loss).

    ``hint`` re-constrains the residual stream each layer — with a mesh this
    is Megatron-style sequence parallelism (seq dim sharded on the model
    axis between blocks; XLA inserts the gather/scatter around attention).
    """
    norm = _norm_fn(cfg)
    hint = hint or (lambda t, role: t)
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe", "shared_attn"):
        x = x + attention(p["attn"], norm(p["norm1"], x),
                          n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                          head_dim=cfg.resolved_head_dim, inv_freq=inv_freq,
                          window=cfg.sliding_window, hint=hint)
        h = norm(p["norm2"], x)
        if kind == "moe":
            # gather seq across TP once (Megatron-SP schedule): dispatch
            # groups == dp shards, so the expert scatter stays TP-local
            h = hint(h, "moe_in")
            y, aux = moe_apply(p["moe"], h, cfg.moe, hint=hint,
                               groups=moe_groups)
        else:
            y = _mlp_fn(cfg)(p["mlp"], h)
        return hint(x + y, "residual"), aux
    if kind == "mamba2":
        x = x + mamba2(p["mamba"], norm(p["norm1"], x), _mamba_cfg(cfg))
    elif kind == "mlstm":
        x = x + mlstm(p["mlstm"], norm(p["norm1"], x), _xlstm_cfg(cfg))
    elif kind == "slstm":
        x = x + slstm(p["slstm"], norm(p["norm1"], x), _xlstm_cfg(cfg))
    else:
        raise ValueError(kind)
    return hint(x, "residual"), aux


def _run_segments(params, cfg: ArchConfig, x: jnp.ndarray,
                  remat: bool = False, hint=None, moe_groups: int = 1):
    inv_freq = _inv_freq(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    for si, (kind, cnt) in enumerate(cfg.layout):
        if kind == "shared_attn":
            for _ in range(cnt):
                x, aux = _block_apply(cfg, kind, params["shared"], x,
                                      inv_freq, hint, moe_groups)
                aux_total += aux
            continue

        def body(carry, p, _kind=kind):
            xc, auxc = carry

            def blk(pp, xx):  # closure keeps inv_freq/hint out of the
                return _block_apply(cfg, _kind, pp, xx, inv_freq, hint,
                                    moe_groups)

            fn = jax.checkpoint(blk) if remat else blk
            xn, aux = fn(p, xc)
            return (xn.astype(xc.dtype), auxc + aux), None

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params[f"seg{si}"])
    return x, aux_total


def embed_inputs(params, cfg: ArchConfig, tokens=None, embeds=None):
    """Tokens -> activations; modality stubs pass precomputed ``embeds``
    (frame/patch embeddings) which are prepended to token embeddings."""
    parts = []
    if embeds is not None:
        parts.append(embeds)
    if tokens is not None:
        parts.append(embed(params["embed"], tokens))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    if cfg.positions == "sinusoidal":
        x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]
    return x


def forward(params, cfg: ArchConfig, tokens=None, embeds=None,
            remat: bool = False, hint=None, act_dtype=None,
            moe_groups: int = 1) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits, aux_loss).

    ``hint``: optional callable(x, role) applying sharding constraints
    (launch/sharding.make_hint_fn); identity when None (mesh-free tests).
    """
    hint = hint or (lambda x, role: x)
    x = hint(embed_inputs(params, cfg, tokens, embeds), "activations")
    if act_dtype is not None:
        x = x.astype(act_dtype)
    x, aux = _run_segments(params, cfg, x, remat=remat, hint=hint,
                           moe_groups=moe_groups)
    x = _norm_fn(cfg)(params["final_norm"], x)
    table = params["embed" if cfg.tie_embeddings else "unembed"]
    logits = hint(unembed(table, x), "logits")
    if cfg.padded_vocab != cfg.vocab:  # mask vocab-padding columns
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits, aux


def lm_loss(params, cfg: ArchConfig, batch: dict,
            remat: bool = False, hint=None,
            act_dtype=None, moe_groups: int = 1) -> tuple[jnp.ndarray, dict]:
    """Next-token cross-entropy (+ z-loss + MoE aux)."""
    hint = hint or (lambda x, role: x)
    logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), remat=remat, hint=hint,
                          act_dtype=act_dtype, moe_groups=moe_groups)
    labels = batch["labels"]
    # align: logits for the positions that predict `labels`
    logits = hint(logits[:, -labels.shape[1]:, :].astype(jnp.float32),
                  "logits")
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    xent = jnp.sum((logz - gold) * mask) / denom
    zloss = 1e-4 * jnp.sum(jnp.square(logz) * mask) / denom
    loss = xent + zloss + 1e-2 * aux
    return loss, {"xent": xent, "zloss": zloss, "moe_aux": aux}


# ------------------------------------------------------------------ decode
def init_cache(cfg: ArchConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode-state pytree mirroring the layout."""
    cache: dict[str, Any] = {}
    hd = cfg.resolved_head_dim
    shared_idx = 0
    for si, (kind, cnt) in enumerate(cfg.layout):
        if kind in ("dense", "moe"):
            cache[f"seg{si}"] = KVCache(
                jnp.zeros((cnt, batch, s_max, cfg.n_kv_heads, hd), dtype),
                jnp.zeros((cnt, batch, s_max, cfg.n_kv_heads, hd), dtype))
        elif kind == "shared_attn":
            for _ in range(cnt):
                cache[f"shared{shared_idx}"] = init_kv_cache(
                    batch, s_max, cfg.n_kv_heads, hd, dtype)
                shared_idx += 1
        elif kind == "mamba2":
            st = init_mamba2_state(batch, _mamba_cfg(cfg))
            cache[f"seg{si}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cnt,) + x.shape).copy(), st)
        elif kind == "mlstm":
            st = init_mlstm_state(batch, _xlstm_cfg(cfg))
            cache[f"seg{si}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cnt,) + x.shape).copy(), st)
        elif kind == "slstm":
            st = init_slstm_state(batch, _xlstm_cfg(cfg))
            cache[f"seg{si}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cnt,) + x.shape).copy(), st)
    return cache


def _block_decode(cfg: ArchConfig, kind: str, p: dict, x, state, pos,
                  inv_freq):
    norm = _norm_fn(cfg)
    if kind in ("dense", "moe", "shared_attn"):
        y, state = attention_decode(
            p["attn"], norm(p["norm1"], x), state, pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, inv_freq=inv_freq,
            window=cfg.sliding_window)
        x = x + y
        h = norm(p["norm2"], x)
        if kind == "moe":
            y2, _ = moe_apply(p["moe"], h, cfg.moe)
        else:
            y2 = _mlp_fn(cfg)(p["mlp"], h)
        return x + y2, state
    if kind == "mamba2":
        y, state = mamba2_decode(p["mamba"], norm(p["norm1"], x), state,
                                 _mamba_cfg(cfg))
        return x + y, state
    if kind == "mlstm":
        y, state = mlstm_decode(p["mlstm"], norm(p["norm1"], x), state,
                                _xlstm_cfg(cfg))
        return x + y, state
    if kind == "slstm":
        y, state = slstm_decode(p["slstm"], norm(p["norm1"], x), state,
                                _xlstm_cfg(cfg))
        return x + y, state
    raise ValueError(kind)


def decode_step(params, cfg: ArchConfig, cache: dict, tokens: jnp.ndarray,
                pos) -> tuple[jnp.ndarray, dict]:
    """One-token decode. tokens: (B, 1) int32; pos: scalar position of the
    new token (KV caches of length s_max must satisfy pos < s_max)."""
    inv_freq = _inv_freq(cfg)
    x = embed(params["embed"], tokens)
    if cfg.positions == "sinusoidal":
        d = cfg.d_model
        x = x + _sinusoidal_at(pos, d, x.dtype)
    new_cache = dict(cache)
    shared_idx = 0
    for si, (kind, cnt) in enumerate(cfg.layout):
        if kind == "shared_attn":
            for _ in range(cnt):
                key = f"shared{shared_idx}"
                x, new_cache[key] = _block_decode(
                    cfg, kind, params["shared"], x, cache[key], pos, inv_freq)
                shared_idx += 1
            continue

        def body(x, ps, _kind=kind):
            p, st = ps
            xn, st_new = _block_decode(cfg, _kind, p, x, st, pos, inv_freq)
            return xn, st_new

        x, new_cache[f"seg{si}"] = jax.lax.scan(
            body, x, (params[f"seg{si}"], cache[f"seg{si}"]))
    x = _norm_fn(cfg)(params["final_norm"], x)
    table = params["embed" if cfg.tie_embeddings else "unembed"]
    logits = unembed(table, x)
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits, new_cache


def _sinusoidal_at(pos, d: int, dtype) -> jnp.ndarray:
    dim = jnp.arange(0, d, 2).astype(jnp.float32)
    ang = jnp.asarray(pos, jnp.float32) / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang[: (d - d // 2)]))
    return pe.astype(dtype)[None, None, :]
