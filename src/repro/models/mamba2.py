"""Mamba2 (SSD) block — chunked-parallel training form + O(1) decode.

The SSD formulation computes the selective state-space recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T ,   y_t = C_t h_t + D x_t

with a chunk-parallel algorithm: inside a chunk of Q steps everything is a
(masked) matmul (MXU-friendly); across chunks a short lax.scan carries the
(H, N, P) state. This is the TPU-native layout of the Mamba2 paper's
algorithm; no sequential scan over single timesteps ever happens in
training, so seq 4k..32k lowers to dense matmuls.

Decode is the plain recurrence: state (B, H, N, P) updated in O(H*N*P) per
token — the reason long_500k runs for SSM/hybrid archs (DESIGN.md).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import dense, init_dense

__all__ = ["Mamba2Config", "init_mamba2", "mamba2", "mamba2_decode",
           "init_mamba2_state", "Mamba2State"]


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2(key, cfg: Mamba2Config, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    # in_proj emits [z, x, B, C, dt]
    return {
        "in_proj": init_dense(ks[0], d, 2 * di + 2 * N + H, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, di)) * 0.2
                   ).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": init_dense(ks[2], di, d, dtype=dtype),
    }


def _split_proj(params, u, cfg: Mamba2Config):
    di, N, H = cfg.d_inner, cfg.d_state, cfg.n_heads
    zxbcdt = dense(params["in_proj"], u)
    z, x, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    return z, x, Bm, Cm, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d: x (B,S,D), w (K,D)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out)


def _gated_norm(y, z, scale, eps=1e-6):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def mamba2(params: dict, u: jnp.ndarray, cfg: Mamba2Config) -> jnp.ndarray:
    """Chunked SSD forward. u: (B, S, d_model); S must be chunk-divisible
    (the transformer stack pads)."""
    B, S, _ = u.shape
    H, N, P = cfg.n_heads, cfg.d_state, cfg.head_dim
    Q = min(cfg.chunk, S)
    nc = S // Q
    z, x, Bm, Cm, dt = _split_proj(params, u, cfg)
    x = _causal_conv(x, params["conv_w"])
    x = x.reshape(B, S, H, P)
    A = -jnp.exp(params["A_log"])  # (H,)

    # chunked views
    xc = x.reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dA = dtc * A  # (B,nc,Q,H)
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative

    # ---- intra-chunk (masked quadratic within Q only)
    # L[i,j] = exp(cum_i - cum_j) for j <= i  (B,nc,H,Q,Q)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    ii = jnp.arange(Q)[:, None]
    jj = jnp.arange(Q)[None, :]
    mask = (jj <= ii)[None, None, :, :, None]
    # clamp BEFORE exp: masked (j > i) entries have diff > 0 and would
    # overflow; exp(inf)*0 poisons the VJP with NaNs.
    Lm = jnp.exp(jnp.where(mask, diff, -1e30))
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)[..., None] * Lm  # (B,nc,Q,Q,H)
    xbar = xc * dtc[..., None]  # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xbar.astype(jnp.float32))

    # ---- inter-chunk: scan over chunks carrying state (B,H,N,P)
    seg_end = cum[:, :, -1:, :]  # (B,nc,1,H)
    # state contribution of chunk c: sum_j exp(seg_end - cum_j) * B_j x_j^T
    w_in = jnp.exp(seg_end - cum)  # (B,nc,Q,H)
    chunk_state = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchnp", Bc, w_in, xbar.astype(jnp.float32))
    decay_chunk = jnp.exp(seg_end[:, :, 0, :])  # (B,nc,H)

    def scan_body(h, inp):
        cs, dc = inp  # (B,H,N,P), (B,H)
        h_out = h  # state BEFORE this chunk
        h = h * dc[..., None, None] + cs
        return h, h_out

    cs_t = jnp.moveaxis(chunk_state, 1, 0)  # (nc,B,H,N,P)
    dc_t = jnp.moveaxis(decay_chunk, 1, 0)  # (nc,B,H)
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, h_prev = jax.lax.scan(scan_body, h0, (cs_t, dc_t))
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,H,N,P) state entering chunk

    y_inter = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cum), h_prev)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + params["D"][None, None, :, None] * x
    y = y.reshape(B, S, cfg.d_inner).astype(u.dtype)
    y = _gated_norm(y, z, params["norm_scale"])
    return dense(params["out_proj"], y)


# ------------------------------------------------------------------- decode
from typing import NamedTuple


class Mamba2State(NamedTuple):
    h: jnp.ndarray  # (B, H, N, P) ssm state
    conv: jnp.ndarray  # (B, K-1, d_inner) conv tail


def init_mamba2_state(batch: int, cfg: Mamba2Config,
                      dtype=jnp.float32) -> Mamba2State:
    return Mamba2State(
        h=jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
    )


def mamba2_decode(params: dict, u: jnp.ndarray, state: Mamba2State,
                  cfg: Mamba2Config) -> tuple[jnp.ndarray, Mamba2State]:
    """One-token step. u: (B, 1, d_model)."""
    B = u.shape[0]
    H, N, P = cfg.n_heads, cfg.d_state, cfg.head_dim
    z, x, Bm, Cm, dt = _split_proj(params, u, cfg)  # seq dim = 1
    # conv over [tail, x]
    xin = jnp.concatenate([state.conv, x], axis=1)  # (B, K, di)
    w = params["conv_w"]
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", xin, w))[:, None, :]
    new_conv = xin[:, 1:, :]
    xh = xc.reshape(B, H, P)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[:, 0, :] * A)  # (B,H)
    xbar = xh * dt[:, 0, :, None]  # (B,H,P)
    h = state.h * dA[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32), xbar.astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, 1, cfg.d_inner).astype(u.dtype)
    y = _gated_norm(y, z, params["norm_scale"])
    return dense(params["out_proj"], y), Mamba2State(h=h, conv=new_conv)
