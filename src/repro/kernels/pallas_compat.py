"""Pallas API compatibility across jax versions.

The TPU compiler-params class was renamed ``TPUCompilerParams`` ->
``CompilerParams`` around jax 0.6; the kernels must build on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(dimension_semantics: tuple[str, ...]):
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(dimension_semantics=dimension_semantics)
