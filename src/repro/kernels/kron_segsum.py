"""Pallas TPU kernel: fused Kronecker-contribution + segment-sum (TTM build).

This is the compute hot spot of HOOI (paper §4.3): for every non-zero element
e, accumulate ``val(e) * kron(a_e, b_e)`` into row ``rows[e]`` of the local
penultimate matrix Z^p. On a GPU/CPU this is a scatter-add; scatter-add is
hostile to the TPU's systolic MXU, so we *reformulate segment-sum as a
one-hot matmul* (the TPU-native adaptation, see DESIGN.md §2):

    Z[rb*128 : rb*128+128, :] += onehot(rows)ᵀ @ C,   C = kron(a_blk, b_blk)

Key structural facts exploited:

  * elements are sorted by dense-renumbered local row id, so one block of
    ``block_e`` elements touches at most ``span = block_e//128 + 2``
    consecutive 128-row blocks (proof: interior rows of a sorted dense-id
    block are fully contained in it);
  * the whole (R_pad, Ka, Kb_blk) Z tile is held in VMEM with a grid-constant
    output index over the inner (element-block) grid dimension, so
    accumulation across grid steps is the canonical safe Pallas pattern
    (no aliasing, no revisits after eviction);
  * the one-hot matmul (128 x block_e) @ (block_e x Ka*Kb_blk) runs on the
    MXU with hardware-aligned dims (128 rows, block_e and Kb_blk multiples
    of 128).

Grid: (n_kb, n_eb) — Kb blocks outer (Z tile changes rarely), element blocks
inner (Z tile constant, stays resident). Scalar-prefetched ``first_rb`` gives
each element block its first row-block so only ``span`` row windows are
updated per step (total MXU work ≈ span·128/block_e ≈ 1.5x the minimal
E·K̂ MACs, versus a fully dense one-hot matmul's R_pad/128 x blowup).

VMEM budget per step: Z tile (R_pad·Ka·Kb_blk·4B) + C (block_e·Ka·Kb_blk·4B)
+ inputs; ops.py enforces <= ~12 MiB and falls back to the jnp reference
beyond that (large-R cases are sharded across devices anyway — Lite's
R_max <= ceil(L/P)+2 bound is precisely what keeps R_pad small per device).

Validated against ref.kron_segsum_ref in interpret mode (CPU) across shape/
dtype sweeps; targets TPU via pl.pallas_call + BlockSpec VMEM tiling.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params

__all__ = ["kron_segsum", "kron_segsum_oracle", "tile_geometry",
           "TileGeometry", "ROW_BLOCK"]

ROW_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class TileGeometry:
    """Padded tile shapes + VMEM footprint of one kernel launch.

    Single source of truth for the padding math: the VMEM admission gate
    (``ops.kernel_fits_vmem``) and the kernel itself both derive their shapes
    from here, so the gate can never drift from what the kernel allocates.
    """

    Ka: int
    block_e: int
    span: int  # 128-row windows one element block can touch
    R_pad: int  # Z-tile rows (num_rows rounded up + span slack)
    kb_blk: int  # Kb block held per grid step
    Kb_pad: int  # Kb rounded up to a multiple of kb_blk
    itemsize: int = 4  # bytes per kron-contribution element (2 under bf16)
    oracle_s: int = 0  # fused oracle panel width (0 = plain build)

    @property
    def vmem_bytes(self) -> int:
        """Resident bytes per grid step: Z tile (always f32) + C block at
        the contribution itemsize, plus — when the first oracle product is
        fused in — the resident X panel slab and the (R_pad, s) accumulator.
        """
        z_tile = self.R_pad * self.Ka * self.kb_blk * 4
        c_blk = self.block_e * self.Ka * self.kb_blk * self.itemsize
        oracle = 0
        if self.oracle_s:
            oracle = (self.Ka * self.kb_blk * self.oracle_s * 4
                      + self.R_pad * self.oracle_s * 4)
        return z_tile + c_blk + oracle


def tile_geometry(num_rows: int, Ka: int, Kb: int,
                  block_e: int = 256, kb_block: int | None = None,
                  itemsize: int = 4, oracle_s: int = 0
                  ) -> TileGeometry:
    span = block_e // ROW_BLOCK + 2
    kb_blk = kb_block or min(max(-(-Kb // 128) * 128, 128), 512)
    return TileGeometry(
        Ka=Ka,
        block_e=block_e,
        span=span,
        R_pad=-(-num_rows // ROW_BLOCK) * ROW_BLOCK + span * ROW_BLOCK,
        kb_blk=kb_blk,
        Kb_pad=-(-Kb // kb_blk) * kb_blk,
        itemsize=itemsize,
        oracle_s=oracle_s,
    )


def _kernel(first_rb_ref, rows_ref, a_ref, b_ref, z_ref, *, span: int,
            block_e: int, Ka: int, kb_blk: int):
    k = pl.program_id(0)  # Kb-block index (outer)
    i = pl.program_id(1)  # element-block index (inner)
    del k  # b/z BlockSpecs already select the Kb block

    @pl.when(i == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    a = a_ref[...]  # (block_e, Ka)
    b = b_ref[...]  # (block_e, kb_blk)
    rows = rows_ref[...]  # (block_e, 1) int32, sorted, dense ids
    # C[e, ka*kb_blk + kb] = a[e, ka] * b[e, kb]   (C-order kron)
    C = (a[:, :, None] * b[:, None, :]).reshape(block_e, Ka * kb_blk)

    row0 = first_rb_ref[i] * ROW_BLOCK
    local = rows[:, 0] - row0  # (block_e,) in [0, span*128) for real elements
    col = jax.lax.broadcasted_iota(jnp.int32, (block_e, ROW_BLOCK), 1)
    for s in range(span):  # statically unrolled: span is 3-6
        onehot = (local[:, None] == col + s * ROW_BLOCK).astype(C.dtype)
        upd = jax.lax.dot_general(
            onehot, C, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (128, Ka*kb_blk) on the MXU
        idx = (pl.dslice(row0 + s * ROW_BLOCK, ROW_BLOCK),
               slice(None), slice(None))
        cur = pl.load(z_ref, idx)
        pl.store(z_ref, idx, cur + upd.reshape(ROW_BLOCK, Ka, kb_blk))


def _cast_contrib_operands(a, b, precision):
    """bf16 mixed precision: the kron contribution operands (and hence the
    per-element products) are rounded to bf16; accumulation into the Z tile
    stays f32 via ``preferred_element_type`` on every MXU dot."""
    if precision == "bf16":
        return a.astype(jnp.bfloat16), b.astype(jnp.bfloat16)
    return a, b


@functools.partial(
    jax.jit,
    static_argnames=("num_rows", "block_e", "kb_block", "interpret",
                     "precision"),
)
def kron_segsum(
    rows: jnp.ndarray,  # (E,) int32 — dense local row ids, SORTED ascending
    a: jnp.ndarray,  # (E, Ka) float32 — values folded in
    b: jnp.ndarray,  # (E, Kb) float32
    num_rows: int,
    *,
    block_e: int = 256,
    kb_block: int | None = None,
    interpret: bool = True,
    precision: str = "f32",
) -> jnp.ndarray:
    """Z = segment_sum(kron(a,b), rows) of shape (num_rows, Ka*Kb).

    Requirements: ``rows`` sorted ascending with dense ids in [0, num_rows)
    (padding elements must have a==0 and any valid sorted row id; the wrapper
    in ops.py arranges all of this). ``precision="bf16"`` rounds the kron
    operands to bf16 (halving the C-block VMEM footprint) while the Z tile
    accumulates in f32.
    """
    E, Ka = a.shape
    Kb = b.shape[1]
    if E == 0:
        # an empty grid would never run the @pl.when(i == 0) zero-init, so
        # the output buffer would be uninitialized memory (and rows[-1]
        # below would index an empty array): the sum over no elements is 0
        return jnp.zeros((num_rows, Ka * Kb), jnp.float32)
    itemsize = 2 if precision == "bf16" else 4
    geom = tile_geometry(num_rows, Ka, Kb, block_e, kb_block,
                         itemsize=itemsize)
    span, kb_blk = geom.span, geom.kb_blk
    R_pad, Kb_pad = geom.R_pad, geom.Kb_pad
    a, b = _cast_contrib_operands(a, b, precision)

    # --- padding to hardware-aligned shapes -------------------------------
    E_pad = -(-E // block_e) * block_e

    if E_pad != E:
        pad = E_pad - E
        # pad rows with the *last* row id to keep sortedness; a=0 kills them
        rows = jnp.concatenate([rows, jnp.full((pad,), rows[-1], rows.dtype)])
        a = jnp.concatenate([a, jnp.zeros((pad, Ka), a.dtype)])
        b = jnp.concatenate([b, jnp.ones((pad, Kb), b.dtype)])
    if Kb_pad != Kb:
        b = jnp.pad(b, ((0, 0), (0, Kb_pad - Kb)))

    n_eb = E_pad // block_e
    n_kb = Kb_pad // kb_blk
    first_rb = rows[jnp.arange(n_eb) * block_e] // ROW_BLOCK  # (n_eb,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_kb, n_eb),
        in_specs=[
            pl.BlockSpec((block_e, 1), lambda k, i, frb: (i, 0)),  # rows
            pl.BlockSpec((block_e, Ka), lambda k, i, frb: (i, 0)),  # a
            pl.BlockSpec((block_e, kb_blk), lambda k, i, frb: (i, k)),  # b
        ],
        out_specs=pl.BlockSpec(
            (R_pad, Ka, kb_blk), lambda k, i, frb: (0, 0, k)
        ),
    )
    kern = functools.partial(
        _kernel, span=span, block_e=block_e, Ka=Ka, kb_blk=kb_blk
    )
    z3 = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R_pad, Ka, Kb_pad), jnp.float32),
        interpret=interpret,
        compiler_params=tpu_compiler_params(("arbitrary", "arbitrary")),
    )(first_rb.astype(jnp.int32), rows[:, None].astype(jnp.int32), a, b)
    return z3[:num_rows, :, :Kb].reshape(num_rows, Ka * Kb)


def _kernel_fused(first_rb_ref, rows_ref, a_ref, b_ref, x_ref, z_ref, zx_ref,
                  *, span: int, block_e: int, Ka: int, kb_blk: int,
                  R_pad: int, n_eb: int):
    """kron-segsum accumulation + first oracle panel product, one launch.

    Identical accumulation body to ``_kernel``; when the element-block loop
    finishes a Kb block (the Z tile for that block is complete and still
    VMEM-resident) the kernel immediately multiplies it into the resident X
    panel slab, so the first Lanczos matvec never re-reads Z from HBM. The
    (R_pad, s) accumulator ``zx`` is grid-constant over the whole grid and
    sums the per-Kb-block partial products.
    """
    k = pl.program_id(0)  # Kb-block index (outer)
    i = pl.program_id(1)  # element-block index (inner)

    @pl.when(i == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    @pl.when((k == 0) & (i == 0))
    def _init_zx():
        zx_ref[...] = jnp.zeros_like(zx_ref)

    a = a_ref[...]  # (block_e, Ka)
    b = b_ref[...]  # (block_e, kb_blk)
    rows = rows_ref[...]  # (block_e, 1) int32, sorted, dense ids
    C = (a[:, :, None] * b[:, None, :]).reshape(block_e, Ka * kb_blk)

    row0 = first_rb_ref[i] * ROW_BLOCK
    local = rows[:, 0] - row0
    col = jax.lax.broadcasted_iota(jnp.int32, (block_e, ROW_BLOCK), 1)
    for s in range(span):
        onehot = (local[:, None] == col + s * ROW_BLOCK).astype(C.dtype)
        upd = jax.lax.dot_general(
            onehot, C, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        idx = (pl.dslice(row0 + s * ROW_BLOCK, ROW_BLOCK),
               slice(None), slice(None))
        cur = pl.load(z_ref, idx)
        pl.store(z_ref, idx, cur + upd.reshape(ROW_BLOCK, Ka, kb_blk))

    @pl.when(i == n_eb - 1)
    def _oracle():
        # Z tile for Kb block k is final here; contract it with the matching
        # X slab while it is still resident. kb_blk is a multiple of 128, so
        # the (R_pad, Ka, kb_blk) -> (R_pad, Ka*kb_blk) reshape is
        # layout-preserving.
        Zf = z_ref[...].reshape(R_pad, Ka * kb_blk)
        Xf = x_ref[...].reshape(Ka * kb_blk, x_ref.shape[-1])
        zx_ref[...] += jax.lax.dot_general(
            Zf, Xf, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


@functools.partial(
    jax.jit,
    static_argnames=("num_rows", "block_e", "kb_block", "interpret",
                     "precision"),
)
def kron_segsum_oracle(
    rows: jnp.ndarray,  # (E,) int32 — dense local row ids, SORTED ascending
    a: jnp.ndarray,  # (E, Ka) float32 — values folded in
    b: jnp.ndarray,  # (E, Kb) float32
    num_rows: int,
    X: jnp.ndarray,  # (Ka*Kb, s) float32 — first oracle panel V_1
    *,
    block_e: int = 256,
    kb_block: int | None = None,
    interpret: bool = True,
    precision: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Z build + first oracle product: returns ``(Z, Z @ X)``.

    Same contract as ``kron_segsum`` plus the panel ``X``; the product is
    computed from the VMEM-resident Z tile at the end of each Kb block, so
    the first Lanczos pass over Z costs no extra HBM read of Z.
    """
    E, Ka = a.shape
    Kb = b.shape[1]
    s = X.shape[1]
    if E == 0:
        return (jnp.zeros((num_rows, Ka * Kb), jnp.float32),
                jnp.zeros((num_rows, s), jnp.float32))
    itemsize = 2 if precision == "bf16" else 4
    geom = tile_geometry(num_rows, Ka, Kb, block_e, kb_block,
                         itemsize=itemsize, oracle_s=s)
    span, kb_blk = geom.span, geom.kb_blk
    R_pad, Kb_pad = geom.R_pad, geom.Kb_pad
    a, b = _cast_contrib_operands(a, b, precision)

    E_pad = -(-E // block_e) * block_e
    if E_pad != E:
        pad = E_pad - E
        rows = jnp.concatenate([rows, jnp.full((pad,), rows[-1], rows.dtype)])
        a = jnp.concatenate([a, jnp.zeros((pad, Ka), a.dtype)])
        b = jnp.concatenate([b, jnp.ones((pad, Kb), b.dtype)])

    # X enters as (Ka*Kb, s) in C-order (b fastest); regroup per Kb block and
    # zero-pad the Kb tail so pad columns of Z contract against zeros
    X3 = X.astype(jnp.float32).reshape(Ka, Kb, s)
    if Kb_pad != Kb:
        b = jnp.pad(b, ((0, 0), (0, Kb_pad - Kb)))
        X3 = jnp.pad(X3, ((0, 0), (0, Kb_pad - Kb), (0, 0)))

    n_eb = E_pad // block_e
    n_kb = Kb_pad // kb_blk
    first_rb = rows[jnp.arange(n_eb) * block_e] // ROW_BLOCK

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_kb, n_eb),
        in_specs=[
            pl.BlockSpec((block_e, 1), lambda k, i, frb: (i, 0)),  # rows
            pl.BlockSpec((block_e, Ka), lambda k, i, frb: (i, 0)),  # a
            pl.BlockSpec((block_e, kb_blk), lambda k, i, frb: (i, k)),  # b
            pl.BlockSpec((Ka, kb_blk, s), lambda k, i, frb: (0, k, 0)),  # X
        ],
        out_specs=[
            pl.BlockSpec((R_pad, Ka, kb_blk), lambda k, i, frb: (0, 0, k)),
            pl.BlockSpec((R_pad, s), lambda k, i, frb: (0, 0)),  # zx acc
        ],
    )
    kern = functools.partial(
        _kernel_fused, span=span, block_e=block_e, Ka=Ka, kb_blk=kb_blk,
        R_pad=R_pad, n_eb=n_eb,
    )
    z3, zx = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R_pad, Ka, Kb_pad), jnp.float32),
            jax.ShapeDtypeStruct((R_pad, s), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(("arbitrary", "arbitrary")),
    )(first_rb.astype(jnp.int32), rows[:, None].astype(jnp.int32), a, b, X3)
    return (z3[:num_rows, :, :Kb].reshape(num_rows, Ka * Kb),
            zx[:num_rows, :])
