"""Pallas TPU kernel: fused Lanczos oracle pair  (Z @ x, Zᵀ @ y).

Every Lanczos bidiagonalization iteration issues the two oracle products
back-to-back (paper §3 'SVD Component'). Z^p is the big operand (R x K̂) and
both products are memory-bound: done naively, Z is streamed from HBM twice
per iteration. Fusing them reads Z once — a straight 2x cut of the dominant
HBM term for the SVD phase.

Design: 1-D grid over 128-row blocks of Z. Per step:
    xo[rb]  = Z_blk @ x          (MXU, 128 x K̂ · K̂)
    yo_acc += Z_blkᵀ @ y[rb]     (MXU, K̂ x 128 · 128)
``yo`` uses a grid-constant output index, so the accumulator tile stays in
VMEM across all steps (canonical safe accumulation pattern). x stays resident
(constant index); y/xo stream block-by-block.

VMEM per step: Z block (128·K̂·4B) + x (K̂·4B) + yo (K̂·4B)  — tiny.
Validated against ref.oracle_pair_ref in interpret mode; TPU-targeted tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401 — kernels reference pltpu types

from .pallas_compat import tpu_compiler_params

__all__ = ["oracle_pair"]

ROW_BLOCK = 128


def _kernel(z_ref, x_ref, y_ref, xo_ref, yo_ref):
    i = pl.program_id(0)
    Z = z_ref[...]  # (128, Khat)
    x = x_ref[...]  # (Khat, s) — s = panel width (1 for the vector oracle)
    y = y_ref[...]  # (128, s)
    xo_ref[...] = jax.lax.dot_general(
        Z, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (128, s)
    zty = jax.lax.dot_general(
        Z, y, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Khat, s)

    @pl.when(i == 0)
    def _init():
        yo_ref[...] = jnp.zeros_like(yo_ref)

    yo_ref[...] += zty


@functools.partial(jax.jit, static_argnames=("interpret",))
def oracle_pair(
    Z: jnp.ndarray,  # (R, Khat) float32
    x: jnp.ndarray,  # (Khat,) or (Khat, s) — panel of right-space directions
    y: jnp.ndarray,  # (R,) or (R, s) — panel of left-space directions
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (Z @ x, Zᵀ @ y) with one pass over Z.

    ``x``/``y`` may be vectors (the classic oracle) or width-``s`` panels
    (block Lanczos): the same grid-constant accumulator services all ``s``
    directions per row block, so the pass count over Z is independent of
    ``s``. Both operands must share the panel width.
    """
    vec_in = x.ndim == 1
    if vec_in:
        x = x[:, None]
        y = y[:, None]
    R, Khat = Z.shape
    s = x.shape[1]
    R_pad = max(-(-R // ROW_BLOCK) * ROW_BLOCK, ROW_BLOCK)
    K_pad = max(-(-Khat // 128) * 128, 128)
    Zp = jnp.pad(Z, ((0, R_pad - R), (0, K_pad - Khat)))
    xp = jnp.pad(x, ((0, K_pad - Khat), (0, 0)))
    yp = jnp.pad(y, ((0, R_pad - R), (0, 0)))
    n_rb = R_pad // ROW_BLOCK

    xo, yo = pl.pallas_call(
        _kernel,
        grid=(n_rb,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, K_pad), lambda i: (i, 0)),  # Z
            pl.BlockSpec((K_pad, s), lambda i: (0, 0)),  # x (resident)
            pl.BlockSpec((ROW_BLOCK, s), lambda i: (i, 0)),  # y
        ],
        out_specs=[
            pl.BlockSpec((ROW_BLOCK, s), lambda i: (i, 0)),  # xo
            pl.BlockSpec((K_pad, s), lambda i: (0, 0)),  # yo (accumulator)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R_pad, s), jnp.float32),
            jax.ShapeDtypeStruct((K_pad, s), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(("arbitrary",)),
    )(Zp, xp, yp)
    if vec_in:
        return xo[:R, 0], yo[:Khat, 0]
    return xo[:R, :], yo[:Khat, :]
