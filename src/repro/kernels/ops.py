"""Jit'd dispatch wrappers around the Pallas kernels.

Public entry points used by the rest of the framework:

  * ``penultimate(coords, values, factors, mode, num_rows)`` — kernel-backed
    drop-in for repro.core.ttm.penultimate / penultimate_local.
  * ``oracle_pair(Z, x, y)`` — fused Lanczos oracle.

The wrappers (i) prepare kernel-friendly layouts (fold the N-2 leading
Kronecker levels into ``a``, sort elements by row id), (ii) enforce the VMEM
budget and fall back to the pure-jnp reference when a shape doesn't fit, and
(iii) select interpret mode automatically (interpret=True off-TPU, compiled
on TPU).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from . import ref
from .kron_segsum import (  # noqa: F401
    ROW_BLOCK, kron_segsum, kron_segsum_oracle, tile_geometry)
from .oracle_fused import oracle_pair as _oracle_pair_kernel

__all__ = ["penultimate", "penultimate_local", "penultimate_sorted",
           "penultimate_sorted_oracle", "oracle_pair", "kernel_fits_vmem",
           "split_kron_dims", "vmem_budget_bytes"]

# conservative default VMEM budget for the resident Z tile + C block (bytes);
# override per-platform with REPRO_VMEM_BUDGET or the vmem_budget_bytes
# parameter on the gate
_VMEM_BUDGET = 12 * 1024 * 1024


def vmem_budget_bytes() -> int:
    """The admission budget for resident kernel tiles, in bytes.

    ``REPRO_VMEM_BUDGET`` (bytes; parsed and validated by
    ``repro.envknobs``) overrides the conservative default, so a real-TPU
    deployment can open up the full ~16 MiB/core (or a fraction, leaving
    headroom for double buffering) without a code change.
    """
    from repro import envknobs

    budget = envknobs.vmem_budget()
    return _VMEM_BUDGET if budget is None else budget


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def kernel_fits_vmem(num_rows: int, Ka: int, Kb: int,
                     block_e: int = 256, *, precision: str = "f32",
                     oracle_s: int = 0,
                     vmem_budget: int | None = None) -> bool:
    """Admission gate: does this launch's resident footprint fit the budget?

    Derives the footprint from the same ``tile_geometry`` the kernel uses
    (bf16 halves the C-block term; a fused oracle panel adds its X slab and
    accumulator), so the gate can never drift from the kernel's allocation.
    """
    geom = tile_geometry(num_rows, Ka, Kb, block_e,
                         itemsize=2 if precision == "bf16" else 4,
                         oracle_s=oracle_s)
    budget = vmem_budget_bytes() if vmem_budget is None else vmem_budget
    return geom.vmem_bytes <= budget


def split_kron_dims(core_dims: Sequence[int], mode: int) -> tuple[int, int]:
    """(Ka, Kb) that ``_split_ab`` will produce for these factor widths.

    Lets callers (the executor's step-key logic) evaluate the VMEM gate
    before any array exists: b takes the last non-mode factor's width, a
    takes the product of the rest.
    """
    other = [j for j in range(len(core_dims)) if j != mode]
    *lead, last = other
    Ka = 1
    for j in lead:
        Ka *= int(core_dims[j])
    return Ka, int(core_dims[last])


def _split_ab(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fold modes j != mode into (a, b): a = val * kron(leading rows),
    b = rows of the last non-mode factor (the widest kron level stays in the
    kernel's hot loop)."""
    other = [j for j in range(len(factors)) if j != mode]
    *lead, last = other
    nnz = values.shape[0]
    a = values[:, None]
    for j in lead:
        rows = jnp.take(factors[j], coords[:, j], axis=0)
        # explicit width (not -1): must also trace for nnz == 0
        a = (a[:, :, None] * rows[:, None, :]).reshape(
            nnz, a.shape[1] * rows.shape[1])
    b = jnp.take(factors[last], coords[:, last], axis=0)
    return a, b


def penultimate_sorted(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    local_rows: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_local_rows: int,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_e: int = 256,
    precision: str = "f32",
) -> jnp.ndarray:
    """Z^p for *pre-sorted* dense local row ids — the partition.py contract.

    ``repro.distributed.partition`` emits each rank's elements already sorted
    by dense-renumbered local row id (padding elements carry value 0 and the
    last real row id), which is exactly the kernel's precondition — so the
    distributed mode step skips the runtime ``argsort`` that
    ``penultimate_local`` pays for arbitrary row orders. All branching here
    is on static shape information, so this is safe to call inside a
    shard_map-traced step: the kernel/fallback choice is baked into the
    trace (and must therefore be part of the compiled-step cache key).
    """
    a, b = _split_ab(coords, values, factors, mode)
    Ka, Kb = a.shape[1], b.shape[1]
    if not use_kernel or not kernel_fits_vmem(num_local_rows, Ka, Kb, block_e,
                                              precision=precision):
        return ref.kron_segsum_ref(local_rows, a, b, num_local_rows,
                                   precision=precision)
    interpret = _interpret_default() if interpret is None else interpret
    return kron_segsum(
        local_rows.astype(jnp.int32),
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        num_local_rows,
        block_e=block_e,
        interpret=interpret,
        precision=precision,
    )


def penultimate_sorted_oracle(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    local_rows: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_local_rows: int,
    X: jnp.ndarray,  # (K_hat, s) first oracle panel
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_e: int = 256,
    precision: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Z^p build + first oracle product ``(Z^p, Z^p @ X)``.

    Same contract as ``penultimate_sorted``; the fused kernel contracts the
    VMEM-resident Z tile against the panel before it is ever written to HBM.
    The fallback computes the product from the reference Z — numerically the
    same pipeline, without the HBM saving.
    """
    a, b = _split_ab(coords, values, factors, mode)
    Ka, Kb = a.shape[1], b.shape[1]
    if not use_kernel or not kernel_fits_vmem(
            num_local_rows, Ka, Kb, block_e, precision=precision,
            oracle_s=int(X.shape[1])):
        return ref.kron_segsum_oracle_ref(local_rows, a, b, num_local_rows,
                                          X, precision=precision)
    interpret = _interpret_default() if interpret is None else interpret
    return kron_segsum_oracle(
        local_rows.astype(jnp.int32),
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        num_local_rows,
        X.astype(jnp.float32),
        block_e=block_e,
        interpret=interpret,
        precision=precision,
    )


def penultimate_local(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    local_rows: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_local_rows: int,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    block_e: int = 256,
    precision: str = "f32",
) -> jnp.ndarray:
    """Kernel-backed local penultimate matrix Z^p (see core.ttm).

    Accepts rows in any order; sorts before handing to the kernel. Callers
    that can guarantee sorted dense ids should use ``penultimate_sorted``.
    """
    if not use_kernel or not kernel_fits_vmem(
            num_local_rows, *split_kron_dims([f.shape[1] for f in factors],
                                             mode), block_e,
            precision=precision):
        a, b = _split_ab(coords, values, factors, mode)
        return ref.kron_segsum_ref(local_rows, a, b, num_local_rows,
                                   precision=precision)
    order = jnp.argsort(local_rows)
    return penultimate_sorted(
        coords[order], values[order], local_rows[order], factors, mode,
        num_local_rows, use_kernel=True, interpret=interpret, block_e=block_e,
        precision=precision)


def penultimate(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_rows: int,
    **kw,
) -> jnp.ndarray:
    """Global Z_(n) (single-rank): rows are the raw mode-n coordinates."""
    return penultimate_local(
        coords, values, coords[:, mode], factors, mode, num_rows, **kw
    )


def oracle_pair(
    Z: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    if not use_kernel:
        return ref.oracle_pair_ref(Z, x, y)
    interpret = _interpret_default() if interpret is None else interpret
    return _oracle_pair_kernel(Z, x, y, interpret=interpret)
