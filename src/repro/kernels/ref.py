"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel in kron_segsum.py / oracle_fused.py is numerically validated
against these functions in tests/test_kernels.py (shape & dtype sweeps,
interpret=True execution of the kernel body).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["kron_segsum_ref", "kron_segsum_oracle_ref", "oracle_pair_ref"]


def kron_segsum_ref(
    rows: jnp.ndarray,  # (E,) int32 local row ids (dense-renumbered)
    a: jnp.ndarray,  # (E, Ka) float — element values folded in
    b: jnp.ndarray,  # (E, Kb) float
    num_rows: int,
    precision: str = "f32",
) -> jnp.ndarray:
    """Z[r] = sum_{e: rows[e]=r} kron(a[e], b[e]) — the TTM hot loop.

    Returns (num_rows, Ka*Kb). C-order kron: b varies fastest.
    ``precision="bf16"`` models the kernel's mixed-precision contract:
    operands and per-element products rounded to bf16, f32 accumulation.
    """
    E, Ka = a.shape
    Kb = b.shape[1]
    if precision == "bf16":
        a = a.astype(jnp.bfloat16)
        b = b.astype(jnp.bfloat16)
    contribs = (a[:, :, None] * b[:, None, :]).reshape(E, Ka * Kb)
    contribs = contribs.astype(jnp.float32)
    return jax.ops.segment_sum(contribs, rows, num_segments=num_rows)


def kron_segsum_oracle_ref(
    rows: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    num_rows: int,
    X: jnp.ndarray,  # (Ka*Kb, s)
    precision: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused build + first oracle product reference: (Z, Z @ X)."""
    Z = kron_segsum_ref(rows, a, b, num_rows, precision)
    return Z, Z @ X


def oracle_pair_ref(
    Z: jnp.ndarray,  # (R, Khat)
    x: jnp.ndarray,  # (Khat,) or (Khat, s) panel
    y: jnp.ndarray,  # (R,) or (R, s) panel
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The Lanczos oracle pair: (Z @ x, Z.T @ y) — one logical pass over Z."""
    return Z @ x, Z.T @ y
