"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel in kron_segsum.py / oracle_fused.py is numerically validated
against these functions in tests/test_kernels.py (shape & dtype sweeps,
interpret=True execution of the kernel body).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["kron_segsum_ref", "oracle_pair_ref"]


def kron_segsum_ref(
    rows: jnp.ndarray,  # (E,) int32 local row ids (dense-renumbered)
    a: jnp.ndarray,  # (E, Ka) float — element values folded in
    b: jnp.ndarray,  # (E, Kb) float
    num_rows: int,
) -> jnp.ndarray:
    """Z[r] = sum_{e: rows[e]=r} kron(a[e], b[e]) — the TTM hot loop.

    Returns (num_rows, Ka*Kb). C-order kron: b varies fastest.
    """
    E, Ka = a.shape
    Kb = b.shape[1]
    contribs = (a[:, :, None] * b[:, None, :]).reshape(E, Ka * Kb)
    return jax.ops.segment_sum(contribs, rows, num_segments=num_rows)


def oracle_pair_ref(
    Z: jnp.ndarray,  # (R, Khat)
    x: jnp.ndarray,  # (Khat,)
    y: jnp.ndarray,  # (R,)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The Lanczos oracle pair: (Z @ x, Z.T @ y) — one logical pass over Z."""
    return Z @ x, Z.T @ y
