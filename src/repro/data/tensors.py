"""Synthetic sparse tensor generators mirroring the paper's FROSTT benchmark.

The paper evaluates on delicious/enron/flickr/nell/amazon/patents/reddit — all
characterized by (i) a handful of modes (3–4), (ii) heavy-tailed slice-size
distributions (a few slices hold millions of elements — the reason CoarseG
collapses), and (iii) huge mode lengths. We generate scaled-down tensors with
the same qualitative structure:

  * mode coordinates drawn from Zipf-like distributions with per-mode exponent,
  * optional "hub" slices that concentrate a fixed fraction of elements
    (models enron's 5M-element slices out of 54M),
  * deduplicated coordinates, reproducible by seed.

``paper_suite()`` returns the suite used by benchmarks/run.py, scaled so HOOI
runs on CPU in seconds — shape ratios and skew are faithful; raw sizes are not.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.coo import SparseTensor

__all__ = ["synth_tensor", "paper_suite", "SUITE_SPECS"]


def _zipf_coords(rng, L: int, n: int, alpha: float) -> np.ndarray:
    """n samples in [0, L) with a Zipf(alpha)-shaped marginal (alpha=0: uniform)."""
    if alpha <= 0:
        return rng.integers(0, L, size=n)
    # inverse-CDF sampling over ranks 1..L with p(r) ~ r^-alpha; permuted so the
    # popular slices are in random positions (as in real data).
    ranks = np.arange(1, L + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = rng.random(n)
    idx = np.searchsorted(cdf, u, side="left")
    perm = rng.permutation(L)
    return perm[np.minimum(idx, L - 1)]


def synth_tensor(
    shape: tuple[int, ...],
    nnz: int,
    alphas: tuple[float, ...] | float = 1.0,
    hub_fraction: float = 0.0,
    hub_modes: tuple[int, ...] = (),
    seed: int = 0,
) -> SparseTensor:
    """Generate a random sparse tensor with skewed slices.

    hub_fraction: this fraction of elements is forced into a single random
    slice along each mode in hub_modes (creates the pathological large slices
    the paper discusses for CoarseG).
    """
    rng = np.random.default_rng(seed)
    N = len(shape)
    if isinstance(alphas, (int, float)):
        alphas = tuple(float(alphas) for _ in range(N))
    cols = [_zipf_coords(rng, shape[n], nnz, alphas[n]) for n in range(N)]
    coords = np.stack(cols, axis=1).astype(np.int64)
    if hub_fraction > 0 and hub_modes:
        k = int(nnz * hub_fraction)
        pick = rng.choice(nnz, size=k, replace=False)
        for m in hub_modes:
            coords[pick, m] = rng.integers(0, shape[m])
    values = rng.standard_normal(nnz)
    t = SparseTensor(coords, values, shape)
    return t.dedup()


@dataclasses.dataclass(frozen=True)
class SuiteSpec:
    name: str
    shape: tuple[int, ...]
    nnz: int
    alphas: tuple[float, ...]
    hub_fraction: float = 0.0
    hub_modes: tuple[int, ...] = ()
    mirror_of: str = ""  # which FROSTT tensor this is scaled from


# Scaled-down mirrors of the paper's Fig 9 suite (same mode-count and skew
# character; ~1e4–2e5 nnz so full HOOI benchmarks run on one CPU in seconds).
SUITE_SPECS: tuple[SuiteSpec, ...] = (
    SuiteSpec("delicious-s", (530, 17000, 2400, 140), 140_000, (1.1, 1.3, 1.2, 0.9),
              mirror_of="delicious"),
    SuiteSpec("enron-s", (600, 500, 2400, 100), 54_000, (1.4, 1.4, 1.1, 0.8),
              hub_fraction=0.09, hub_modes=(0,), mirror_of="enron"),
    SuiteSpec("flickr-s", (320, 28000, 1600, 73), 112_000, (1.2, 1.4, 1.2, 0.7),
              mirror_of="flickr"),
    SuiteSpec("nell1-s", (2900, 2100, 25000), 143_000, (1.2, 1.2, 1.4),
              mirror_of="nell1"),
    SuiteSpec("nell2-s", (1200, 900, 2800), 77_000, (0.9, 0.9, 1.0),
              mirror_of="nell2"),
    # "big" mirrors: denser, very large hub slices (amazon/patents/reddit)
    SuiteSpec("amazon-s", (4800, 1700, 1800), 170_000, (1.0, 1.1, 1.1),
              hub_fraction=0.05, hub_modes=(0,), mirror_of="amazon"),
    SuiteSpec("patents-s", (46, 2390, 239), 200_000, (0.4, 1.0, 0.5),
              mirror_of="patents"),
    SuiteSpec("reddit-s", (8200, 1760, 8100), 230_000, (1.3, 0.9, 1.3),
              hub_fraction=0.04, hub_modes=(1,), mirror_of="reddit"),
)


def paper_suite(scale: float = 1.0, seed: int = 0) -> dict[str, SparseTensor]:
    """Instantiate the synthetic suite; ``scale`` multiplies nnz."""
    out = {}
    for i, s in enumerate(SUITE_SPECS):
        out[s.name] = synth_tensor(
            s.shape,
            max(1000, int(s.nnz * scale)),
            s.alphas,
            hub_fraction=s.hub_fraction,
            hub_modes=s.hub_modes,
            seed=seed + i,
        )
    return out
