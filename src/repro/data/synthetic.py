"""Deterministic synthetic LM data pipeline.

Hash-based token stream: batch ``i`` is a pure function of (seed, step,
shard), so the pipeline state is a single integer — checkpointing the data
pipeline is O(1) and resume is exact regardless of mesh shape (elastic
restarts keep sample order). Shardable: each data-parallel group draws its
slice of the global batch by global example id.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticStream", "make_global_batch_specs"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_embed_stub: int = 0  # modality-stub embedding positions
    d_model: int = 0


class SyntheticStream:
    """Stateless-function data source with an integer cursor."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = int(step)

    # ------------------------------------------------------------- state
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, st: dict) -> None:
        assert st["seed"] == self.cfg.seed, "stream seed mismatch"
        self.step = int(st["step"])

    # ------------------------------------------------------------- batch
    def next_batch(self) -> dict:
        """Host-side numpy batch (converted/sharded by the caller)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, self.step]))
        n_tok = cfg.seq_len - cfg.n_embed_stub
        tokens = rng.integers(
            0, cfg.vocab, size=(cfg.global_batch, n_tok), dtype=np.int32)
        # next-token objective with a drifting motif so loss is learnable
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = tokens[:, 0]
        batch = {"tokens": tokens, "labels": labels}
        if cfg.n_embed_stub:
            # modality stub: deterministic pseudo-embeddings
            batch["embeds"] = rng.standard_normal(
                (cfg.global_batch, cfg.n_embed_stub, cfg.d_model)
            ).astype(np.float32)
        self.step += 1
        return batch


def make_global_batch_specs(cfg: DataConfig, vocab: int,
                            dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct stand-ins matching next_batch() (for dry-runs)."""
    n_tok = cfg.seq_len - cfg.n_embed_stub
    specs = {
        "tokens": jax.ShapeDtypeStruct((cfg.global_batch, n_tok), jnp.int32),
        "labels": jax.ShapeDtypeStruct((cfg.global_batch, n_tok), jnp.int32),
    }
    if cfg.n_embed_stub:
        specs["embeds"] = jax.ShapeDtypeStruct(
            (cfg.global_batch, cfg.n_embed_stub, cfg.d_model), jnp.float32)
    return specs
