"""FROSTT-style ``.tns`` ingestion: one-shot loads and streamed batches.

The paper's evaluation tensors (delicious, enron, nell, ...) are published
by FROSTT as ``.tns`` text files — one element per line, 1-based
coordinates followed by the value, ``#``/``%`` comment lines allowed. This
module is the real-dataset front door of the data layer:

* ``load_tns`` — whole-file read into a ``SparseTensor`` (thin superset of
  ``repro.core.coo.read_tns``: an explicit ``shape`` pins the dense extent
  instead of inferring it from the max coordinate, which matters when a
  file's trailing slices happen to be empty).
* ``iter_tns_batches`` — a generator of bounded COO batches that never
  materializes the whole file, for feeding ingest pipelines.
* ``stream_tns`` — builds a ``StreamingTensor`` by appending those batches
  in file order. With ``shape=None`` it makes an extra streaming pass first
  to infer the extent (a ``StreamingTensor``'s shape is fixed at birth —
  appends may never grow it). The result drops straight into
  ``StreamScheduler.submit``: each appended batch replays the refresh
  ladder exactly as a synthetic stream would, which is how the
  ``bench_objectives`` benchmark runs masked completion end-to-end over a
  real-format dataset.

Values are kept as written (float64). Duplicate coordinates are preserved —
under streaming semantics they are additive value updates.
"""

from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.core.coo import SparseTensor
from repro.streaming import StreamingTensor

__all__ = ["load_tns", "iter_tns_batches", "stream_tns"]

_COMMENTS = ("#", "%")


def _parse_lines(lines, ndim: int | None):
    """Parse text lines -> (coords 0-based, values, ndim); skips comments."""
    coords, values = [], []
    for line in lines:
        s = line.strip()
        if not s or s.startswith(_COMMENTS):
            continue
        parts = s.split()
        if ndim is None:
            ndim = len(parts) - 1
            if ndim < 1:
                raise ValueError(
                    f"a .tns line needs >= 1 coordinate plus a value, "
                    f"got {s!r}")
        if len(parts) != ndim + 1:
            raise ValueError(
                f"inconsistent .tns line (expected {ndim} coords + value): "
                f"{s!r}")
        coords.append([int(p) for p in parts[:ndim]])
        values.append(float(parts[ndim]))
    if not coords:
        return np.zeros((0, ndim or 0), np.int64), np.zeros(0), ndim
    c = np.asarray(coords, dtype=np.int64)
    if c.min() < 1:
        raise ValueError(".tns coordinates are 1-based; got a coordinate "
                         f"{int(c.min())}")
    return c - 1, np.asarray(values, dtype=np.float64), ndim


def load_tns(path, shape: tuple[int, ...] | None = None) -> SparseTensor:
    """Read a whole ``.tns`` file into a ``SparseTensor``.

    ``shape`` pins the dense extent (validated against the data); ``None``
    infers it as the per-mode max coordinate, matching ``read_tns``.
    """
    with open(path) as f:
        coords, values, ndim = _parse_lines(f, None)
    if ndim is None:
        raise ValueError(f"{path}: no elements found")
    if shape is None:
        shape = tuple(int(coords[:, n].max()) + 1 for n in range(ndim))
    else:
        shape = tuple(int(L) for L in shape)
        if len(shape) != ndim:
            raise ValueError(
                f"shape has {len(shape)} modes, file has {ndim}")
    return SparseTensor(coords, values, shape)


def iter_tns_batches(path, batch_nnz: int = 100_000
                     ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield ``(coords, values)`` batches of at most ``batch_nnz`` elements.

    Streams the file line-by-line (bounded memory); coordinates come out
    0-based, file order preserved across batches.
    """
    if batch_nnz < 1:
        raise ValueError(f"batch_nnz must be >= 1, got {batch_nnz}")
    ndim = None
    pending: list[str] = []
    with open(path) as f:
        for line in f:
            s = line.strip()
            if not s or s.startswith(_COMMENTS):
                continue
            pending.append(s)
            if len(pending) >= batch_nnz:
                coords, values, ndim = _parse_lines(pending, ndim)
                pending.clear()
                yield coords, values
    if pending:
        coords, values, _ = _parse_lines(pending, ndim)
        yield coords, values


def stream_tns(path, batch_nnz: int = 100_000,
               shape: tuple[int, ...] | None = None,
               name: str | None = None) -> StreamingTensor:
    """Materialize a ``.tns`` file as a ``StreamingTensor``, batch by batch.

    With ``shape=None`` an extra pass over the file infers the dense extent
    first (a stream's shape is fixed at construction). Each subsequent
    batch is one ``append`` — a scheduler consuming the returned stream
    sees the same version-by-version growth a live ingest would produce.
    """
    if shape is None:
        hi = None
        for coords, _ in iter_tns_batches(path, batch_nnz):
            if len(coords) == 0:
                continue
            m = coords.max(axis=0)
            hi = m if hi is None else np.maximum(hi, m)
        if hi is None:
            raise ValueError(f"{path}: no elements found")
        shape = tuple(int(x) + 1 for x in hi)
    if name is None:
        name = str(path)
    stream = StreamingTensor(shape, name=name)
    for coords, values in iter_tns_batches(path, batch_nnz):
        stream.append(coords, values)
    return stream
