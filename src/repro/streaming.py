"""Streaming sparse tensors: incremental COO appends + chain fingerprints.

The paper's distribution step is cheap enough to run in real time; the
streaming layer makes that pay off for *evolving* tensors (the SGD_Tucker
serving regime: ratings/interactions arriving in batches, each batch
followed by a re-decomposition). A ``StreamingTensor`` accumulates COO
batches and answers, in O(batch) rather than O(nnz):

  * **fingerprint** — a hash chain ``fp_{k+1} = H(fp_k || batch_k)``. Two
    streams that saw the same append history share a fingerprint, so the
    plan cache (``repro.core.plan``) and the executor's upload cache keep
    working across snapshots without re-hashing the full tensor. Distinct
    histories of equal content hash differently — a conservative cache
    miss, never a false hit.

  * **per-mode slice histograms** — maintained incrementally; the raw
    material of the paper's §4 metrics, exposed (``slice_hist``) for
    external monitoring of a stream's shape. The scheduler's invalidation
    predicate does *not* read them — it projects the snapshot's appended
    coordinates onto the adopted plan's slice->rank owner maps instead
    (``repro.engine.scheduler``).

``snapshot()`` materializes the current state as an ordinary
``SparseTensor`` whose memoized fingerprint is *pre-set* to the chain value
and which carries the stream version (``_stream_version``) — downstream
plan construction and persistence record which version of the stream a
plan describes.

Element semantics are plain COO: appending a coordinate that already
exists adds a second element with the same coordinate, which every
*linear* consumer (partitioning, TTM scatter-adds, the core build) treats
additively — i.e. duplicate appends are *value updates*. That is exactly
the distribution-preserving append the scheduler's "keep the plan" fast
path is built for. The one non-linear quantity, ||T||_F^2 (the fit
denominator: sum of *accumulated* values squared, not of element values
squared), is maintained incrementally per unique coordinate and attached
to snapshots as ``_true_norm2`` — ``fit_score`` prefers it, so fits
reported for streamed value updates stay exact.
"""

from __future__ import annotations

import hashlib
import threading

import numpy as np

from repro.core.coo import SparseTensor

__all__ = ["StreamingTensor"]


class StreamingTensor:
    """Append-only COO stream over a fixed dense shape.

    Thread-safety: ``append`` and the read methods take an internal lock, so
    a producer thread can append while scheduler workers snapshot. The
    *scheduling* of concurrent appends vs. submits is the scheduler's
    contract (see ``repro.engine.scheduler``).
    """

    def __init__(self, shape, *, name: str = "stream"):
        self.shape = tuple(int(L) for L in shape)
        if not self.shape or any(L <= 0 for L in self.shape):
            raise ValueError(f"invalid shape {shape!r}")
        self.name = str(name)
        self._lock = threading.RLock()
        self._coords: list[np.ndarray] = []  # one (batch, N) array per append
        self._values: list[np.ndarray] = []
        self._version = 0
        h = hashlib.sha1()
        h.update(b"stream:")
        h.update(repr(self.shape).encode())
        self._fp = h.hexdigest()
        self._hists = [np.zeros(L, dtype=np.int64) for L in self.shape]
        # accumulated value per unique coordinate (raveled) and the true
        # ||T||^2 = sum of accumulated values squared — one float per
        # distinct nonzero, same order of memory as the stream itself
        self._acc: dict[int, float] = {}
        self._norm2 = 0.0
        self._snapshot: SparseTensor | None = None

    @classmethod
    def from_tensor(cls, t: SparseTensor, *, name: str = "stream"
                    ) -> "StreamingTensor":
        """Seed a stream with an existing tensor as its first batch."""
        s = cls(t.shape, name=name)
        s.append(t.coords, t.values)
        return s

    # ------------------------------------------------------------- queries
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def nnz(self) -> int:
        with self._lock:
            return sum(int(c.shape[0]) for c in self._coords)

    @property
    def version(self) -> int:
        """Number of appended batches so far (0 = empty stream)."""
        with self._lock:
            return self._version

    def fingerprint(self) -> str:
        """Chain fingerprint of the append history (O(1) read)."""
        with self._lock:
            return self._fp

    def slice_hist(self, mode: int) -> np.ndarray:
        """|Slice_mode^l| for every l — maintained incrementally."""
        with self._lock:
            return self._hists[mode].copy()

    # -------------------------------------------------------------- ingest
    def append(self, coords, values) -> int:
        """Append one COO batch; returns the new stream version.

        Coordinates must lie inside ``shape`` (streaming never grows the
        dense extent — a mode-length change is a different tensor and a
        different stream). Duplicate coordinates are additive updates.

        An empty batch is a no-op: version and fingerprint are unchanged,
        so a serving loop that flushes on a timer keeps hitting the
        scheduler's zero-cost ``reuse`` path when nothing arrived.
        """
        coords = np.ascontiguousarray(np.asarray(coords, dtype=np.int64))
        values = np.ascontiguousarray(
            np.asarray(values, dtype=np.float64).ravel())
        if coords.ndim != 2 or coords.shape[1] != self.ndim:
            raise ValueError(
                f"coords must be (batch, {self.ndim}), got {coords.shape}")
        if values.shape[0] != coords.shape[0]:
            raise ValueError(
                f"{values.shape[0]} values for {coords.shape[0]} coords")
        if coords.shape[0] == 0:
            with self._lock:
                return self._version
        if coords.min() < 0:
            raise ValueError("coordinates must be non-negative")
        for n, L in enumerate(self.shape):
            hi = int(coords[:, n].max())
            if hi >= L:
                raise ValueError(
                    f"mode-{n} coordinate {hi} out of bounds for "
                    f"length {L}")
        with self._lock:
            self._coords.append(coords)
            self._values.append(values)
            self._version += 1
            h = hashlib.sha1()
            h.update(self._fp.encode())
            h.update(coords.tobytes())
            h.update(values.tobytes())
            self._fp = h.hexdigest()
            for n in range(self.ndim):
                self._hists[n] += np.bincount(
                    coords[:, n], minlength=self.shape[n])
            # duplicate-aware norm update: ||T||^2 changes by
            # (old+delta)^2 - old^2 per *unique* coordinate touched
            flat = np.ravel_multi_index(tuple(coords.T), self.shape)
            uniq, inv = np.unique(flat, return_inverse=True)
            deltas = np.zeros(len(uniq))
            np.add.at(deltas, inv, values)
            olds = np.fromiter(
                (self._acc.get(int(c), 0.0) for c in uniq),
                dtype=np.float64, count=len(uniq))
            news = olds + deltas
            self._norm2 += float(np.sum(news * news - olds * olds))
            self._acc.update(zip(uniq.tolist(), news.tolist()))
            self._snapshot = None
            return self._version

    def coords_since(self, version: int) -> np.ndarray:
        """Coordinates appended after ``version`` (concatenated, in order).

        Convenience for external consumers tracking a stream against a
        known version. Note the scheduler does NOT read the live stream
        for its invalidation input — it slices its own snapshot
        (``t.coords[len(policy):]``) so a racing append can never produce
        a policy extension longer than the tensor it extends.
        """
        with self._lock:
            if not 0 <= version <= self._version:
                raise ValueError(
                    f"version {version} outside [0, {self._version}]")
            chunks = self._coords[version:]
            if not chunks:
                return np.zeros((0, self.ndim), dtype=np.int64)
            return np.concatenate(chunks, axis=0)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> SparseTensor:
        """The stream's current state as a ``SparseTensor``.

        The snapshot's memoized fingerprint is the chain fingerprint (so
        repeated snapshots at one version hit the same plan-cache entry)
        and it carries ``_stream_version`` for plan provenance. Cached
        until the next append.
        """
        with self._lock:
            if self._snapshot is not None:
                return self._snapshot
            if self._coords:
                coords = np.concatenate(self._coords, axis=0)
                values = np.concatenate(self._values, axis=0)
            else:
                coords = np.zeros((0, self.ndim), dtype=np.int64)
                values = np.zeros(0, dtype=np.float64)
            t = SparseTensor(coords, values, self.shape)
            object.__setattr__(t, "_fingerprint", self._fp)
            object.__setattr__(t, "_stream_version", self._version)
            # duplicates make sum(values**2) != ||T||^2; hand consumers
            # the maintained true norm (fit_score prefers it)
            object.__setattr__(t, "_true_norm2", max(self._norm2, 0.0))
            self._snapshot = t
            return t
