"""One home for every ``REPRO_*`` environment knob.

Historically each knob was parsed where it was consumed (`engine/zbuild.py`,
`engine/oracle.py`, `kernels/ops.py`), with slightly different tolerance
for malformed values. This module centralizes them behind *validated*
parsers: an unset / empty variable means "no override" (``None`` or
``False``), and any malformed value raises ``ValueError`` naming the
variable — a typo'd CI leg fails loudly instead of silently running the
wrong configuration. Consumers keep their historical entry points
(``resolve_precision`` etc.) and delegate the env step here.

| variable              | values                    | consumed by            |
| --------------------- | ------------------------- | ---------------------- |
| ``REPRO_FORCE_KERNEL``  | ``0``/``1``               | ``engine/zbuild.py``   |
| ``REPRO_FUSED_ZBUILD``  | ``0``/``1``               | ``engine/zbuild.py``   |
| ``REPRO_PRECISION``     | ``f32``/``bf16``          | ``engine/zbuild.py``   |
| ``REPRO_LANCZOS_BLOCK`` | int >= 1                  | ``engine/oracle.py``   |
| ``REPRO_VMEM_BUDGET``   | bytes, int > 0            | ``kernels/ops.py``     |
| ``REPRO_OBJECTIVE``     | ``tucker``/``completion``/``nn`` | ``engine/objective.py`` |
| ``REPRO_WARM_START``    | ``none``/``sketch``/``auto`` | ``engine/oracle.py``   |
| ``REPRO_SAMPLE_FRACTION`` | float in (0, 1]         | ``engine/scheduler.py`` |
"""

from __future__ import annotations

import os

__all__ = ["PRECISIONS", "OBJECTIVES", "WARM_STARTS", "KNOBS", "env_flag",
           "force_kernel", "fused_zbuild", "precision", "lanczos_block",
           "vmem_budget", "objective", "warm_start", "sample_fraction",
           "snapshot"]

PRECISIONS = ("f32", "bf16")
OBJECTIVES = ("tucker", "completion", "nn")
WARM_STARTS = ("none", "sketch", "auto")


def _raw(name: str) -> str:
    return os.environ.get(name, "").strip()


def env_flag(name: str) -> bool:
    """Parse a 0/1 switch; unset/empty and ``0`` are False, ``1`` is True."""
    raw = _raw(name)
    if raw in ("", "0"):
        return False
    if raw == "1":
        return True
    raise ValueError(f"{name} must be '0' or '1', got {raw!r}")


def force_kernel() -> bool:
    """``REPRO_FORCE_KERNEL=1``: auto kernel resolution engages the
    (interpret-mode, off-TPU) kernel wherever the VMEM gate admits it."""
    return env_flag("REPRO_FORCE_KERNEL")


def fused_zbuild() -> bool:
    """``REPRO_FUSED_ZBUILD=1``: default the fused Z-build→oracle pipeline
    on when the caller passes ``fused_zbuild=None``."""
    return env_flag("REPRO_FUSED_ZBUILD")


def precision() -> str | None:
    """``REPRO_PRECISION``: Z-build precision override, or None if unset."""
    raw = _raw("REPRO_PRECISION")
    if not raw:
        return None
    if raw not in PRECISIONS:
        raise ValueError(
            f"REPRO_PRECISION must be one of {PRECISIONS}, got {raw!r}")
    return raw


def lanczos_block() -> int | None:
    """``REPRO_LANCZOS_BLOCK``: requested Lanczos panel width, or None."""
    raw = _raw("REPRO_LANCZOS_BLOCK")
    if not raw:
        return None
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(
            f"REPRO_LANCZOS_BLOCK must be an integer >= 1, got {raw!r}"
        ) from None
    if value < 1:
        raise ValueError(f"REPRO_LANCZOS_BLOCK must be >= 1, got {value}")
    return value


def vmem_budget() -> int | None:
    """``REPRO_VMEM_BUDGET``: kernel tile admission budget in bytes, or
    None (consumers fall back to their conservative default)."""
    raw = _raw("REPRO_VMEM_BUDGET")
    if not raw:
        return None
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(
            f"REPRO_VMEM_BUDGET must be a positive integer (bytes), "
            f"got {raw!r}") from None
    if value <= 0:
        raise ValueError(f"REPRO_VMEM_BUDGET must be positive, got {value}")
    return value


def objective() -> str | None:
    """``REPRO_OBJECTIVE``: default sweep objective name, or None."""
    raw = _raw("REPRO_OBJECTIVE")
    if not raw:
        return None
    if raw not in OBJECTIVES:
        raise ValueError(
            f"REPRO_OBJECTIVE must be one of {OBJECTIVES}, got {raw!r}")
    return raw


def warm_start() -> str | None:
    """``REPRO_WARM_START``: default oracle warm-start mode, or None."""
    raw = _raw("REPRO_WARM_START")
    if not raw:
        return None
    if raw not in WARM_STARTS:
        raise ValueError(
            f"REPRO_WARM_START must be one of {WARM_STARTS}, got {raw!r}")
    return raw


def sample_fraction() -> float | None:
    """``REPRO_SAMPLE_FRACTION``: default stochastic-refine sample
    fraction for the streaming scheduler, or None (rung disabled)."""
    raw = _raw("REPRO_SAMPLE_FRACTION")
    if not raw:
        return None
    try:
        value = float(raw)
    except ValueError:
        raise ValueError(
            f"REPRO_SAMPLE_FRACTION must be a float in (0, 1], "
            f"got {raw!r}") from None
    if not 0.0 < value <= 1.0:
        raise ValueError(
            f"REPRO_SAMPLE_FRACTION must be in (0, 1], got {value}")
    return value


# the registry: variable name -> zero-arg validated parser
KNOBS = {
    "REPRO_FORCE_KERNEL": force_kernel,
    "REPRO_FUSED_ZBUILD": fused_zbuild,
    "REPRO_PRECISION": precision,
    "REPRO_LANCZOS_BLOCK": lanczos_block,
    "REPRO_VMEM_BUDGET": vmem_budget,
    "REPRO_OBJECTIVE": objective,
    "REPRO_WARM_START": warm_start,
    "REPRO_SAMPLE_FRACTION": sample_fraction,
}


def snapshot() -> dict[str, object]:
    """Resolved value of every knob — provenance stamping for benches."""
    return {name: parse() for name, parse in KNOBS.items()}
