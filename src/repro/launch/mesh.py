"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init;
smoke tests and benchmarks must keep seeing 1 device.
"""

from __future__ import annotations

from repro.jax_compat import make_mesh_auto

__all__ = ["make_production_mesh", "mesh_axes", "dp_axes", "TP_AXIS"]

TP_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel (and FSDP) axes: everything except the TP axis."""
    return tuple(a for a in mesh.axis_names if a != TP_AXIS)
