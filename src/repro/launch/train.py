"""End-to-end training driver (CLI).

Production behaviors demonstrated end-to-end on any device count:
  * pjit with explicit 2-D param sharding (FSDP x TP) from sharding.py,
  * deterministic restartable data pipeline,
  * periodic atomic checkpoints + automatic resume (fault tolerance),
  * per-step watchdog flagging stragglers (steps slower than k x median),
  * optional Tucker/PowerSGD gradient compression on the slow axis,
  * optional failure injection (--fail-at) to exercise checkpoint/restart.

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck --ckpt-every 20
"""

from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.jax_compat import make_mesh_auto
from repro.data.synthetic import DataConfig, SyntheticStream
from repro.launch import sharding as shr
from repro.train import train_step as ts
from repro.train.grad_compress import CompressConfig
from repro.train.optimizer import AdamWConfig


def make_data_mesh():
    """Mesh over whatever devices exist: (data,) x (model=1)."""
    n = len(jax.devices())
    return make_mesh_auto((n, 1), ("data", "model"))


def train_main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-rank", type=int, default=0,
                    help=">0 enables low-rank grad compression")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a crash at this step (tests restart)")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_data_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=min(20, args.steps // 10 + 1))
    compress = (CompressConfig(rank=args.compress_rank, min_size=4096)
                if args.compress_rank > 0 else None)
    hint = shr.make_hint_fn(mesh)
    step_fn = ts.make_train_step(cfg, opt_cfg, microbatches=args.microbatches,
                                 remat=False, compress=compress, hint=hint)

    n_stub = 16 if cfg.frontend in ("audio", "vision") else 0
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed,
                      n_embed_stub=n_stub, d_model=cfg.d_model)
    stream = SyntheticStream(dcfg)

    key = jax.random.PRNGKey(args.seed)
    state = ts.make_train_state(cfg, key, compress=compress is not None)
    start_step = 0

    # ---- resume if a checkpoint exists
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tmpl = {"state": jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)}
        restored, start_step = ckpt.restore_checkpoint(args.ckpt_dir, tmpl)
        state = jax.tree.unflatten(jax.tree.structure(state),
                                   jax.tree.leaves(restored["state"]))
        stream.load_state_dict(restored["meta"]["data"])
        print(f"[train] resumed from step {start_step}")

    state_sh = shr.state_shardings(mesh, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, state_sh)
    batch_sh_cache = {}
    with mesh:
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        losses, times = [], []
        for step in range(start_step, args.steps):
            if step == args.fail_at:
                raise RuntimeError(f"[train] injected failure at step {step}")
            hb = stream.next_batch()
            batch = {}
            for k, v in hb.items():
                if k not in batch_sh_cache:
                    spec = shr.batch_specs(mesh, {k: v})[k]
                    batch_sh_cache[k] = NamedSharding(mesh, spec)
                batch[k] = jax.device_put(jnp.asarray(v), batch_sh_cache[k])
            t0 = time.perf_counter()
            state, metrics = jit_step(state, batch,
                                      jax.random.fold_in(key, step))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            losses.append(loss)
            times.append(dt)
            # ---- straggler watchdog
            if len(times) > 8:
                med = statistics.median(times[-32:])
                if dt > args.straggler_factor * med:
                    print(f"[train][watchdog] step {step} took {dt:.3f}s "
                          f"(median {med:.3f}s) — straggler; at scale this "
                          "triggers drain/replace of the slow host")
            if step % args.log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save_checkpoint(
                    args.ckpt_dir, step + 1,
                    {"state": state,
                     "meta": {"data": stream.state_dict(),
                              "arch": cfg.name}})
                ckpt.cleanup_old(args.ckpt_dir, keep=3)

    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"first_loss": losses[0], "last_loss": losses[-1],
            "steps": len(losses)}


if __name__ == "__main__":
    train_main()
