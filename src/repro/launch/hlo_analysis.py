"""Trip-count-aware HLO analysis for the roofline.

XLA's ``compiled.cost_analysis()`` sums over the HLO *text*, so the body of a
``while`` loop (every ``jax.lax.scan`` — our layer stacks!) is counted ONCE
instead of trip-count times. Same for collective ops inside loops. This
module parses the (post-SPMD-partitioning) HLO text into computations,
extracts per-computation

  * dot FLOPs (2 x numel(result) x contracted-dim product),
  * collective operand bytes by kind,
  * fusion-boundary traffic (sum of operand+result bytes of top-level ops —
    an HBM-traffic proxy at fusion granularity),

recovers each while loop's trip count from its condition computation
(``compare(i, constant)``), and aggregates recursively:

    total(comp) = flat(comp) + sum_while trip x total(body) (+cond)

Also handles ``call``/fusion-referenced computations. Conservative: unknown
trip counts default to 1 (reported).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# "%name = type[dims]{layout} op-name(...)" (possibly tuple-typed)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_numel(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str  # everything after '('


@dataclasses.dataclass
class HloStats:
    flops: float
    collective_bytes: dict
    traffic_bytes: float
    unknown_trip_loops: int

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _split_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    cur_name = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "{" in line:
            cur_name = hdr.group(1)
            cur = []
            comps[cur_name] = cur
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.append(_Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _dot_flops(ins: _Instr, symbols: dict[str, str]) -> float:
    """2 * numel(result) * prod(lhs contracting dims)."""
    out_n = _shape_numel(ins.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    args = re.findall(r"%([\w\.\-]+)", ins.rest)
    if not args:
        return 0.0
    lhs_type = symbols.get(args[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 0.0
    dims = [int(d) for d in sm.group(2).split(",") if d]
    contracted = 1
    if m and m.group(1):
        for ci in m.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                contracted *= dims[ci]
    return 2.0 * out_n * contracted


def _trip_count(cond_instrs: list[_Instr]) -> int | None:
    """jax scans lower to `while(cond: i < C)`; find C.

    Post-fusion the compare often hides inside a called fusion computation,
    with C passed in from the condition region — so: if the condition region
    holds any integer constants, the loop bound is the largest one (index
    seeds are 0/1; the bound is the scan length)."""
    consts = []
    for ins in cond_instrs:
        cm = re.search(r"constant\((\d+)\)", ins.op + "(" + ins.rest)
        if cm:
            consts.append(int(cm.group(1)))
        # direct compare against a literal constant operand
        if ins.op == "compare":
            for lit in re.findall(r"constant\((\d+)\)", ins.rest):
                consts.append(int(lit))
    return max(consts) if consts else None


_META_OPS = ("tuple", "get-tuple-element", "parameter", "bitcast",
             "constant", "iota", "while", "conditional", "call",
             "after-all", "partition-id")


def analyze_hlo(text: str) -> HloStats:
    comps = _split_computations(text)
    symbol_types = {c: {i.name: i.type_str for i in instrs}
                    for c, instrs in comps.items()}

    flat_flops: dict[str, float] = {}
    flat_coll: dict[str, dict] = {}
    flat_traffic: dict[str, float] = {}
    children: dict[str, list[tuple[str, int]]] = defaultdict(list)
    unknown = 0

    # ---- pass 1: flops, collectives, loop structure -----------------------
    for cname, instrs in comps.items():
        fl = 0.0
        coll: dict[str, float] = defaultdict(float)
        syms = symbol_types[cname]
        for ins in instrs:
            if ins.op in ("dot", "convolution"):
                fl += _dot_flops(ins, syms)
            base_op = ins.op.replace("-start", "")
            if base_op in _COLLECTIVES:
                args = re.findall(r"%([\w\.\-]+)", ins.rest)
                b = sum(_shape_bytes(syms.get(a, "")) for a in args
                        if a in syms)
                if b == 0:
                    b = _shape_bytes(ins.type_str)
                coll[base_op] += b
            if ins.op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                trip = None
                if cm and cm.group(1) in comps:
                    trip = _trip_count(comps[cm.group(1)])
                if trip is None:
                    trip = 1
                    unknown += 1
                if bm:
                    children[cname].append((bm.group(1), trip, "while"))
            else:
                # fusion/call-referenced computations can hold dots and
                # collectives; count once per call site.
                for key in ("calls=", "to_apply="):
                    fm = re.search(key + r"%?([\w\.\-]+)", ins.rest)
                    if fm and fm.group(1) in comps:
                        children[cname].append((fm.group(1), 1, "call"))
        flat_flops[cname] = fl
        flat_coll[cname] = dict(coll)

    # which while bodies contain nested while loops?
    bodies = {c for kids in children.values() for c, _, k in kids
              if k == "while"}

    def has_nested_while(cname, depth=0) -> bool:
        if depth > 50:
            return False
        for child, _t, kind in children.get(cname, []):
            if kind == "while":
                return True
            if has_nested_while(child, depth + 1):
                return True
        return False

    # ---- pass 2: HBM traffic model ----------------------------------------
    # Every produced tensor counted once (result bytes); big-buffer reads
    # come through dynamic-slice/gather results or entry parameters; DUS/
    # scatter charge 2x the update slice. *Leaf* while bodies (no nested
    # loops) model a fused TPU kernel: their intermediates live in VMEM, so
    # only the loop-carried root tuple, sliced reads and collectives count.
    for cname, instrs in comps.items():
        syms = symbol_types[cname]
        leaf_kernel = cname in bodies and not has_nested_while(cname)
        traffic = 0.0
        for ins in instrs:
            b_res = _shape_bytes(ins.type_str)
            if ins.op in ("dynamic-update-slice", "scatter"):
                args = re.findall(r"%([\w\.\-]+)", ins.rest)
                upd = args[1] if len(args) > 1 else None
                traffic += 2 * _shape_bytes(syms.get(upd, "")) if upd else 0
            elif ins.op in ("dynamic-slice", "gather"):
                traffic += b_res
            elif ins.op == "parameter" and cname.startswith("main"):
                traffic += b_res  # weight/arg reads
            elif leaf_kernel:
                # VMEM-resident intermediate of a kernel-like loop body;
                # the loop carry also stays resident across iterations.
                continue
            elif ins.op not in _META_OPS:
                traffic += b_res
        flat_traffic[cname] = traffic

    # recursive aggregation with memoization
    memo: dict[str, tuple[float, dict, float]] = {}

    def total(cname: str, depth=0) -> tuple[float, dict, float]:
        if cname in memo:
            return memo[cname]
        if depth > 50:
            return (0.0, {}, 0.0)
        fl = flat_flops.get(cname, 0.0)
        coll = dict(flat_coll.get(cname, {}))
        tr = flat_traffic.get(cname, 0.0)
        for child, trip, kind in children.get(cname, []):
            cf, cc, ct = total(child, depth + 1)
            fl += trip * cf
            if kind == "while":  # call/fusion traffic already at boundary
                tr += trip * ct
            for k, v in cc.items():
                coll[k] = coll.get(k, 0.0) + trip * v
        memo[cname] = (fl, coll, tr)
        return memo[cname]

    # entry computation: the one not referenced as a child/body
    referenced = {c for kids in children.values() for c, _, _ in kids}
    entries = [c for c in comps
               if c not in referenced and (flat_flops[c] or children.get(c))]
    # prefer a computation literally marked ENTRY in the text
    em = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    entry = em.group(1) if em and em.group(1) in comps else (
        entries[0] if entries else next(iter(comps), None))
    if entry is None:
        return HloStats(0.0, {}, 0.0, unknown)
    fl, coll, tr = total(entry)
    return HloStats(flops=fl, collective_bytes=coll, traffic_bytes=tr,
                    unknown_trip_loops=unknown)
