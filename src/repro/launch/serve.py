"""Batched serving driver: continuous-batching style prefill + decode.

Demonstrates the serving path end-to-end on CPU with a smoke config:
  * prefill builds the KV cache for a batch of prompts (token-by-token via
    the decode path — the prefill *step* itself is what the dry-run lowers),
  * decode loop emits tokens for the whole batch each step,
  * simple continuous batching: finished sequences are replaced by queued
    requests mid-flight (slot recycling), the metric that matters at scale.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
      --requests 8 --batch 4 --gen-len 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.train import train_step as ts


def serve_main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4, help="decode slots")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(cfg, key)
    decode = jax.jit(ts.make_decode_step(cfg))

    rng = np.random.default_rng(args.seed)
    queue = [rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
             for _ in range(args.requests)]
    results: list[list[int]] = []

    B = args.batch
    cache = tfm.init_cache(cfg, B, args.s_max, dtype=jnp.float32)
    slot_pos = np.zeros(B, np.int32)  # next position per slot
    slot_req: list[int | None] = [None] * B
    slot_out: list[list[int]] = [[] for _ in range(B)]
    cur_tok = jnp.zeros((B, 1), jnp.int32)
    served = 0
    t0 = time.perf_counter()
    steps = 0

    def feed_slot(s: int, cache, cur_tok):
        """Prefill a queued request into slot s via the decode path."""
        nonlocal served
        req = queue.pop(0)
        slot_req[s] = served
        served += 1
        slot_out[s] = []
        # NOTE: single-slot prefill via decode steps; a production server
        # batches prefill separately (the prefill_32k dry-run cell).
        for t, tok in enumerate(req):
            one = jnp.zeros((B, 1), jnp.int32).at[s, 0].set(int(tok))
            _next, cache = decode(params, cache, one, jnp.int32(t))
        slot_pos[s] = len(req)
        cur_tok = cur_tok.at[s, 0].set(int(req[-1]))
        return cache, cur_tok

    for s in range(B):
        if queue:
            cache, cur_tok = feed_slot(s, cache, cur_tok)

    while any(r is not None for r in slot_req):
        pos = int(slot_pos.max())  # homogeneous-position decode (simplest)
        nxt, cache = decode(params, cache, cur_tok, jnp.int32(pos))
        steps += 1
        nxt_np = np.asarray(nxt)[:, 0]
        for s in range(B):
            if slot_req[s] is None:
                continue
            slot_out[s].append(int(nxt_np[s]))
            slot_pos[s] += 1
            if len(slot_out[s]) >= args.gen_len:
                results.append(slot_out[s])
                slot_req[s] = None
                if queue and slot_pos.max() < args.s_max - args.prompt_len - args.gen_len:
                    cache, cur_tok = feed_slot(s, cache, cur_tok)
        cur_tok = nxt

    dt = time.perf_counter() - t0
    tput = sum(len(r) for r in results) / dt
    print(f"[serve] {len(results)} requests, {steps} decode steps, "
          f"{tput:.1f} tok/s")
    return {"completed": len(results), "decode_steps": steps,
            "tokens_per_s": tput}


if __name__ == "__main__":
    serve_main()
