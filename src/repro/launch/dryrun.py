import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 or 2x16x16),
  2. builds ShapeDtypeStruct stand-ins for all step inputs (no allocation),
  3. jits the step with explicit in/out shardings and .lower().compile()s it,
  4. records memory_analysis(), cost_analysis() and the collective-byte
     tally parsed from the compiled HLO into a JSON artifact under
     experiments/dryrun/.

Any failure here (sharding mismatch, OOM-at-compile, unsupported collective)
is a bug in the framework. benchmarks/roofline.py consumes the artifacts.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import sharding as shr
from repro.models import transformer as tfm
from repro.train import train_step as ts
from repro.train.optimizer import AdamWConfig

# ---------------------------------------------------------------- shapes
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# TPU v5e-ish constants (roofline)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_DEF_RE = re.compile(r"^\s*(%?[\w\.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def cell_skip_reason(arch: str, shape: str) -> str | None:
    cfg = get_config(arch)
    if shape == "long_500k" and cfg.full_attention:
        return ("pure full-attention arch: 500k context requires "
                "sub-quadratic attention (see DESIGN.md shape-cell skips)")
    return None


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (partitioned) HLO.

    Builds a symbol table of defined values, then for each collective line
    sums the sizes of its operands (falling back to the result size when an
    operand is unknown, e.g. a constant inlined)."""
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1).lstrip("%")] = _bytes_of(m.group(2), m.group(3))
    per_kind: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        km = _COLL_RE.search(line)
        if not km or "=" not in line:
            continue
        kind = km.group(1)
        # fusion-context mentions (e.g. metadata) guard: need op call syntax
        if f"{kind}(" not in line and f"{kind}-start(" not in line:
            continue
        args = re.findall(r"%?([\w\.\-]+)", line.split("(", 1)[1])
        op_bytes = 0
        for a in args:
            if a in sizes:
                op_bytes += sizes[a]
        if op_bytes == 0:
            m = _DEF_RE.match(line)
            if m:
                op_bytes = _bytes_of(m.group(2), m.group(3))
        per_kind[kind] = per_kind.get(kind, 0) + op_bytes
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "count_by_kind": count,
            "total_bytes": sum(per_kind.values())}


# ---------------------------------------------------------------- lowering
def lower_cell(arch: str, shape: str, multi_pod: bool):
    cfg = get_config(arch)
    spec = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)

    n_stub = 256 if cfg.frontend in ("audio", "vision") else 0

    if spec["kind"] == "train":
        opt_cfg = AdamWConfig()
        hint = shr.make_hint_fn(mesh)
        dp = mesh.size // mesh.shape["model"]
        step = ts.make_train_step(cfg, opt_cfg, microbatches=1, remat=True,
                                  hint=hint, act_dtype=jnp.bfloat16,
                                  moe_groups=dp)
        state_shape = jax.eval_shape(
            lambda k: ts.make_train_state(cfg, k), key_spec)
        batch_shape = {
            "tokens": jax.ShapeDtypeStruct(
                (spec["batch"], spec["seq"] - n_stub), jnp.int32),
            "labels": jax.ShapeDtypeStruct(
                (spec["batch"], spec["seq"] - n_stub), jnp.int32),
        }
        if n_stub:
            batch_shape["embeds"] = jax.ShapeDtypeStruct(
                (spec["batch"], n_stub, cfg.d_model), jnp.float32)
        state_sh = shr.state_shardings(mesh, state_shape)
        batch_sh = shr.batch_shardings(mesh, batch_shape)
        key_sh = NamedSharding(mesh, P())
        with mesh:
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh, key_sh),
                             out_shardings=(state_sh, None), donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch_shape, key_spec)
        return mesh, lowered, dict(
            tokens=spec["batch"] * spec["seq"],
            params=cfg.param_count(), active=cfg.active_param_count(),
            flavor="train")

    if spec["kind"] == "prefill":
        dp = mesh.size // mesh.shape["model"]
        step = ts.make_prefill_step(cfg, spec["seq"],
                                    hint=shr.make_hint_fn(mesh),
                                    moe_groups=dp)
        params_shape = jax.eval_shape(
            lambda k: tfm.init_params(cfg, k, dtype=jnp.bfloat16), key_spec)
        batch_shape = {
            "tokens": jax.ShapeDtypeStruct(
                (spec["batch"], spec["seq"] - n_stub), jnp.int32)}
        if n_stub:
            batch_shape["embeds"] = jax.ShapeDtypeStruct(
                (spec["batch"], n_stub, cfg.d_model), jnp.bfloat16)
        p_sh = shr.param_shardings(mesh, params_shape)
        b_sh = shr.batch_shardings(mesh, batch_shape)
        with mesh:
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_shape, batch_shape)
        return mesh, lowered, dict(
            tokens=spec["batch"] * spec["seq"],
            params=cfg.param_count(), active=cfg.active_param_count(),
            flavor="prefill")

    # decode
    step = ts.make_decode_step(cfg)
    params_shape = jax.eval_shape(
        lambda k: tfm.init_params(cfg, k, dtype=jnp.bfloat16), key_spec)
    cache_shape = jax.eval_shape(
        lambda: tfm.init_cache(cfg, spec["batch"], spec["seq"],
                               dtype=jnp.bfloat16))
    tok_shape = jax.ShapeDtypeStruct((spec["batch"], 1), jnp.int32)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    p_sh = shr.param_shardings(mesh, params_shape)
    c_specs = shr.cache_specs(mesh, cache_shape)
    c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                        is_leaf=lambda x: isinstance(x, P))
    t_sh = shr.batch_shardings(mesh, {"t": tok_shape})["t"]
    with mesh:
        jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, None),
                         out_shardings=(t_sh, c_sh), donate_argnums=(1,))
        lowered = jitted.lower(params_shape, cache_shape, tok_shape, pos_shape)
    return mesh, lowered, dict(
        tokens=spec["batch"], params=cfg.param_count(),
        active=cfg.active_param_count(), flavor="decode")


def analyze(mesh, lowered, info: dict) -> dict:
    n_chips = mesh.size
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returns [dict] per program
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()

    # trip-count-aware analysis: cost_analysis() counts while (=scan) bodies
    # ONCE; hlo_analysis multiplies through loop trip counts (validated in
    # tests/test_hlo_analysis.py).
    from repro.launch.hlo_analysis import analyze_hlo

    stats = analyze_hlo(hlo)
    coll = {"bytes_by_kind": stats.collective_bytes,
            "total_bytes": stats.total_collective_bytes,
            "unknown_trip_loops": stats.unknown_trip_loops,
            "flat_parse": collective_bytes(hlo)}

    flops = float(stats.flops)  # per partition, trip-corrected (dot ops)
    bytes_acc = float(stats.traffic_bytes)  # fusion-boundary HBM proxy
    # roofline terms (seconds, per chip)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll["total_bytes"] / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    flavor = info["flavor"]
    n_active = info["active"]
    if flavor == "train":
        model_flops = 6.0 * n_active * info["tokens"]
    else:
        model_flops = 2.0 * n_active * info["tokens"]
    model_flops_per_chip = model_flops / n_chips

    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes_est": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
    }
    return {
        "n_chips": n_chips,
        "mesh": dict(zip(mesh.axis_names, [mesh.shape[a] for a in mesh.axis_names])),
        "compile_seconds": compile_s,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "xla_cost_analysis_flat": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": coll,
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_per_chip": model_flops_per_chip,
            "useful_flop_ratio": (model_flops_per_chip / flops) if flops else 0.0,
            "bound_step_time_s": max(terms.values()),
        },
        "memory": mem,
        "fits_hbm_16g": mem["peak_bytes_est"] < 16e9,
        "info": info,
    }


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             skip_existing: bool = False) -> dict | None:
    reason = cell_skip_reason(arch, shape)
    tag = f"{mesh_kind}/{arch}/{shape}"
    path = os.path.join(out_dir, mesh_kind, arch, f"{shape}.json")
    if skip_existing and os.path.exists(path):
        print(f"[dryrun] SKIP (exists) {tag}")
        return None
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if reason:
        rec = {"skipped": True, "reason": reason}
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[dryrun] SKIP {tag}: {reason}")
        return rec
    t0 = time.time()
    mesh, lowered, info = lower_cell(arch, shape, multi_pod=(mesh_kind == "multi"))
    lower_s = time.time() - t0
    rec = analyze(mesh, lowered, info)
    rec["lower_seconds"] = lower_s
    rec["arch"] = arch
    rec["shape"] = shape
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    r = rec["roofline"]
    print(f"[dryrun] OK {tag}: dominant={r['dominant']} "
          f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
          f"coll={r['collective_s']:.3e}s useful={r['useful_flop_ratio']:.2f} "
          f"fits16G={rec['fits_hbm_16g']} "
          f"(lower {lower_s:.0f}s compile {rec['compile_seconds']:.0f}s)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run needs 512 placeholder devices"
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mk in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    run_cell(arch, shape, mk, args.out,
                             skip_existing=args.skip_existing)
                except Exception as e:  # noqa: BLE001
                    failures.append((mk, arch, shape, repr(e)))
                    print(f"[dryrun] FAIL {mk}/{arch}/{shape}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES")
        sys.exit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
