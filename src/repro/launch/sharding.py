"""Sharding rules: params / batch / cache -> PartitionSpec pytrees.

Strategy (DESIGN.md §5): 2-D sharded params — Megatron tensor parallelism on
the "model" axis, ZeRO-3/FSDP on the data(-and-pod) axes. Rules are
name+shape based so they survive the stacked-layer leading axis that
jax.lax.scan segments introduce.

Divisibility is always checked against the actual mesh axis sizes; a dim
that doesn't divide falls back to replication on that axis (e.g. grok-1's
8 experts on a 16-way model axis shard the expert *f_f* dim instead).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import TP_AXIS, dp_axes

__all__ = ["param_specs", "param_shardings", "batch_specs", "batch_shardings",
           "cache_specs", "state_shardings", "tree_size_bytes"]


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh, axes) -> bool:
    return dim % _axis_size(mesh, axes) == 0


def _spec_for(mesh, path: str, shape: tuple[int, ...]) -> P:
    """Core rule table. ``path`` is the '/'-joined pytree path."""
    fsdp = dp_axes(mesh)  # ("data",) or ("pod", "data")
    tp = TP_AXIS
    nd = len(shape)

    def guard(spec_entries):
        """Replicate any axis whose dim doesn't divide its mesh axes."""
        out = []
        for dim, entry in zip(shape, spec_entries):
            out.append(entry if entry and _fits(dim, mesh, entry) else None)
        return P(*out)

    base = path.split("/")[-1]
    ctx = path

    # ---- embeddings: (V, d) vocab->model, d->fsdp
    if "embed" in ctx and base == "table":
        return guard([tp, fsdp])
    # ---- norms / small vectors: replicated
    if base in ("scale", "bias", "A_log", "D", "dt_bias", "norm_scale"):
        return P(*([None] * nd))
    # ---- MoE experts: (E, d, F) / (E, F, d)
    if re.search(r"moe/w_(gate|up)", ctx):
        if _fits(shape[-3], mesh, tp):
            return guard([tp, fsdp, None])
        return guard([None, fsdp, tp])  # few experts: shard F on model
    if re.search(r"moe/w_down", ctx):
        if _fits(shape[-3], mesh, tp):
            return guard([tp, None, fsdp])
        return guard([None, tp, fsdp])
    if "router" in ctx:
        return guard([fsdp, None] if nd == 2 else [None, fsdp, None])
    # ---- attention
    if re.search(r"attn/w[qkv]/w$", ctx) or re.search(r"attn/w[qkv]$", ctx):
        return guard([fsdp, tp])
    if base == "b":  # qkv bias (column-parallel output dim)
        return guard([tp])
    if "attn/wo" in ctx:
        return guard([tp, fsdp])
    # ---- MLP
    if re.search(r"mlp/(up|gate)", ctx):
        return guard([fsdp, tp])
    if "mlp/down" in ctx:
        return guard([tp, fsdp])
    # ---- mamba2
    if "in_proj" in ctx:
        return guard([fsdp, tp])
    if "out_proj" in ctx:
        return guard([tp, fsdp])
    if "conv_w" in ctx:
        return guard([None, tp])
    # ---- xlstm
    if re.search(r"(wq|wk|wv|wo_gate|w_in)/w$", ctx):
        return guard([fsdp, tp])
    if re.search(r"wout/w$", ctx):
        return guard([tp, fsdp])
    if re.search(r"(wi|wf)/w$", ctx):
        return guard([fsdp, None])
    if base == "r":  # slstm recurrent (H, Dh, 4Dh): small, replicate
        return P(*([None] * nd))
    # ---- fallback: shard the biggest dim on fsdp if divisible
    if nd >= 2:
        big = max(range(nd), key=lambda i: shape[i])
        entries = [None] * nd
        if _fits(shape[big], mesh, fsdp):
            entries[big] = fsdp
        return P(*entries)
    return P(*([None] * nd))


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
        for p in path)


def param_specs(mesh, params_shape: Any) -> Any:
    """PartitionSpec pytree for a params (or ShapeDtypeStruct) pytree.

    Stacked-layer leading axes (from scan segments) get a leading None: a
    leaf whose rule matches at rank r but arrives at rank r+1 is treated as
    stacked.
    """

    def one_checked(path, leaf):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        # heuristics: norms/vectors replicate at any rank; matrices need the
        # stacked-axis probe. Use trailing-2 ranks for matching.
        if len(shape) >= 2:
            trail = shape[-3:] if ("moe/" in pstr and len(shape) >= 3) else shape[-2:]
            spec = _spec_for(mesh, pstr, trail)
            pad = len(shape) - len(spec)
            return P(*([None] * pad), *spec)
        spec = _spec_for(mesh, pstr, shape)
        return spec

    return jax.tree_util.tree_map_with_path(one_checked, params_shape)


def param_shardings(mesh, params_shape: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(mesh, params_shape))


def state_shardings(mesh, state_shape: Any) -> Any:
    """TrainState sharding: opt moments follow params (ZeRO); step scalar
    replicated; error-feedback follows params."""
    params_sh = param_specs(mesh, state_shape.params)

    def like_params(tree):
        if tree is None:
            return None
        return jax.tree.map(
            lambda s: s, param_specs(mesh, tree))

    specs = type(state_shape)(
        params=params_sh,
        opt=type(state_shape.opt)(
            step=P(),
            mu=like_params(state_shape.opt.mu),
            nu=like_params(state_shape.opt.nu),
        ),
        err=like_params(state_shape.err),
    )
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------------- batch
def batch_specs(mesh, batch_shape: dict) -> dict:
    """Batch dims over all dp axes (pod included); seq unsharded."""
    fsdp = dp_axes(mesh)

    def one(leaf):
        shape = tuple(leaf.shape)
        entries = [None] * len(shape)
        if shape and _fits(shape[0], mesh, fsdp):
            entries[0] = fsdp
        elif shape and _fits(shape[0], mesh, ("data",) if "data" in mesh.axis_names else fsdp):
            entries[0] = "data"
        return P(*entries)

    return jax.tree.map(one, batch_shape)


def batch_shardings(mesh, batch_shape: dict) -> dict:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        batch_specs(mesh, batch_shape))


def cache_specs(mesh, cache_shape: Any) -> Any:
    """Decode caches: batch dim -> dp axes when divisible; KV-head dim ->
    model when divisible (long-context B=1 falls back to head sharding);
    recurrent states follow the same rule on their head dim."""
    fsdp = dp_axes(mesh)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        entries = [None] * nd
        # KVCache leaves: (n_layers, B, S, KV, hd) or (B, S, KV, hd)
        name = _path_str(path)
        if nd >= 4:
            b_ax = nd - 4
            s_ax = nd - 3
            kv_ax = nd - 2
            if _fits(shape[b_ax], mesh, fsdp) and shape[b_ax] > 1:
                entries[b_ax] = fsdp
            if _fits(shape[kv_ax], mesh, TP_AXIS) and shape[kv_ax] > 1:
                entries[kv_ax] = TP_AXIS
            else:
                # MHA-style caches (KV % tp != 0): shard the *sequence* dim
                # instead — decode attention reduces over S, which XLA
                # partitions as partial-softmax + small all-reduces (the
                # flash-decode pattern), and the cache memory divides by tp.
                if _fits(shape[s_ax], mesh, TP_AXIS) and shape[s_ax] > 1:
                    entries[s_ax] = TP_AXIS
        elif nd >= 2:
            b_ax = 1 if nd >= 3 else 0
            if _fits(shape[b_ax], mesh, fsdp) and shape[b_ax] > 1:
                entries[b_ax] = fsdp
        del name
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def make_hint_fn(mesh):
    """Activation-sharding hints threaded into model code (forward/loss).

    Keeps the (B, S, vocab) logits vocab-sharded on the model axis through
    the fp32 loss math (otherwise XLA tends to replicate them: ~13 GB/device
    at 4k x 256 x 150k vocab), and batch-shards activations on the dp axes.
    Returns identity for roles whose dims don't divide the mesh.
    """
    fsdp = dp_axes(mesh)

    def hint(x, role: str):
        shape = tuple(x.shape)
        if role == "logits" and len(shape) >= 2:
            entries = [None] * len(shape)
            if _fits(shape[0], mesh, fsdp) and shape[0] > 1:
                entries[0] = fsdp
            if _fits(shape[-1], mesh, TP_AXIS):
                entries[-1] = TP_AXIS
        elif role == "activations" and len(shape) >= 2:
            entries = [None] * len(shape)
            if _fits(shape[0], mesh, fsdp) and shape[0] > 1:
                entries[0] = fsdp
        elif role == "moe_in" and len(shape) == 3:
            # MoE ingress: (B, S, d) batch->dp, seq gathered across TP
            entries = [None, None, None]
            if _fits(shape[0], mesh, fsdp) and shape[0] > 1:
                entries[0] = fsdp
        elif role == "moe_buf" and len(shape) == 4:
            G, E, _, _ = shape
            entries = [None, None, None, None]
            if _fits(G, mesh, fsdp) and G > 1:
                entries[0] = fsdp
            if _fits(E, mesh, TP_AXIS) and E > 1:
                entries[1] = TP_AXIS
        elif role == "attn_full" and len(shape) >= 4:
            # q/k/v gathered to full sequence once per layer; batch stays
            # on the dp axes, everything else replicated (few-KV-head GQA
            # cannot head-shard 16 ways).
            entries = [None] * len(shape)
            if _fits(shape[0], mesh, fsdp) and shape[0] > 1:
                entries[0] = fsdp
        elif role == "residual" and len(shape) == 3:
            # Megatron sequence parallelism: the residual stream between
            # blocks is (batch -> dp, seq -> model)-sharded; XLA inserts the
            # all-gather before attention and the reduce-scatter after, and
            # every per-token op (norm/MLP/MoE ingress) stays seq-sharded.
            B, S, _ = shape
            entries = [None, None, None]
            if _fits(B, mesh, fsdp) and B > 1:
                entries[0] = fsdp
            if S > 1024 and _fits(S, mesh, TP_AXIS):
                entries[1] = TP_AXIS
            if entries[1] is None:
                return x  # no SP win for short sequences / decode
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*entries)))

    return hint


def tree_size_bytes(tree) -> int:
    return sum(
        int(jnp.prod(jnp.asarray(x.shape))) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree))
