"""Sharded, atomic, mesh-shape-agnostic checkpointing (fault tolerance).

Design goals (1000+ node deployments):
  * each host writes only its addressable shards (no gather-to-host-0),
  * atomic publish: write to ``step_N.tmp/`` then os.rename -> ``step_N/``
    (a crashed writer never corrupts the latest checkpoint),
  * mesh-shape agnostic restore: arrays are saved as full logical tensors
    per shard-grid cell + a JSON manifest of the global shape; restore
    reassembles and re-shards under *any* new mesh (elastic scaling),
  * data-pipeline state (an integer cursor) and optimizer step ride along.

On this single-process CPU container every shard is addressable, so save
degenerates to "one host writes everything" — the code paths are the same
ones a multi-host job takes (process_index filtering).
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "cleanup_old"]

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, state: dict) -> str:
    """state: arbitrary pytree of arrays + python scalars under 'meta'."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    meta = state.get("meta", {})
    arrays = {k: v for k, v in state.items() if k != "meta"}
    manifest = {"step": step, "meta": meta, "arrays": {}}
    for key, leaf in _flatten_with_paths(arrays):
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        # multi-host: only the shard owner writes; here process 0 owns all
        if jax.process_index() == 0:
            np.save(os.path.join(tmp, fname), arr)
        manifest["arrays"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    if jax.process_index() == 0:
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template: dict,
                       step: int | None = None,
                       shardings=None) -> tuple[dict, int]:
    """Restore into the structure of ``template``; re-shard with
    ``shardings`` (a pytree of NamedSharding congruent with template's
    array part) if given — this is what makes elastic mesh changes work."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    arrays_tmpl = {k: v for k, v in template.items() if k != "meta"}
    flat = _flatten_with_paths(arrays_tmpl)
    loaded = {}
    for key, leaf in flat:
        info = manifest["arrays"][key]
        arr = np.load(os.path.join(path, info["file"]))
        want = tuple(np.shape(leaf)) if hasattr(leaf, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(
                f"checkpoint/template shape mismatch for {key}: "
                f"{arr.shape} vs {want}")
        loaded[key] = arr

    def rebuild(tree, prefix=""):
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        for pth, leaf in flat_t:
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in pth)
            leaves.append(loaded[key])
        return jax.tree_util.tree_unflatten(treedef, leaves)

    out = rebuild(arrays_tmpl)
    if shardings is not None:
        out = jax.tree.map(
            lambda a, s: jax.device_put(a, s), out, shardings)
    out["meta"] = manifest["meta"]
    return out, step


def cleanup_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
