"""granite-3-2b — 40L d2048 32H (GQA kv=8) d_ff 8192 vocab 49155.

[hf:ibm-granite/granite-3.0-2b-base; tied embeddings, SwiGLU, RMSNorm]
"""

from .base import ArchConfig, register

NAME = "granite-3-2b"


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=49155,
        layout=(("dense", 40),),
        tie_embeddings=True,
        rope_theta=10_000.0,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        layout=(("dense", 2),),
        tie_embeddings=True,
    )


register(NAME, config, smoke)
