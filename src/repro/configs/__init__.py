"""Config registry: one module per assigned architecture (+ paper suite)."""

from .base import ArchConfig, MoEConfig, get_config, list_archs, register

_LOADED = False

ASSIGNED_ARCHS = (
    "qwen3-moe-235b-a22b",
    "grok-1-314b",
    "zamba2-1.2b",
    "musicgen-medium",
    "granite-3-2b",
    "qwen2-1.5b",
    "stablelm-3b",
    "chatglm3-6b",
    "xlstm-125m",
    "internvl2-1b",
)


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    from . import (chatglm3_6b, granite_3_2b, grok_1_314b,  # noqa: F401
                   internvl2_1b, musicgen_medium, qwen2_1p5b,
                   qwen3_moe_235b_a22b, stablelm_3b, xlstm_125m, zamba2_1p2b)
    _LOADED = True


__all__ = ["ArchConfig", "MoEConfig", "get_config", "list_archs", "register",
           "ASSIGNED_ARCHS"]
