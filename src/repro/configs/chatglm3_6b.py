"""chatglm3-6b — 28L d4096 32H (GQA kv=2) d_ff 13696 vocab 65024.

[arXiv:2406.12793; hf-verified. 2d-RoPE = rotary on half the head dims
(rope_fraction 0.5), QKV bias, RMSNorm + SwiGLU.]
"""

from .base import ArchConfig, register

NAME = "chatglm3-6b"


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="dense",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab=65024,
        layout=(("dense", 28),),
        rope_fraction=0.5,  # 2d RoPE: rotate half of head_dim
        qkv_bias=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        layout=(("dense", 2),),
        rope_fraction=0.5,
        qkv_bias=True,
    )


register(NAME, config, smoke)
