"""Architecture config schema + registry.

Every assigned architecture is a frozen ArchConfig in its own module
(src/repro/configs/<id>.py) with two entry points:

    config()  -> the exact published configuration
    smoke()   -> a reduced same-family configuration for CPU smoke tests

``layout`` composes the model from block segments; contiguous same-kind
segments are stacked and scanned (jax.lax.scan) so HLO size and compile time
are O(#segment kinds), not O(depth).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.moe import MoEConfig

__all__ = ["ArchConfig", "MoEConfig", "register", "get_config", "list_archs"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # moe | dense | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # block composition: tuple of (kind, count); kinds:
    #   dense | moe | mamba2 | mlstm | slstm | shared_attn
    layout: tuple[tuple[str, int], ...]
    head_dim: int = 0  # 0 -> d_model // n_heads
    rope_fraction: float = 1.0  # 0 -> no rotary
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    norm: str = "rms"  # rms | ln
    mlp: str = "swiglu"  # swiglu | gelu
    moe: Optional[MoEConfig] = None
    ssm_state: int = 0
    mamba_headdim: int = 64
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False
    frontend: str = "none"  # none | audio | vision (stubs; see DESIGN.md)
    positions: str = "rope"  # rope | sinusoidal | none
    full_attention: bool = True  # True => long_500k cell is skipped
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding/logit tables padded to a TP-shardable multiple of 256
        (Megatron convention); the true ``vocab`` stays authoritative for
        ids/labels and param counting."""
        return -(-self.vocab // 256) * 256

    def total_blocks(self) -> int:
        """Primary block count == published n_layers. ``shared_attn``
        occurrences reuse one weight set and are not counted as layers
        (zamba convention)."""
        return sum(c for k, c in self.layout if k != "shared_attn")

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and sanity tests)."""
        d, hd = self.d_model, self.resolved_head_dim
        H, KV = self.n_heads, self.n_kv_heads
        total = self.vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab * d
        for kind, cnt in self.layout:
            if kind in ("dense", "moe", "shared_attn"):
                attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
                if kind == "moe":
                    m = self.moe
                    ff = d * m.num_experts + 3 * m.num_experts * d * m.d_ff_expert
                elif self.mlp == "swiglu":
                    ff = 3 * d * self.d_ff
                else:
                    ff = 2 * d * self.d_ff
                total += cnt * (attn + ff + 2 * d)
            elif kind == "mamba2":
                di = 2 * d
                Hm = di // self.mamba_headdim
                n = self.ssm_state
                blk = d * (2 * di + 2 * n + Hm) + di * d + 4 * di + 3 * Hm
                total += cnt * (blk + d)
            elif kind in ("mlstm", "slstm"):
                if kind == "mlstm":
                    blk = 5 * d * d + 2 * d * self.n_heads
                else:
                    hd_x = d // self.n_heads
                    blk = 4 * d * d + self.n_heads * hd_x * 4 * hd_x + d * d
                total += cnt * (blk + d)
        total += d  # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        n_moe = sum(c for k, c in self.layout if k == "moe")
        all_exp = 3 * m.num_experts * self.d_model * m.d_ff_expert
        act_exp = 3 * m.top_k * self.d_model * m.d_ff_expert
        return int(full - n_moe * (all_exp - act_exp))


_REGISTRY: dict[str, tuple] = {}


def register(name: str, config_fn, smoke_fn) -> None:
    _REGISTRY[name] = (config_fn, smoke_fn)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    from . import _ensure_loaded

    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg_fn, smoke_fn = _REGISTRY[name]
    return smoke_fn() if smoke else cfg_fn()


def list_archs() -> list[str]:
    from . import _ensure_loaded

    _ensure_loaded()
    return sorted(_REGISTRY)
