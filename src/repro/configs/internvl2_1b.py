"""internvl2-1b — InternViT frontend (STUB) + Qwen2-0.5B-class LM backbone:
24L d896 14H (GQA kv=2) d_ff 4864 vocab 151655 (arXiv:2404.16821).

Per the assignment, only the LM backbone is modeled; input_specs() provides
precomputed ViT patch embeddings which are prepended to the token stream.
"""

from .base import ArchConfig, register

NAME = "internvl2-1b"


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151655,
        layout=(("dense", 24),),
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        frontend="vision",
        notes="InternViT frontend stubbed (precomputed patch embeddings).",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="vlm",
        n_layers=2,
        d_model=56,
        n_heads=4,
        n_kv_heads=2,
        d_ff=112,
        vocab=256,
        layout=(("dense", 2),),
        qkv_bias=True,
        tie_embeddings=True,
        frontend="vision",
    )


register(NAME, config, smoke)
