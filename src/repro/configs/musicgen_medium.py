"""musicgen-medium — 48L d1536 24H MHA decoder over EnCodec tokens
(arXiv:2306.05284). vocab 2048 (codebook size).

The EnCodec frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings; the backbone is a plain decoder with
sinusoidal positions, LayerNorm and GeLU MLP (faithful to the paper's
transformer recipe).
"""

from .base import ArchConfig, register

NAME = "musicgen-medium"


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab=2048,
        layout=(("dense", 48),),
        norm="ln",
        mlp="gelu",
        positions="sinusoidal",
        rope_fraction=0.0,
        frontend="audio",
        notes="decoder-only over EnCodec tokens; frontend stubbed.",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=128,
        layout=(("dense", 2),),
        norm="ln",
        mlp="gelu",
        positions="sinusoidal",
        rope_fraction=0.0,
        frontend="audio",
    )


register(NAME, config, smoke)
