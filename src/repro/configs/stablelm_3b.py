"""stablelm-3b — 32L d2560 32H (MHA kv=32) d_ff 6912 vocab 50304.

[hf:stabilityai/stablelm family; unverified tier. Partial rotary (25% of
head dim) and LayerNorm, per the StableLM recipe.]
"""

from .base import ArchConfig, register

NAME = "stablelm-3b"


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab=50304,
        layout=(("dense", 32),),
        rope_fraction=0.25,
        norm="ln",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        layout=(("dense", 2),),
        rope_fraction=0.25,
        norm="ln",
    )


register(NAME, config, smoke)
