"""grok-1-314b — 64L d6144 48H (GQA kv=8) MoE 8e top-2, d_ff 32768.

[hf:xai-org/grok-1; unverified tier per assignment]
"""

from .base import ArchConfig, MoEConfig, register

NAME = "grok-1-314b"


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab=131072,
        layout=(("moe", 64),),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32768,
                      capacity_factor=1.25),
        notes="8 experts top-2; head_dim 128 (48*128 = 6144).",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        layout=(("moe", 2),),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      capacity_factor=1.25),
    )


register(NAME, config, smoke)
