"""xlstm-125m — 12L d768 4H, alternating mLSTM / sLSTM blocks
(arXiv:2405.04517; unverified tier). d_ff = 0: the xLSTM blocks are
self-contained (no separate MLP). SSM family => long_500k runs (O(1)
recurrent state per token).
"""

from .base import ArchConfig, register

NAME = "xlstm-125m"

_LAYOUT = (("mlstm", 1), ("slstm", 1)) * 6  # 12 blocks, alternating


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        layout=_LAYOUT,
        positions="none",
        rope_fraction=0.0,
        full_attention=False,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=256,
        layout=(("mlstm", 1), ("slstm", 1)),
        positions="none",
        rope_fraction=0.0,
        full_attention=False,
    )


register(NAME, config, smoke)
