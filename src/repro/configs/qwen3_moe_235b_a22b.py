"""qwen3-moe-235b-a22b — 94L d4096 64H (GQA kv=4) MoE 128e top-8.

[hf:Qwen/Qwen3-235B-A22B family; per-layer expert d_ff=1536, head_dim=128,
vocab 151936, rope theta 1e6; hf-verified tier per assignment]
"""

from .base import ArchConfig, MoEConfig, register

NAME = "qwen3-moe-235b-a22b"


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=1536,  # per-expert ffn width
        vocab=151936,
        layout=(("moe", 94),),
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536,
                      capacity_factor=1.25),
        rope_theta=1_000_000.0,
        notes="128 experts top-8; q/k use head_dim 128 (> d/H).",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab=256,
        layout=(("moe", 2),),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96,
                      capacity_factor=1.25),
        rope_theta=1_000_000.0,
    )


register(NAME, config, smoke)
