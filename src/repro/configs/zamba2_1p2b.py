"""zamba2-1.2b — 38 Mamba2 blocks (state 64) + one *shared* attention block
interleaved (arXiv:2411.15242).

Layout adaptation (DESIGN.md): the shared transformer block (attention+MLP,
one weight set) is applied after every 6th Mamba2 block — 6 occurrences over
38 Mamba2 blocks (5+1 pattern x6, then 8 trailing Mamba2 blocks). The shared
block is MHA (kv=32) with d_ff 8192, as assigned. Hybrid family =>
long_500k runs (SSM state is O(1); the shared-attn KV cache is linear).
"""

from .base import ArchConfig, register

NAME = "zamba2-1.2b"

_LAYOUT = (("mamba2", 5), ("shared_attn", 1)) * 6 + (("mamba2", 8),)


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32000,
        layout=_LAYOUT,
        ssm_state=64,
        mamba_headdim=64,
        full_attention=False,  # hybrid: long_500k cell runs
        notes="Mamba2 + shared attn blocks; 38 mamba blocks total.",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        layout=(("mamba2", 2), ("shared_attn", 1), ("mamba2", 2)),
        ssm_state=16,
        mamba_headdim=16,
        full_attention=False,
    )


register(NAME, config, smoke)
