"""qwen2-1.5b — 28L d1536 12H (GQA kv=2) d_ff 8960 vocab 151936, QKV bias.

[arXiv:2407.10671; hf-verified]
"""

from .base import ArchConfig, register

NAME = "qwen2-1.5b"


def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        layout=(("dense", 28),),
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        layout=(("dense", 2),),
        qkv_bias=True,
        tie_embeddings=True,
    )


register(NAME, config, smoke)
