"""Version compatibility for jax runtime APIs used across layers.

The mesh/shard_map surface moved between jax 0.4 and 0.6:
  * ``jax.make_mesh`` grew ``axis_types`` / ``jax.sharding.AxisType``;
  * ``shard_map`` moved from ``jax.experimental`` to ``jax.shard_map`` and
    renamed ``check_rep`` -> ``check_vma``.

Keep every such gate here (kernels have their own in
``repro.kernels.pallas_compat`` to avoid importing pallas eagerly).
"""

from __future__ import annotations

import jax

__all__ = ["make_mesh_auto", "shard_map_compat"]


def make_mesh_auto(shape, axis_names, devices=None):
    """``jax.make_mesh`` with Auto axis types where supported."""
    kw = {} if devices is None else {"devices": devices}
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
            **kw,
        )
    return jax.make_mesh(shape, axis_names, **kw)


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """shard_map with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
