"""Distribution schemes for sparse Tucker decomposition (paper §5–6).

A *policy* along mode n is a mapping ``pi_n: elements -> [0, P)`` represented as
an int32 array of shape (nnz,). A *scheme* is a sequence of N policies (multi-
policy) or one policy reused across modes (uni-policy).

Schemes implemented:

  * ``lite``     — the paper's contribution (Fig 8). Multi-policy. Provably
                   E_max <= ceil(|E|/P), R_sum <= L + P, R_max <= ceil(L/P)+2.
  * ``coarse``   — CoarseG: whole slices per rank. Multi-policy. Strategies:
                   LPT best-processor-fit (default) or randomized contiguous
                   blocks (Smith-Karypis style).
  * ``medium``   — MediumG: medium-grained processor grid (Smith-Karypis).
                   Uni-policy.
  * ``hypergraph`` — HyperG stand-in: streaming greedy hypergraph partitioner
                   (elements = vertices, slices along all modes = hyperedges;
                   objective = balanced connectivity-1 min cut). Uni-policy.
                   The paper used Zoltan offline; ours is in-repo and kept
                   deliberately lightweight — it is the *baseline*, not the
                   contribution.
  * ``random``   — uniform random elements. Uni-policy (sanity baseline).
  * ``auto``     — real-time selector: builds the cheap candidates (lite,
                   coarse, medium), scores them with the analytic cost model
                   in repro.core.plan, and returns the predicted-fastest one.

All scheme constructors are host-side numpy (the paper runs them "real-time" as
part of HOOI; our runtimes are benchmarked in benchmarks/run.py).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Callable, Sequence

import numpy as np

from .coo import SparseTensor

__all__ = [
    "Scheme",
    "lite_policy",
    "coarse_policy",
    "medium_policies",
    "hypergraph_policy",
    "random_policy",
    "build_scheme",
    "row_owner_map",
    "SCHEMES",
]


@dataclasses.dataclass(frozen=True)
class Scheme:
    """A distribution scheme: one policy per mode."""

    name: str
    policies: tuple[np.ndarray, ...]  # each (nnz,) int32, one per mode
    uni: bool  # True if every mode uses the same policy (single tensor copy)
    P: int

    def policy(self, mode: int) -> np.ndarray:
        return self.policies[mode]

    @property
    def nmodes(self) -> int:
        return len(self.policies)

    def tensor_copies(self) -> int:
        """Copies of the input tensor stored (memory model, paper §7.3)."""
        return 1 if self.uni else self.nmodes

    def content_key(self) -> str:
        """Content hash of (name, P, uni, policy bytes), memoized.

        Used as the plan-cache key for prebuilt schemes: keying on ``id()``
        would let CPython reuse a garbage-collected scheme's id and hand a
        *different* scheme the old cached plan. Two schemes with equal
        content hash equal — that is exactly when their plans coincide.
        """
        cached = getattr(self, "_content_key", None)
        if cached is None:
            h = hashlib.sha1()
            h.update(f"{self.name}|{self.P}|{self.uni}|".encode())
            for pol in self.policies:
                arr = np.ascontiguousarray(pol)
                h.update(str(arr.shape).encode())
                h.update(str(arr.dtype).encode())
                h.update(arr.tobytes())
            cached = h.hexdigest()
            object.__setattr__(self, "_content_key", cached)  # frozen dc
        return cached


# =========================================================================
# Lite (paper Fig 8) — the contribution
# =========================================================================
def lite_policy(t: SparseTensor, mode: int, P: int) -> np.ndarray:
    """Lite distribution along ``mode`` (paper Fig 8), vectorized.

    Stage 1: slices sorted by cardinality ascending, assigned whole to ranks
    round-robin while the hard limit ceil(|E|/P) is respected.
    Stage 2: remaining (large) slices split across *contiguous* ranks, filling
    each rank exactly to the limit.
    """
    nnz = t.nnz
    if nnz == 0:
        return np.zeros(0, dtype=np.int32)
    L = t.shape[mode]
    limit = -(-nnz // P)  # ceil

    sizes = t.slice_sizes(mode)  # (L,)
    order = np.argsort(sizes, kind="stable")  # ascending slice ids
    sorted_sizes = sizes[order]

    # ---- stage 1: find the exit iteration t_hat (0-based over sorted slices)
    # Slice at sorted position j goes to rank j % P; violation when the rank's
    # running load + size > limit. Compute per-residue-class prefix loads.
    loads_before = np.zeros(L, dtype=np.int64)
    for r in range(min(P, L)):
        cls = np.arange(r, L, P)
        cs = np.cumsum(sorted_sizes[cls])
        loads_before[cls[1:]] = cs[:-1]
    violation = loads_before + sorted_sizes > limit
    viol_idx = np.nonzero(violation)[0]
    t_hat = int(viol_idx[0]) if viol_idx.size else L  # first violating position

    owner_of_slice = np.full(L, -1, dtype=np.int64)
    owner_of_slice[order[:t_hat]] = np.arange(t_hat) % P

    # rank loads at end of stage 1
    stage1_loads = np.zeros(P, dtype=np.int64)
    np.add.at(stage1_loads, np.arange(t_hat) % P, sorted_sizes[:t_hat])

    # ---- element-level assignment
    owners = np.empty(nnz, dtype=np.int32)
    slice_of_e = t.coords[:, mode]
    stage1_mask = owner_of_slice[slice_of_e] >= 0
    owners[stage1_mask] = owner_of_slice[slice_of_e[stage1_mask]]

    n_stage2 = int(nnz - stage1_mask.sum())
    if n_stage2:
        # Stage-2 elements, ordered by (sorted slice rank, element order):
        # concatenated stream cut into segments by remaining rank gaps in rank
        # order 0..P-1. Elements of each large slice land on contiguous ranks.
        rank_of_slice = np.empty(L, dtype=np.int64)
        rank_of_slice[order] = np.arange(L)
        e_idx = np.nonzero(~stage1_mask)[0]
        key = rank_of_slice[slice_of_e[e_idx]]
        stream = e_idx[np.argsort(key, kind="stable")]  # element ids in stream order
        gaps = limit - stage1_loads  # (P,) >= 0
        cum = np.cumsum(gaps)
        # position i in stream -> first rank whose cumulative gap exceeds i
        pos = np.arange(n_stage2)
        owners[stream] = np.searchsorted(cum, pos, side="right").astype(np.int32)
    return owners


# =========================================================================
# CoarseG — whole slices per rank
# =========================================================================
def coarse_policy(
    t: SparseTensor,
    mode: int,
    P: int,
    strategy: str = "lpt",
    seed: int = 0,
) -> np.ndarray:
    """Coarse-grained policy: every slice assigned in its entirety.

    strategy='lpt':   best-processor-fit on slices sorted descending (classic
                      LPT, 4/3-approx for makespan) — the strongest coarse
                      heuristic discussed in the paper.
    strategy='block': random slice order, contiguous blocks with balanced
                      element counts (Smith & Karypis [25] style).
    """
    L = t.shape[mode]
    sizes = t.slice_sizes(mode)
    owner_of_slice = np.empty(L, dtype=np.int64)
    if strategy == "lpt":
        order = np.argsort(-sizes, kind="stable")
        loads = np.zeros(P, dtype=np.int64)
        # LPT via heap-free argmin (P small); vectorizing is not worth it here
        import heapq

        heap = [(0, p) for p in range(P)]
        heapq.heapify(heap)
        for sl in order:
            load, p = heapq.heappop(heap)
            owner_of_slice[sl] = p
            heapq.heappush(heap, (load + int(sizes[sl]), p))
    elif strategy == "block":
        rng = np.random.default_rng(seed + mode)
        order = rng.permutation(L)
        csum = np.cumsum(sizes[order])
        total = int(csum[-1]) if L else 0
        # cut points at total*p/P
        targets = (np.arange(1, P) * total) // P
        cuts = np.searchsorted(csum, targets, side="left")
        block_id = np.zeros(L, dtype=np.int64)
        block_id[cuts] += 1  # may repeat; cumsum caps below
        block_id = np.minimum(np.cumsum(block_id), P - 1)
        owner_of_slice[order] = block_id
    else:
        raise ValueError(f"unknown coarse strategy {strategy!r}")
    return owner_of_slice[t.coords[:, mode]].astype(np.int32)


# =========================================================================
# MediumG — processor grid (uni-policy)
# =========================================================================
def _factor_grid(P: int, lengths: Sequence[int]) -> list[int]:
    """Factorize P into q_1 x ... x q_N with q_n roughly proportional to L_n."""
    # prime factorization of P
    primes = []
    x = P
    d = 2
    while d * d <= x:
        while x % d == 0:
            primes.append(d)
            x //= d
        d += 1
    if x > 1:
        primes.append(x)
    primes.sort(reverse=True)
    q = [1] * len(lengths)
    for f in primes:
        # give factor to the mode with largest remaining length ratio L_n / q_n
        ratios = [lengths[n] / q[n] for n in range(len(lengths))]
        n = int(np.argmax(ratios))
        q[n] *= f
    return q


def medium_policies(
    t: SparseTensor, P: int, seed: int = 0
) -> tuple[np.ndarray, list[int]]:
    """MediumG: overlay a q_1 x ... x q_N processor grid; random index perms."""
    rng = np.random.default_rng(seed)
    q = _factor_grid(P, t.shape)
    owner = np.zeros(t.nnz, dtype=np.int64)
    stride = 1
    for n in reversed(range(t.ndim)):
        L = t.shape[n]
        perm = rng.permutation(L)
        permuted = perm[t.coords[:, n]]
        # block index along mode n in [0, q_n)
        block = (permuted.astype(np.int64) * q[n]) // L
        owner += block * stride
        stride *= q[n]
    return owner.astype(np.int32), q


# =========================================================================
# HyperG stand-in — streaming greedy hypergraph partitioner (uni-policy)
# =========================================================================
def hypergraph_policy(
    t: SparseTensor,
    P: int,
    seed: int = 0,
    imbalance: float = 0.05,
) -> np.ndarray:
    """Greedy streaming hypergraph partitioning.

    Vertices = elements; hyperedges = slices along all modes. For each element
    (random order) choose the part that minimizes new slice-part connections
    (connectivity-1 metric), subject to a hard balance cap. Candidates are the
    parts already touching one of the element's N slices, plus the least
    loaded part.

    This is the in-repo stand-in for Zoltan (see DESIGN.md §8.4); it shares the
    objective but is far cheaper. Like the paper's HyperG, it is meant for
    medium tensors only.
    """
    rng = np.random.default_rng(seed)
    nnz = t.nnz
    if nnz == 0:
        return np.zeros(0, dtype=np.int32)
    cap = int(math.ceil(nnz / P * (1.0 + imbalance)))
    # slice key per (mode, coord): offset coords per mode into one id space
    offsets = np.concatenate([[0], np.cumsum(t.shape)])[: t.ndim]
    slice_ids = t.coords + offsets[None, :]  # (nnz, N) global slice ids

    part_of: list[dict[int, int]] = [dict() for _ in range(int(offsets[-1] + t.shape[-1]))]
    # part_of[slice_id] : dict part -> count of that slice's elements in part
    loads = np.zeros(P, dtype=np.int64)
    owners = np.empty(nnz, dtype=np.int32)
    order = rng.permutation(nnz)
    for e in order:
        sids = slice_ids[e]
        cand: set[int] = set()
        for s in sids:
            cand.update(part_of[s].keys())
        cand.add(int(np.argmin(loads)))
        best_p, best_score = -1, None
        for p in cand:
            if loads[p] >= cap:
                continue
            # connections created = slices of e not yet touching p
            new_conn = sum(1 for s in sids if p not in part_of[s])
            score = (new_conn, loads[p])
            if best_score is None or score < best_score:
                best_score, best_p = score, p
        if best_p < 0:  # everything at cap (shouldn't happen with slack)
            best_p = int(np.argmin(loads))
        owners[e] = best_p
        loads[best_p] += 1
        for s in sids:
            d = part_of[s]
            d[best_p] = d.get(best_p, 0) + 1
    return owners


def random_policy(t: SparseTensor, P: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, P, size=t.nnz, dtype=np.int32)


# =========================================================================
# Scheme factory
# =========================================================================
def build_scheme(
    t: SparseTensor,
    name: str,
    P: int,
    seed: int = 0,
    **kw,
) -> Scheme:
    name = name.lower()
    if name == "auto":
        # Real-time selection (paper's headline loop): delegate to the plan
        # layer, which builds the cheap candidates, scores them with the
        # analytic cost model, and caches the result. Lazy import: plan.py
        # imports this module.
        from .plan import plan as _plan

        return _plan(t, "auto", P, seed=seed, **kw).scheme
    if name == "lite":
        pols = tuple(lite_policy(t, n, P) for n in range(t.ndim))
        return Scheme("lite", pols, uni=False, P=P)
    if name in ("coarse", "coarseg"):
        pols = tuple(
            coarse_policy(t, n, P, strategy=kw.get("strategy", "lpt"), seed=seed)
            for n in range(t.ndim)
        )
        return Scheme("coarse", pols, uni=False, P=P)
    if name in ("medium", "mediumg"):
        pol, _ = medium_policies(t, P, seed=seed)
        return Scheme("medium", tuple(pol for _ in range(t.ndim)), uni=True, P=P)
    if name in ("hypergraph", "hyperg"):
        pol = hypergraph_policy(t, P, seed=seed, imbalance=kw.get("imbalance", 0.05))
        return Scheme("hypergraph", tuple(pol for _ in range(t.ndim)), uni=True, P=P)
    if name == "random":
        pol = random_policy(t, P, seed=seed)
        return Scheme("random", tuple(pol for _ in range(t.ndim)), uni=True, P=P)
    raise ValueError(f"unknown scheme {name!r}")


SCHEMES = ("lite", "coarse", "medium", "hypergraph", "random")


# =========================================================================
# Row-index mapping sigma_n (paper §3, §5 "Row-Index Mapping")
# =========================================================================
def row_owner_map(t: SparseTensor, policy: np.ndarray, mode: int, P: int) -> np.ndarray:
    """sigma_n: row index -> owning rank.

    The owner of row l is chosen among the ranks sharing Slice_n^l — we pick
    the rank holding the most elements of the slice (minimizes the data that
    rank must receive), breaking ties toward lower load. Empty slices get
    round-robin owners (their factor rows are zero but still live somewhere).
    """
    L = t.shape[mode]
    slc = t.coords[:, mode].astype(np.int64)
    pair = slc * P + policy  # (slice, rank) key
    uniq, counts = np.unique(pair, return_counts=True)
    u_slice = uniq // P
    u_rank = (uniq % P).astype(np.int64)
    owner = np.full(L, -1, dtype=np.int64)
    # argmax count per slice: sort by (slice, count) and keep the last per slice
    order = np.lexsort((counts, u_slice))
    sl_sorted = u_slice[order]
    is_last = np.r_[sl_sorted[1:] != sl_sorted[:-1], np.ones(1, dtype=bool)] if len(order) else np.zeros(0, dtype=bool)
    owner[sl_sorted[is_last]] = u_rank[order][is_last]
    empty = owner < 0
    owner[empty] = np.arange(int(empty.sum())) % P
    return owner
