"""PartitionPlan: reusable distribution plans + the real-time ``auto`` selector.

The paper's headline claim is that a *lightweight* distribution scheme chosen
in real time beats offline hypergraph partitioning on overall HOOI time. This
module closes that loop:

  * ``PartitionPlan`` bundles everything host-side partitioning produces for
    one (tensor, scheme, P) triple: the ``Scheme`` (per-mode policies), the
    padded per-mode ``ModePartition`` arrays the SPMD runtime consumes, the
    §4 ``SchemeMetrics``, and an analytic ``PlanCost`` (compute seconds from
    the critical-path FLOP model + comm seconds from ``comm_model``).

  * ``plan(t, scheme, P)`` is the single constructor. Plans are cached
    in-process with LRU eviction, keyed by tensor *content*
    (``SparseTensor.fingerprint()``) — repeated ``dist_hooi`` / benchmark
    calls on the same tensor skip all host-side partitioning work (the paper
    amortizes distribution cost across HOOI iterations; we amortize it across
    whole runs). ``save()``/``load()`` extend the same amortization across
    processes: a plan serializes to one ``.npz`` and is validated against the
    tensor's fingerprint on load.

  * ``scheme="auto"`` makes the real-time selection story executable: build
    the cheap candidates (``lite``, ``coarse``, ``medium``), score each with
    the cost model, return the predicted-fastest plan. ``hypergraph`` is
    deliberately not a candidate — it is the offline baseline the paper
    argues against (its construction alone dwarfs the modeled savings).

The cost-model rates live in ``repro.core.calibrate`` (``CostModel``); the
defaults are order-of-magnitude figures, and ``set_cost_model`` installs
rates fitted from measured executor sweeps — the cache keys on the model
version, so recalibration transparently re-scores ``auto`` selections.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Sequence

import numpy as np

from .calibrate import current_cost_model_state
from .coo import SparseTensor
from .distribution import Scheme, build_scheme
from .metrics import ModeMetrics, SchemeMetrics, scheme_metrics

__all__ = [
    "PlanCost",
    "PartitionPlan",
    "plan",
    "load_plan",
    "AUTO_CANDIDATES",
    "plan_cache_stats",
    "plan_cache_clear",
    "last_plan_call_cache_hit",
    "slice_owner_maps",
    "extend_scheme",
    "refresh_decision",
    "stochastic_refine_seconds",
    "rescore_plan",
]

# Candidates for real-time selection: the schemes whose construction is cheap
# enough to run inline before every decomposition (paper Fig 16).
AUTO_CANDIDATES = ("lite", "coarse", "medium")

PLAN_FILE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Modeled per-invocation wall time of one HOOI sweep under a plan.

    Deterministic function of the §4 metrics and the current ``CostModel`` —
    measured (noisy) build time is kept separately on
    ``PartitionPlan.build_s`` so selection is reproducible.
    """

    flops_s: float  # critical-path TTM+SVD flops / rates (= ttm_s + svd_s)
    comm_s: float  # per-device collective bytes (comm_model + fm volume) / BW
    comm_bytes: float
    path: str  # collective path ("baseline" | "liteopt" | "auto") costed
    # per-phase split under the CostModel's (possibly calibrated) phase
    # rates; defaults keep pre-phase plan files loadable
    ttm_s: float = 0.0  # bottleneck-rank TTM (Z build) seconds
    svd_s: float = 0.0  # bottleneck-rank Lanczos/SVD seconds
    # per-mode comm backend the engine will run ("local"|"psum"|"boundary");
    # defaults keep pre-engine plan files loadable
    mode_backends: tuple = ()
    # modeled comm seconds per whole-plan backend choice — what lets the
    # auto selector score comm backends, not just schemes
    backend_s: dict | None = None

    @property
    def total_s(self) -> float:
        return self.flops_s + self.comm_s


@dataclasses.dataclass(frozen=True, eq=False)
class PartitionPlan:
    """Everything host-side partitioning produces, ready for the runtime.

    ``eq=False``: plans compare by identity — the cache contract is that a
    hit returns the *same object*, so sharing is observable and device-side
    uploads keyed on the plan (``HooiExecutor``) can be reused.
    """

    scheme: Scheme
    parts: tuple  # tuple[ModePartition, ...] (repro.distributed.partition)
    metrics: SchemeMetrics
    cost: PlanCost
    core_dims: tuple[int, ...]
    P: int
    build_s: float  # measured host-side construction wall time
    cache_key: tuple | None = None
    # auto only: modeled total_s per candidate name (selection transparency)
    candidates: dict | None = None
    # content hash of the tensor this plan was built for (save/load guard).
    # For plans built from a StreamingTensor snapshot this is the stream's
    # *chain* fingerprint (incremental hash of the append history) — equally
    # content-identifying, O(batch) to maintain.
    fingerprint: str | None = None
    # stream version the fingerprint corresponds to (None for one-shot
    # tensors); lets persisted plans say *which* state of a stream they
    # describe
    stream_version: int | None = None
    # partitions built with geometric (pow2) pad quantization — part of the
    # compiled-shape contract, so it must survive save/load
    pad_geometric: bool = False
    # sweep objective this plan partitions and scores ("tucker" |
    # "completion" | "nn"): a completion plan describes the objective's
    # *training view* of the tensor, and the cost includes the objective's
    # extra FLOP terms — running it under another objective would be wrong
    # twice, so executors and load() refuse a mismatch
    objective: str = "tucker"

    @property
    def name(self) -> str:
        return self.scheme.name

    @property
    def nmodes(self) -> int:
        return self.scheme.nmodes

    def comm(self, mode: int) -> dict:
        """Per-mode analytic comm model (same dict dist_hooi reports)."""
        from repro.distributed.partition import comm_model

        n = mode
        K = self.core_dims
        khat = int(np.prod([K[j] for j in range(len(K)) if j != n]))
        return comm_model(self.parts[n], khat, 2 * int(K[n]))

    # ------------------------------------------------------------ persistence
    def save(self, path) -> None:
        """Serialize to one ``.npz`` for cross-process reuse (``load``).

        Stores the scheme policies, every padded ``ModePartition`` array, the
        §4 metrics, the modeled cost, and the source tensor's fingerprint;
        ``load`` refuses a plan whose fingerprint does not match the tensor
        it is being applied to.

        ``path`` is a filename or any binary file-like object (e.g.
        ``io.BytesIO``) — the serving tier's warm-start path serializes
        plans through memory when rerouting a stream between executors,
        with the same bytes working across processes.
        """
        if self.fingerprint is None:
            raise ValueError(
                "plan has no tensor fingerprint (built before persistence "
                "support?) — rebuild it with repro.core.plan.plan()"
            )
        arrays: dict[str, np.ndarray] = {}
        policies = self.scheme.policies[:1] if self.scheme.uni \
            else self.scheme.policies
        for n, pol in enumerate(policies):
            arrays[f"policy_{n}"] = np.asarray(pol)
        mp_scalars = []
        for n, mp in enumerate(self.parts):
            scalars = {}
            for f in dataclasses.fields(mp):
                v = getattr(mp, f.name)
                if isinstance(v, np.ndarray):
                    arrays[f"mp{n}_{f.name}"] = v
                else:
                    scalars[f.name] = int(v)
            mp_scalars.append(scalars)
        meta = {
            "version": PLAN_FILE_VERSION,
            "fingerprint": self.fingerprint,
            "scheme": {"name": self.scheme.name, "uni": self.scheme.uni,
                       "P": self.scheme.P, "nmodes": self.scheme.nmodes},
            "mp_scalars": mp_scalars,
            "metrics": dataclasses.asdict(self.metrics),
            "cost": dataclasses.asdict(self.cost),
            "core_dims": list(self.core_dims),
            "P": self.P,
            "build_s": self.build_s,
            "candidates": self.candidates,
            "stream_version": self.stream_version,
            "pad_geometric": self.pad_geometric,
            "objective": self.objective,
        }
        np.savez_compressed(path, __meta__=np.array(json.dumps(meta)),
                            **arrays)

    @classmethod
    def load(cls, path, t: SparseTensor, objective=None) -> "PartitionPlan":
        """Deserialize a plan and validate it against ``t``'s content.

        Raises ``ValueError`` on a fingerprint mismatch — a persisted plan is
        only meaningful for the exact tensor it was partitioned from — and on
        an objective mismatch (``objective``: None honors ``REPRO_OBJECTIVE``
        / defaults to tucker, or a name / ``engine.objective.Objective``; its
        ``prepare_tensor`` view is applied to ``t`` before the fingerprint
        check, mirroring how the plan was built).
        ``path`` is a filename or binary file-like object (see ``save``).
        """
        from repro.distributed.partition import ModePartition
        from repro.engine.objective import resolve_objective

        obj = resolve_objective(objective)
        t = obj.prepare_tensor(t)
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            if meta.get("version") != PLAN_FILE_VERSION:
                raise ValueError(
                    f"unsupported plan file version {meta.get('version')!r}")
            saved_objective = meta.get("objective", "tucker")
            if saved_objective != obj.name:
                raise ValueError(
                    f"plan file was built for objective="
                    f"{saved_objective!r}, asked to load for {obj.name!r} — "
                    "refusing to apply it across objectives")
            fp = t.fingerprint()
            if meta["fingerprint"] != fp:
                raise ValueError(
                    f"plan was built for tensor {meta['fingerprint'][:12]}…, "
                    f"got {fp[:12]}… — refusing to apply a stale plan")
            sm = meta["scheme"]
            if sm["uni"]:
                pol = z["policy_0"]
                policies = tuple(pol for _ in range(sm["nmodes"]))
            else:
                policies = tuple(z[f"policy_{n}"]
                                 for n in range(sm["nmodes"]))
            scheme = Scheme(name=sm["name"], policies=policies,
                            uni=sm["uni"], P=sm["P"])
            parts = []
            for n, scalars in enumerate(meta["mp_scalars"]):
                kw = dict(scalars)
                for f in dataclasses.fields(ModePartition):
                    if f.name not in kw:
                        kw[f.name] = z[f"mp{n}_{f.name}"]
                parts.append(ModePartition(**kw))
        md = meta["metrics"]
        metrics = SchemeMetrics(
            **{**md, "per_mode": tuple(ModeMetrics(**m)
                                       for m in md["per_mode"]),
               "core_dims": tuple(md["core_dims"])})
        cd = dict(meta["cost"])
        if "mode_backends" in cd:  # JSON turns tuples into lists
            cd["mode_backends"] = tuple(cd["mode_backends"])
        return cls(
            scheme=scheme,
            parts=tuple(parts),
            metrics=metrics,
            cost=PlanCost(**cd),
            core_dims=tuple(meta["core_dims"]),
            P=int(meta["P"]),
            build_s=float(meta["build_s"]),
            cache_key=None,
            candidates=meta["candidates"],
            fingerprint=meta["fingerprint"],
            stream_version=meta.get("stream_version"),
            pad_geometric=bool(meta.get("pad_geometric", False)),
            objective=saved_objective,
        )


def load_plan(path, t: SparseTensor, objective=None) -> PartitionPlan:
    """Module-level alias for ``PartitionPlan.load``."""
    return PartitionPlan.load(path, t, objective=objective)


# ---------------------------------------------------------------- cost model
_PATH_BACKEND = {"baseline": "psum", "liteopt": "boundary"}


def _plan_cost(
    parts: Sequence, metrics: SchemeMetrics, core_dims: Sequence[int],
    path: str, model, objective=None
) -> PlanCost:
    from repro.distributed.partition import comm_model
    from repro.engine.comm import backend_comm_bytes, cheaper_backend

    N = len(core_dims)
    P = int(parts[0].P) if parts else 1
    per_mode = []
    for n in range(N):
        khat = int(np.prod([core_dims[j] for j in range(N) if j != n]))
        per_mode.append(comm_model(parts[n], khat, 2 * int(core_dims[n])))
    # factor-matrix rows move once per mode step regardless of backend (§4.2)
    fm_bytes = metrics.fm_volume * 4.0

    # score every comm backend (per-mode bytes at its — possibly
    # calibrated — per-backend bandwidth), so the auto selector can compare
    # backends, not just schemes
    backend_s = {
        b: sum(model.comm_seconds(backend_comm_bytes(b, c), b)
               for c in per_mode)
        + model.comm_seconds(fm_bytes)
        for b in ("psum", "boundary")
    }
    if P == 1:
        # the engine's collective-free local backend: only fm traffic
        backend_s["local"] = model.comm_seconds(fm_bytes)
        mode_backends = ("local",) * N
    elif path == "auto":
        # per-mode selection from the partition metrics — the one rule the
        # engine's resolve_backend also applies at run time
        mode_backends = tuple(cheaper_backend(c, model) for c in per_mode)
    else:
        mode_backends = (_PATH_BACKEND[path],) * N
    comm_bytes = fm_bytes + sum(
        backend_comm_bytes(b, c) for c, b in zip(per_mode, mode_backends))
    comm_s = model.comm_seconds(fm_bytes) + sum(
        model.comm_seconds(backend_comm_bytes(b, c), b)
        for c, b in zip(per_mode, mode_backends) if b != "local")
    # per-phase scoring: with default (un-calibrated) phase rates this
    # reduces exactly to critical_path_flops / flop_rate. Objectives that
    # do extra per-mode factor work (NN-ADMM refine) fold their FLOPs into
    # the svd phase — same phase of the sweep, same rate.
    extra = 0.0
    if objective is not None:
        extra = float(objective.extra_svd_flops(metrics, core_dims, model))
    ttm_s, svd_s = model.phase_seconds(metrics.ttm_flops_max,
                                       metrics.svd_flops_max + extra)
    return PlanCost(
        flops_s=ttm_s + svd_s,
        comm_s=comm_s,
        comm_bytes=comm_bytes,
        path=path,
        ttm_s=ttm_s,
        svd_s=svd_s,
        mode_backends=mode_backends,
        backend_s=backend_s,
    )


# --------------------------------------------------------------------- cache
_CACHE: dict[tuple, PartitionPlan] = {}  # insertion-ordered; LRU eviction
_CACHE_LOCK = threading.Lock()
_STATS = {"hits": 0, "misses": 0}
CACHE_MAX_ENTRIES = 128  # plans hold padded per-device arrays — bound them


def plan_cache_stats() -> dict:
    with _CACHE_LOCK:
        return dict(_STATS, size=len(_CACHE))


# per-thread record of the last plan() call's cache outcome: the global
# hit/miss counters are shared, so "did MY call hit?" cannot be answered by
# differencing them once concurrent submitters build plans in parallel
# (another thread's miss in the window would misreport this thread's hit)
_TLS = threading.local()


def last_plan_call_cache_hit() -> bool:
    """Whether the calling thread's most recent ``plan()`` was a cache hit.

    Thread-local, so it stays correct under concurrent plan builds — this
    is what ``HooiExecutor.run`` reports as ``plan_cache_hit``.
    """
    return bool(getattr(_TLS, "cache_hit", False))


def plan_cache_clear() -> None:
    with _CACHE_LOCK:
        _CACHE.clear()
        _STATS["hits"] = 0
        _STATS["misses"] = 0


def _freeze_kw(kw: dict) -> tuple:
    return tuple(sorted((k, repr(v)) for k, v in kw.items()))


# --------------------------------------------------------------- constructor
def _build_plan(
    t: SparseTensor,
    scheme: Scheme,
    core_dims: tuple[int, ...],
    path: str,
    build_s: float,
    cache_key: tuple | None,
    model,
    pad_geometric: bool = False,
    objective=None,
    metrics: SchemeMetrics | None = None,
) -> PartitionPlan:
    from repro.distributed.partition import make_mode_partitions

    t0 = time.perf_counter()
    parts = make_mode_partitions(t, scheme, pad_geometric=pad_geometric)
    if metrics is None:
        metrics = scheme_metrics(t, scheme, core_dims)
    cost = _plan_cost(parts, metrics, core_dims, path, model,
                      objective=objective)
    return PartitionPlan(
        scheme=scheme,
        parts=parts,
        metrics=metrics,
        cost=cost,
        core_dims=core_dims,
        P=scheme.P,
        build_s=build_s + (time.perf_counter() - t0),
        cache_key=cache_key,
        fingerprint=t.fingerprint(),
        stream_version=getattr(t, "_stream_version", None),
        pad_geometric=pad_geometric,
        objective=objective.name if objective is not None else "tucker",
    )


def plan(
    t: SparseTensor,
    scheme: str | Scheme = "auto",
    P: int | None = None,
    *,
    core_dims: Sequence[int] | None = None,
    path: str = "liteopt",
    seed: int = 0,
    use_cache: bool = True,
    pad_geometric: bool = False,
    objective=None,
    metrics: SchemeMetrics | None = None,
    **scheme_kw,
) -> PartitionPlan:
    """Single constructor for ``PartitionPlan``.

    ``scheme`` may be a scheme name (including ``"auto"``) or a prebuilt
    ``Scheme`` (bypasses the scheme constructor; still builds partitions,
    metrics and cost — cached by the scheme's *content*, so equal-content
    schemes share one plan). For a prebuilt ``Scheme``, ``P`` must be
    omitted or agree with ``scheme.P``; for names it defaults to 8.

    ``core_dims`` defaults to the paper's K=10 per mode; it parameterizes the
    FLOP/comm cost model and the metrics, not the policies themselves.

    ``pad_geometric`` quantizes the padded partition dimensions to powers of
    two (streaming: compiled shapes survive small appends); it participates
    in the cache key since it changes the parts' shapes.

    ``objective`` selects the sweep objective the plan is built *for* (None
    honors ``REPRO_OBJECTIVE``, default tucker; a name or an
    ``engine.objective.Objective``). The objective's ``prepare_tensor`` view
    is applied first — a completion plan partitions the training view, not
    the raw tensor — its parameters join the cache key, its name is stamped
    on the plan (executors refuse a mismatch), and its extra FLOP terms
    enter the cost the auto selector scores.

    ``metrics`` (prebuilt-``Scheme`` path only) supplies precomputed
    ``SchemeMetrics``, skipping the O(nnz·N²) recompute — the streaming
    scheduler maintains them incrementally across appends
    (``repro.core.metrics.MetricsExtender``).
    """
    if path not in ("baseline", "liteopt", "auto"):
        raise ValueError(f"unknown path {path!r}")
    from repro.engine.objective import resolve_objective

    obj = resolve_objective(objective)
    t = obj.prepare_tensor(t)
    N = t.ndim
    core = tuple(int(k) for k in (core_dims or (10,) * N))
    if len(core) != N:
        raise ValueError(f"core_dims has {len(core)} entries for {N} modes")
    # the cost model parameterizes PlanCost: a recalibration must not reuse
    # plans scored under the old rates (model and version read in one
    # snapshot, so the cached cost always matches its key's version)
    model, mv = current_cost_model_state()

    if isinstance(scheme, Scheme):
        if P is not None and P != scheme.P:
            raise ValueError(f"scheme built for P={scheme.P}, asked for {P}")
        # key on scheme *content*, never id(): a GC'd scheme's id can be
        # reused by CPython, which would hand a different scheme the old
        # plan; equal-content schemes sharing one cached plan is correct
        key = ("prebuilt", scheme.content_key(), t.fingerprint(), core, path,
               mv, pad_geometric, obj.cache_token())
        return _cached(key, use_cache,
                       lambda: _build_plan(t, scheme, core, path, 0.0, key,
                                           model, pad_geometric,
                                           objective=obj, metrics=metrics))
    if metrics is not None:
        raise ValueError("prebuilt metrics are only valid with a prebuilt "
                         "Scheme — named schemes rebuild their policies, "
                         "which would invalidate them")
    P = 8 if P is None else int(P)

    name = scheme.lower()
    key = (t.fingerprint(), name, P, core, path, seed, _freeze_kw(scheme_kw),
           mv, pad_geometric, obj.cache_token())

    if name == "auto":
        def make_auto() -> PartitionPlan:
            t0 = time.perf_counter()
            cands = {
                c: plan(t, c, P, core_dims=core, path=path, seed=seed,
                        use_cache=use_cache, pad_geometric=pad_geometric,
                        objective=obj, **scheme_kw)
                for c in AUTO_CANDIDATES
            }
            best = min(cands, key=lambda c: cands[c].cost.total_s)
            return dataclasses.replace(
                cands[best],
                cache_key=key,
                build_s=time.perf_counter() - t0,
                candidates={c: p.cost.total_s for c, p in cands.items()},
            )

        return _cached(key, use_cache, make_auto)

    def make() -> PartitionPlan:
        t0 = time.perf_counter()
        s = build_scheme(t, name, P, seed=seed, **scheme_kw)
        return _build_plan(t, s, core, path, time.perf_counter() - t0, key,
                           model, pad_geometric, objective=obj)

    return _cached(key, use_cache, make)


# --------------------------------------------------- streaming invalidation
def slice_owner_maps(pl: PartitionPlan, t: SparseTensor
                     ) -> tuple[np.ndarray, ...]:
    """Per-mode slice -> rank maps implied by the plan's policies on ``t``.

    ``t`` must be the snapshot the plan was partitioned from (policies are
    per-element). The maps cover every slice — empty slices get round-robin
    owners, the same convention ``row_owner_map`` uses for factor rows — so
    an appended element always has a well-defined rank. Computed once when
    a plan is adopted for a stream (O(nnz·N)); after that the scheduler
    tracks per-rank loads in O(batch) per append.
    """
    from repro.core.distribution import row_owner_map

    if pl.fingerprint is not None and pl.fingerprint != t.fingerprint():
        raise ValueError("owner maps need the snapshot the plan was built "
                         f"from (plan {pl.fingerprint[:12]}…, tensor "
                         f"{t.fingerprint()[:12]}…)")
    return tuple(row_owner_map(t, pl.scheme.policy(n), n, pl.P)
                 for n in range(pl.nmodes))


def extend_scheme(scheme: Scheme, owner_maps: Sequence[np.ndarray],
                  new_coords: np.ndarray) -> Scheme:
    """Cheap per-mode repartition: extend policies to appended elements.

    Existing element assignments are untouched (their device placement
    stays stable); each appended element joins, per mode, the rank that
    owns its slice under ``owner_maps``. This is O(batch) host work versus
    a full scheme (re)construction — the streaming analogue of the paper's
    "distribution step cheaper than one HOOI iteration" claim. The result
    is multi-policy even if the source was uni-policy (owner maps differ
    per mode).
    """
    new_coords = np.asarray(new_coords)
    policies = tuple(
        np.concatenate([
            scheme.policy(n),
            np.asarray(owner_maps[n])[new_coords[:, n]].astype(np.int32),
        ])
        for n in range(scheme.nmodes)
    )
    return Scheme(name=scheme.name, policies=policies, uni=False, P=scheme.P)


def stochastic_refine_seconds(pl: PartitionPlan, sampled_nnz: int,
                              total_nnz: int, model=None) -> float:
    """Modeled seconds for one stochastic-refine pass under this plan.

    The minibatch step does the same per-element Z-build/oracle work as a
    full sweep over ``sampled_nnz / total_nnz`` of the elements, times the
    model's ``sampled_pass_overhead`` (single-device execution, full-
    snapshot fit accounting, pow2 padding — everything a full sweep
    amortizes). Scaling the plan's own ``cost.total_s`` keeps the
    comparison apples-to-apples: both sides are scored by the same
    calibrated model, so the *ratio* is what decides the rung.
    """
    from repro.core.calibrate import current_cost_model

    if model is None:
        model = current_cost_model()
    frac = min(max(float(sampled_nnz) / max(float(total_nnz), 1.0), 0.0), 1.0)
    overhead = float(getattr(model, "sampled_pass_overhead", 2.0))
    return frac * overhead * float(pl.cost.total_s)


def refresh_decision(pl: PartitionPlan, mode_loads: Sequence[np.ndarray],
                     *, tol: float = 0.25,
                     baseline: Sequence[float] | None = None,
                     stochastic: dict | None = None
                     ) -> tuple[str, dict]:
    """Is the plan's scheme still good for the grown element distribution?

    ``mode_loads``: per-mode per-rank element counts after projecting the
    appended coordinates onto the plan's slice owner maps. The drift signal
    is the §4 Metric-1 load imbalance (E_max / E_avg) this plan *would*
    have, compared against the imbalance it was selected at: within
    ``tol`` relative slack the scheme is kept and only the partitions are
    rebuilt (``"repartition"``, via ``extend_scheme``); beyond it the
    appends have skewed some mode enough that the real-time selector should
    rerun (``"reselect"``).

    ``baseline`` overrides the per-mode comparison imbalances. Callers that
    refresh a plan repeatedly (the scheduler) must pin the baseline to the
    *selection-time* values: ``pl`` is replaced on every repartition, so
    re-deriving the baseline from it would ratchet — a stream skewing a
    little per batch would never cross the tolerance. Defaults to ``pl``'s
    own metrics (correct for a one-shot check).

    ``stochastic`` opts the ladder's fourth rung in: a dict with
    ``sampled_nnz`` and ``total_nnz`` (the minibatch the caller *would*
    run), optional ``tol`` (drift ceiling for sampling, default ``tol/2``)
    and ``model`` (CostModel). When the worst drift ratio is within the
    stochastic tolerance **and** the modeled sampled pass is cheaper than
    the plan's full-sweep cost (``stochastic_refine_seconds``), the
    decision is ``"stochastic-refine"`` — keep the adopted plan untouched
    and update factors from the sampled minibatch only. The ladder is
    monotone in drift by construction: stochastic-refine below
    ``1 + stoch_tol``, repartition up to ``1 + tol``, reselect beyond.

    Returns ``(decision, drift)`` where drift maps mode -> {imbalance,
    baseline, ratio} plus ``"worst"`` — surfaced in ``DistHooiStats``.
    When the stochastic rung was evaluated, drift also carries
    ``"stochastic_s"`` / ``"full_sweep_s"`` (the modeled costs).
    """
    drift: dict = {}
    worst = 0.0
    for n, loads in enumerate(mode_loads):
        loads = np.asarray(loads, dtype=np.float64)
        total = float(loads.sum())
        imb = float(loads.max() * len(loads) / total) if total else 1.0
        if baseline is not None:
            base = max(float(baseline[n]), 1.0)
        else:
            base = max(float(pl.metrics.per_mode[n].ttm_imbalance), 1.0)
        ratio = imb / base
        worst = max(worst, ratio)
        drift[n] = {"imbalance": imb, "baseline": base, "ratio": ratio}
    drift["worst"] = worst
    if worst > 1.0 + tol:
        return "reselect", drift
    if stochastic is not None:
        stoch_tol = float(stochastic.get("tol", tol / 2.0))
        stoch_s = stochastic_refine_seconds(
            pl, stochastic["sampled_nnz"], stochastic["total_nnz"],
            stochastic.get("model"))
        drift["stochastic_s"] = stoch_s
        drift["full_sweep_s"] = float(pl.cost.total_s)
        if worst <= 1.0 + stoch_tol and stoch_s < float(pl.cost.total_s):
            return "stochastic-refine", drift
    return "repartition", drift


def rescore_plan(pl: PartitionPlan, t: SparseTensor,
                 core_dims: Sequence[int], *,
                 objective=None) -> PartitionPlan:
    """Re-score a plan for new ``core_dims`` without repartitioning.

    The adaptive-rank policy changes a mode's ``K_n`` mid-stream; the
    partitions (element placement, padded shapes) do not depend on the
    core dims, so the plan's device arrays stay valid — only the §4
    metrics and the modeled cost are rank-parameterized. The returned plan
    is a ``dataclasses.replace`` copy sharing the **same** ``parts`` tuple,
    which is exactly what the executor's upload cache dedupes on
    (``_uploads_by_parts[id(parts)]``): running the rescored plan uploads
    nothing and compiles only the genuinely-new ``niter``/``K_n`` steps.

    ``t`` must be the (objective-prepared) snapshot the plan was built
    from — metrics are recomputed against its element distribution.
    """
    from repro.engine.objective import resolve_objective

    obj = resolve_objective(objective if objective is not None
                            else pl.objective)
    t = obj.prepare_tensor(t)
    if pl.fingerprint is not None and pl.fingerprint != t.fingerprint():
        raise ValueError("rescore needs the snapshot the plan was built "
                         f"from (plan {pl.fingerprint[:12]}…, tensor "
                         f"{t.fingerprint()[:12]}…)")
    core = tuple(int(k) for k in core_dims)
    if len(core) != pl.nmodes:
        raise ValueError(
            f"core_dims has {len(core)} entries for {pl.nmodes} modes")
    model, _ = current_cost_model_state()
    metrics = scheme_metrics(t, pl.scheme, core)
    cost = _plan_cost(pl.parts, metrics, core, pl.cost.path, model,
                      objective=obj)
    return dataclasses.replace(pl, metrics=metrics, cost=cost,
                               core_dims=core, cache_key=None)


def _cached(key: tuple, use_cache: bool, make) -> PartitionPlan:
    if use_cache:
        with _CACHE_LOCK:
            hit = _CACHE.get(key)
            if hit is not None:
                _STATS["hits"] += 1
                # LRU: a hit moves the entry to the back of the eviction order
                _CACHE[key] = _CACHE.pop(key)
                _TLS.cache_hit = True
                return hit
    p = make()
    # set AFTER make(): auto's candidate sub-calls overwrite the flag, the
    # outermost call's outcome must win for last_plan_call_cache_hit()
    _TLS.cache_hit = False
    if use_cache:
        with _CACHE_LOCK:
            _STATS["misses"] += 1
            # a concurrent builder may have won the race: keep its object so
            # the identity contract (same key -> same plan) holds
            existing = _CACHE.get(key)
            if existing is not None:
                return existing
            _CACHE[key] = p
            while len(_CACHE) > CACHE_MAX_ENTRIES:
                _CACHE.pop(next(iter(_CACHE)))
    return p
