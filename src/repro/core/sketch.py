"""Randomized range-finder sketches for the oracle SVD + adaptive rank.

The paper's SVD component spends ``2*K`` full GK iterations per mode per
sweep (§7.1). A Halko-style randomized range finder recovers the leading
subspace of Z in one or two passes: sample ``Y = Z @ Ω`` for a random test
matrix Ω, orthonormalize, optionally power-iterate. This module supplies

* classical test matrices (``test_matrix``: Gaussian and SRHT) and a
  standalone ``range_finder`` that reuses the fused Z-build→oracle-panel
  machinery (``build_local_z_oracle`` → ``kernels/kron_segsum.py``) so the
  Z·Ω product costs the same single element pass as the fused pipeline;
* the *factor-seeded* sketch used by the engine's warm start
  (``warm_start="sketch"``): the start panel for ``gk_block_bidiag`` is
  ``qr(Zᵀ F_n[:, :s])`` — at sweep 0 with random orthonormal factors this
  is exactly a Gaussian-sketch range finder for Zᵀ, and at every later
  sweep (and across the scheduler's ``reselect`` rung) it is one step of
  subspace iteration from the previous factors, so Lanczos only *refines*;
* ``sketch_niter`` — the reduced refinement budget: ``min(k, …)`` Krylov
  directions instead of the full-GK ``min(2k, …)``, cutting counted oracle
  passes roughly in half on top of the better start;
* ``adapt_rank`` — the tail-spectrum policy that grows/shrinks the
  per-mode rank mid-stream (monotone in tail energy by construction).

Everything here is trace-safe: panel products go through the comm
backend's ``OracleSpace`` closures, so the same code runs replicated or
sharded over the mesh axis.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DEFAULT_POWER_ITERS", "SKETCH_KINDS", "test_matrix",
           "sketch_niter", "sketch_block_size", "seeded_start_panel",
           "power_refine", "range_finder", "adapt_rank"]

# one power iteration on top of the factor seed: the seed is already a
# subspace-iteration step at sweep > 0, so a single extra pass suffices to
# sharpen the sweep-0 (purely random) case without inflating pass counts
DEFAULT_POWER_ITERS = 1

SKETCH_KINDS = ("gauss", "srht")


def _fwht(x: jnp.ndarray) -> jnp.ndarray:
    """Fast Walsh–Hadamard transform along axis 0 (length a power of two)."""
    m = x.shape[0]
    h = 1
    while h < m:
        x = x.reshape(m // (2 * h), 2, h, -1)
        x = jnp.concatenate([x[:, 0] + x[:, 1], x[:, 0] - x[:, 1]], axis=1)
        x = x.reshape(m, -1)
        h *= 2
    return x


def test_matrix(key: jax.Array, n: int, s: int,
                kind: str = "gauss") -> jnp.ndarray:
    """Random test matrix Ω (n, s) for sketching: ``Y = Z @ Ω``.

    ``gauss`` is the classical dense Gaussian sketch. ``srht`` is the
    subsampled randomized Hadamard transform — random signs, a
    Walsh–Hadamard mix (computed on the next power of two and truncated to
    ``n`` rows), and ``s`` columns sampled without replacement. Scale is
    irrelevant downstream (every consumer orthonormalizes), so no
    ``sqrt(n/s)`` normalization is applied.
    """
    if kind not in SKETCH_KINDS:
        raise ValueError(f"unknown sketch kind {kind!r} "
                         f"(expected one of {SKETCH_KINDS})")
    if kind == "gauss":
        return jax.random.normal(key, (n, s), jnp.float32)
    m = 1 << max(int(n) - 1, 1).bit_length()
    k_sign, k_sel = jax.random.split(key)
    cols = jax.random.choice(k_sel, m, (s,), replace=False)
    onehot = jnp.zeros((m, s), jnp.float32).at[cols, jnp.arange(s)].set(1.0)
    H_s = _fwht(onehot)[:n]
    signs = jnp.where(jax.random.bernoulli(k_sign, 0.5, (n, 1)), 1.0, -1.0)
    return signs.astype(jnp.float32) * H_s


def sketch_niter(k: int, nrows: int, ncols: int, block_size: int = 1) -> int:
    """Refinement budget for a sketch-warm-started block GK driver.

    The warm start already spans (an approximation of) the leading
    subspace, so the driver only needs ``min(k, nrows, ncols)`` Krylov
    directions to refine — half the full-GK ``min(2k, …)`` budget — counted
    in block iterations exactly like ``lanczos_niter``.
    """
    base = max(int(min(k, nrows, ncols)), 1)
    if block_size <= 1:
        return base
    s = min(int(block_size), base)
    return -(-base // s)


def sketch_block_size(k: int, nrows: int, ncols: int,
                      block_size: int = 1) -> int:
    """Panel width for a sketch-warm-started block driver.

    The factor-seeded start panel must span the mode's whole previous
    subspace: a seed narrower than ``k`` degrades the warm start into a
    cold Krylov run on *half* the budget (the quality loss is observable as
    a lower HOOI fit plateau). Sketch modes therefore widen the requested
    block to at least ``k``, clamped by the operator's vector budget
    exactly like ``effective_block_size`` — so ``sketch_niter`` typically
    counts a single block refinement over a ``k``-wide panel.
    """
    from repro.core.lanczos import effective_block_size

    return effective_block_size(k, nrows, ncols,
                                max(int(block_size), int(k)))


def seeded_start_panel(seed: jnp.ndarray, key: jax.Array, ncols: int,
                       block_size: int) -> jnp.ndarray:
    """Orthonormal (ncols, s) start panel from a factor-seeded sketch.

    ``seed`` is the v-space sketch ``Zᵀ F[:, :w]`` (replicated across
    devices — callers psum partial products first). When the panel is wider
    than the seed (``s > w``, i.e. the block width exceeds the mode rank)
    the excess columns are filled with a Gaussian test matrix from a
    dedicated fold of the step key, keeping the panel deterministic per
    (key, shape) like ``block_start_panel``.
    """
    s = int(block_size)
    w = int(seed.shape[1])
    if w < s:
        extra = jax.random.normal(jax.random.fold_in(key, 41),
                                  (ncols, s - w), seed.dtype)
        seed = jnp.concatenate([seed, extra], axis=1)
    q, _ = jnp.linalg.qr(seed[:, :s])
    return q


def power_refine(matvec: Callable, rmatvec: Callable, panel: jnp.ndarray,
                 iters: int) -> jnp.ndarray:
    """Subspace (power) iteration on a v-space panel through the oracle.

    Each iteration costs one matvec + one rmatvec pass over Z. The panel
    stays in v-space (replicated), so the QR re-orthonormalization needs no
    collectives; the space closures own the u-space reduction.
    """
    q = panel
    for _ in range(int(iters)):
        g = rmatvec(matvec(q))
        q, _ = jnp.linalg.qr(g)
    return q


def range_finder(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    local_rows: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_rows: int,
    k: int,
    key: jax.Array,
    *,
    kind: str = "gauss",
    oversample: int = 4,
    power_iters: int = 0,
    use_kernel: bool = False,
    sorted_rows: bool = False,
    precision: str = "f32",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Classical randomized range finder for one mode's penultimate matrix.

    Draws Ω (K_hat, k + oversample), computes ``(Z, Z @ Ω)`` in ONE fused
    element pass through ``build_local_z_oracle`` (the same oracle-panel
    seam the fused pipeline and the Pallas ``kron_segsum`` kernel serve),
    orthonormalizes, optionally power-iterates, and resolves the small
    projected SVD. Returns ``(U_k, sv_est)`` — the leading left subspace
    and the sketch's spectrum estimate (whose tail drives ``adapt_rank``).
    """
    from repro.engine.zbuild import build_local_z_oracle

    khat = 1
    for i, f in enumerate(factors):
        if i != mode:
            khat *= int(f.shape[1])
    s = max(1, min(int(k) + int(oversample), int(num_rows), khat))
    omega = test_matrix(key, khat, s, kind)
    Z, Y = build_local_z_oracle(
        coords, values, local_rows, factors, mode, num_rows, omega,
        use_kernel=use_kernel, sorted_rows=sorted_rows, precision=precision)
    Q, _ = jnp.linalg.qr(Y)
    for _ in range(int(power_iters)):
        Q, _ = jnp.linalg.qr(Z @ (Z.T @ Q))
    B = Q.T @ Z
    Ub, sv, _ = jnp.linalg.svd(B, full_matrices=False)
    kk = min(int(k), s)
    return Q @ Ub[:, :kk], sv[:kk]


def adapt_rank(
    spectrum,
    k: int,
    *,
    grow_thresh: float = 0.15,
    shrink_thresh: float = 0.02,
    grow_step: int = 2,
    k_min: int = 2,
    k_max: int | None = None,
) -> int:
    """Tail-spectrum rank policy: the next ``R_n`` for one mode.

    ``spectrum`` is the mode's (estimated) leading singular values, e.g.
    the sketch/GK output ``S[:k]``. Ratios are relative to ``σ_1``:

    * the retained tail is still energetic (``σ_k/σ_1 > grow_thresh``) →
      grow by ``grow_step`` (the basis is truncating real signal);
    * trailing values have collapsed (``σ_j/σ_1 < shrink_thresh``) → shrink
      to the number of energetic columns;
    * otherwise keep ``k``.

    The result is clamped to ``[k_min, k_max]`` and, holding ``k`` fixed,
    is monotone non-decreasing in every ratio ``σ_j/σ_1`` — the property
    the streaming tests pin.
    """
    k = int(k)
    s = np.asarray(spectrum, dtype=float).ravel()[:k]
    hi = k if k_max is None else int(k_max)
    lo = min(int(k_min), hi)
    if s.size == 0 or not np.isfinite(s[0]) or s[0] <= 0.0:
        return min(max(k, lo), hi)
    rel = s / s[0]
    if rel[-1] > grow_thresh:
        k_new = k + int(grow_step)
    else:
        k_new = int(np.sum(rel >= shrink_thresh))
    return min(max(k_new, lo), hi)
