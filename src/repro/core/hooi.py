"""HOOI (Higher-Order Orthogonal Iteration) — single-process entry point.

Implements the procedure of paper Fig 2 exactly:

    for each mode n:
        Z_(n)  <- TTM-chain skipping n, unfolded       (engine Z-build stage)
        F~_n   <- leading K_n left singular vectors    (engine oracle stage)
    core   <- T x_1 F~_1^T ... x_N F~_N^T              (once, at the end)

Since the engine refactor this module owns no sweep loop of its own:
``hooi`` is the **local-backend instantiation** of ``repro.engine`` — the
identity partition, no collectives — driving the same
``engine.sweep.run_hooi_sweeps`` loop and the same Z-build/oracle stages as
the distributed executor. The distributed runs differ only in placement and
comm backend, so this module remains the *oracle* the kernels and the
distributed paths are tested against by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .coo import SparseTensor
from .ttm import core_from_factors

__all__ = ["Decomposition", "random_factors", "hosvd_init", "hooi_invocation",
           "hooi", "fit_score"]


@dataclasses.dataclass
class Decomposition:
    core: jnp.ndarray | None  # (K_1..K_N); None until finalized
    factors: list[jnp.ndarray]  # F_n: (L_n, K_n), orthonormal columns

    @property
    def core_dims(self) -> tuple[int, ...]:
        return tuple(int(f.shape[1]) for f in self.factors)


def random_factors(
    shape: Sequence[int], core_dims: Sequence[int], key: jax.Array
) -> list[jnp.ndarray]:
    """Random orthonormal factor matrices (paper: valid HOOI bootstrap)."""
    factors = []
    for n, (L, K) in enumerate(zip(shape, core_dims)):
        sub = jax.random.fold_in(key, n)
        g = jax.random.normal(sub, (L, K), jnp.float32)
        q, _ = jnp.linalg.qr(g)
        factors.append(q)
    return factors


def hosvd_init(t: SparseTensor, core_dims: Sequence[int]) -> list[jnp.ndarray]:
    """HOSVD bootstrap via dense unfoldings — small tensors / tests only."""
    dense = jnp.asarray(t.todense(), jnp.float32)
    factors = []
    for n, K in enumerate(core_dims):
        M = jnp.moveaxis(dense, n, 0).reshape(t.shape[n], -1)
        u, _, _ = jnp.linalg.svd(M, full_matrices=False)
        factors.append(u[:, :K])
    return factors


def hooi_invocation(
    t: SparseTensor,
    factors: list[jnp.ndarray],
    key: jax.Array,
    lanczos_iters: int | None = None,
    use_kernels: bool = False,
    timings: dict | None = None,
    use_fused_oracle: bool | None = None,
    precision: str | None = None,
    lanczos_block: int | None = None,
    fused_zbuild: bool | None = None,
    warm_start: str | None = None,
    objective=None,
) -> list[jnp.ndarray]:
    """One HOOI invocation: refine all factor matrices (no core update).

    Thin wrapper over the engine's local mode step (kept for direct callers
    and the phase-instrumentation benchmarks; per-mode keys are derived as
    ``fold_in(key, n)``, the historical convention for this entry point).
    ``objective`` is an already-resolved ``engine.objective.Objective`` (or
    None for the standard Tucker behavior); this entry point does not apply
    ``prepare_tensor`` — callers own the view.
    """
    from repro.core.lanczos import effective_block_size
    from repro.core.sketch import sketch_block_size
    from repro.engine.steps import local_mode_step
    from repro.engine.oracle import (choose_warm_start, resolve_block_size,
                                     resolve_warm_start)
    from repro.engine.zbuild import resolve_fused_zbuild, resolve_precision

    coords = jnp.asarray(t.coords, jnp.int32)
    values = jnp.asarray(t.values, jnp.float32)
    prec = resolve_precision(precision)
    blk = resolve_block_size(lanczos_block)
    fz = resolve_fused_zbuild(fused_zbuild)
    warm = resolve_warm_start(warm_start)
    new_factors = list(factors)
    track = timings if timings is not None else {}
    for n in range(t.ndim):
        k_n = int(new_factors[n].shape[1])
        khat = 1
        for j, f in enumerate(new_factors):
            if j != n:
                khat *= int(f.shape[1])
        s_eff = effective_block_size(k_n, t.shape[n], khat, blk)
        ws_n = choose_warm_start(warm, k_n, t.shape[n], khat, s_eff, fz)
        fz_n = fz and ws_n != "sketch"
        if ws_n == "sketch":
            s_eff = sketch_block_size(k_n, t.shape[n], khat, blk)
        niter = lanczos_iters
        if niter is not None and (fz_n or s_eff > 1 or ws_n == "sketch"):
            niter = -(-int(niter) // s_eff)  # vector budget -> block count
        new_factors[n] = local_mode_step(
            coords, values, new_factors, n, t.shape[n],
            jax.random.fold_in(key, n),
            niter=niter, use_kernel=use_kernels,
            use_fused_oracle=bool(use_fused_oracle), precision=prec,
            block_size=s_eff, fused_zbuild=fz_n, warm_start=ws_n,
            timings=track, objective=objective,
        )
    return new_factors


def fit_score(t: SparseTensor, dec: Decomposition) -> float:
    """Fit = 1 - ||T - Z||_F / ||T||_F.

    With orthonormal factors and core = T x_n F_n^T (true after finalize),
    ||T - Z||^2 = ||T||^2 - ||G||^2 (classic identity), so no reconstruction
    is materialized.

    ``sum(values**2)`` equals ||T||^2 only for duplicate-free COO; tensors
    carrying duplicate coordinates (streaming value updates — see
    ``repro.streaming``) provide the true norm as ``_true_norm2`` and it
    takes precedence, keeping the identity exact.
    """
    true_norm2 = getattr(t, "_true_norm2", None)
    t_norm2 = float(true_norm2) if true_norm2 is not None \
        else float(np.sum(t.values**2))
    g_norm2 = float(jnp.sum(dec.core**2))
    err2 = max(t_norm2 - g_norm2, 0.0)
    return 1.0 - float(np.sqrt(err2) / (np.sqrt(t_norm2) + 1e-30))


def hooi(
    t: SparseTensor,
    core_dims: Sequence[int],
    n_invocations: int = 5,
    init: str = "random",
    seed: int = 0,
    lanczos_iters: int | None = None,
    use_kernels: bool = False,
    verbose: bool = False,
    use_fused_oracle: bool | None = None,
    precision: str | None = None,
    lanczos_block: int | None = None,
    fused_zbuild: bool | None = None,
    warm_start: str | None = None,
    objective=None,
    metrics_out: dict | None = None,
) -> tuple[Decomposition, list[float]]:
    """Full HOOI driver: bootstrap, invoke repeatedly, finalize core.

    The local-backend instantiation of the shared engine —
    ``dist_hooi(t, core_dims, 1, ...)`` runs the same loop, steps, and key
    schedule through the executor and produces the same fit trajectory.
    ``use_fused_oracle`` (None/False = off) routes the Lanczos oracle
    products through the Pallas ``oracle_pair`` kernel.

    Roofline knobs (each resolved through the same engine resolvers the
    distributed executor uses, so P=1 parity holds on every variant):
    ``precision`` — ``"f32"``/``"bf16"``/``"auto"``/None (None honors
    ``REPRO_PRECISION``); ``lanczos_block`` — s-step Lanczos panel width
    request (None honors ``REPRO_LANCZOS_BLOCK``); ``fused_zbuild`` — fuse
    the Z build with the first oracle panel product (None honors
    ``REPRO_FUSED_ZBUILD``); ``warm_start`` — ``"none"``/``"sketch"``/
    ``"auto"`` oracle warm start (None honors ``REPRO_WARM_START``;
    ``"sketch"`` seeds the block driver with the factor-sketched
    range-finder panel and halves the refinement budget, ``"none"``
    reproduces the historical trajectories bitwise).

    ``objective`` selects what the sweeps optimize (None honors
    ``REPRO_OBJECTIVE``, default standard Tucker; a name or an
    ``engine.objective.Objective`` instance otherwise). The objective's
    ``prepare_tensor`` view is applied here — completion drops its held-out
    entries before any device array is built. ``metrics_out`` (a dict)
    collects the objective's extra per-sweep stats (held-out RMSE).
    """
    from repro.core.lanczos import effective_block_size
    from repro.core.sketch import sketch_block_size
    from repro.engine.objective import resolve_objective
    from repro.engine.oracle import (choose_warm_start, resolve_block_size,
                                     resolve_warm_start)
    from repro.engine.steps import local_mode_step
    from repro.engine.sweep import run_hooi_sweeps
    from repro.engine.zbuild import resolve_fused_zbuild, resolve_precision

    obj = resolve_objective(objective)
    t = obj.prepare_tensor(t)

    key = jax.random.PRNGKey(seed)
    if init == "random":
        factors = random_factors(t.shape, core_dims, key)
    elif init == "hosvd":
        factors = hosvd_init(t, core_dims)
    else:
        raise ValueError(f"unknown init {init!r}")

    coords = jnp.asarray(t.coords, jnp.int32)
    values = jnp.asarray(t.values, jnp.float32)
    fused = bool(use_fused_oracle)
    prec = resolve_precision(precision)
    blk = resolve_block_size(lanczos_block)
    fz = resolve_fused_zbuild(fused_zbuild)
    warm = resolve_warm_start(warm_start)

    def mode_step(n, facs, kk):
        k_n = int(facs[n].shape[1])
        khat = 1
        for j, f in enumerate(facs):
            if j != n:
                khat *= int(f.shape[1])
        s_eff = effective_block_size(k_n, t.shape[n], khat, blk)
        ws_n = choose_warm_start(warm, k_n, t.shape[n], khat, s_eff, fz)
        fz_n = fz and ws_n != "sketch"
        if ws_n == "sketch":
            s_eff = sketch_block_size(k_n, t.shape[n], khat, blk)
        niter = lanczos_iters
        if niter is not None and (fz_n or s_eff > 1 or ws_n == "sketch"):
            niter = -(-int(niter) // s_eff)
        return local_mode_step(coords, values, facs, n, t.shape[n], kk,
                               niter=niter, use_kernel=use_kernels,
                               use_fused_oracle=fused, precision=prec,
                               block_size=s_eff, fused_zbuild=fz_n,
                               warm_start=ws_n, objective=obj)

    def on_sweep(it, _seconds, fit):  # pragma: no cover
        if verbose:
            print(f"  HOOI invocation {it}: fit={fit:.4f}")

    return run_hooi_sweeps(coords, values, t, factors, key, n_invocations,
                           mode_step, on_sweep=on_sweep, objective=obj,
                           metrics_out=metrics_out)
