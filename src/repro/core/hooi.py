"""HOOI (Higher-Order Orthogonal Iteration) — single-process reference.

Implements the procedure of paper Fig 2 exactly:

    for each mode n:
        Z_(n)  <- TTM-chain skipping n, unfolded       (ttm.penultimate)
        F~_n   <- leading K_n left singular vectors    (lanczos)
    core   <- T x_1 F~_1^T ... x_N F~_N^T              (once, at the end)

The distributed version (repro.distributed.dist_hooi) shares all the math
here and differs only in data placement and collectives. This module is also
the *oracle* the distributed path and the Pallas kernels are tested against.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .coo import SparseTensor
from .lanczos import svd_via_lanczos
from .ttm import core_from_factors, penultimate

__all__ = ["Decomposition", "random_factors", "hosvd_init", "hooi_invocation",
           "hooi", "fit_score"]


@dataclasses.dataclass
class Decomposition:
    core: jnp.ndarray | None  # (K_1..K_N); None until finalized
    factors: list[jnp.ndarray]  # F_n: (L_n, K_n), orthonormal columns

    @property
    def core_dims(self) -> tuple[int, ...]:
        return tuple(int(f.shape[1]) for f in self.factors)


def random_factors(
    shape: Sequence[int], core_dims: Sequence[int], key: jax.Array
) -> list[jnp.ndarray]:
    """Random orthonormal factor matrices (paper: valid HOOI bootstrap)."""
    factors = []
    for n, (L, K) in enumerate(zip(shape, core_dims)):
        sub = jax.random.fold_in(key, n)
        g = jax.random.normal(sub, (L, K), jnp.float32)
        q, _ = jnp.linalg.qr(g)
        factors.append(q)
    return factors


def hosvd_init(t: SparseTensor, core_dims: Sequence[int]) -> list[jnp.ndarray]:
    """HOSVD bootstrap via dense unfoldings — small tensors / tests only."""
    dense = jnp.asarray(t.todense(), jnp.float32)
    factors = []
    for n, K in enumerate(core_dims):
        M = jnp.moveaxis(dense, n, 0).reshape(t.shape[n], -1)
        u, _, _ = jnp.linalg.svd(M, full_matrices=False)
        factors.append(u[:, :K])
    return factors


def hooi_invocation(
    t: SparseTensor,
    factors: list[jnp.ndarray],
    key: jax.Array,
    lanczos_iters: int | None = None,
    use_kernels: bool = False,
    timings: dict | None = None,
) -> list[jnp.ndarray]:
    """One HOOI invocation: refine all factor matrices (no core update)."""
    coords = jnp.asarray(t.coords, jnp.int32)
    values = jnp.asarray(t.values, jnp.float32)
    new_factors = list(factors)
    for n in range(t.ndim):
        t0 = time.perf_counter()
        if use_kernels:
            from repro.kernels import ops as kops

            Z = kops.penultimate(
                coords, values, new_factors, n, t.shape[n]
            )
        else:
            Z = penultimate(coords, values, new_factors, n, t.shape[n])
        Z.block_until_ready()
        t1 = time.perf_counter()
        K_n = int(factors[n].shape[1])
        res = svd_via_lanczos(Z, K_n, key=jax.random.fold_in(key, n),
                              niter=lanczos_iters)
        res.left_vectors.block_until_ready()
        t2 = time.perf_counter()
        new_factors[n] = res.left_vectors
        if timings is not None:
            timings.setdefault("ttm", 0.0)
            timings.setdefault("svd", 0.0)
            timings["ttm"] += t1 - t0
            timings["svd"] += t2 - t1
    return new_factors


def fit_score(t: SparseTensor, dec: Decomposition) -> float:
    """Fit = 1 - ||T - Z||_F / ||T||_F.

    With orthonormal factors and core = T x_n F_n^T (true after finalize),
    ||T - Z||^2 = ||T||^2 - ||G||^2 (classic identity), so no reconstruction
    is materialized.
    """
    t_norm2 = float(np.sum(t.values**2))
    g_norm2 = float(jnp.sum(dec.core**2))
    err2 = max(t_norm2 - g_norm2, 0.0)
    return 1.0 - float(np.sqrt(err2) / (np.sqrt(t_norm2) + 1e-30))


def hooi(
    t: SparseTensor,
    core_dims: Sequence[int],
    n_invocations: int = 5,
    init: str = "random",
    seed: int = 0,
    lanczos_iters: int | None = None,
    use_kernels: bool = False,
    verbose: bool = False,
) -> tuple[Decomposition, list[float]]:
    """Full HOOI driver: bootstrap, invoke repeatedly, finalize core."""
    key = jax.random.PRNGKey(seed)
    if init == "random":
        factors = random_factors(t.shape, core_dims, key)
    elif init == "hosvd":
        factors = hosvd_init(t, core_dims)
    else:
        raise ValueError(f"unknown init {init!r}")

    coords = jnp.asarray(t.coords, jnp.int32)
    values = jnp.asarray(t.values, jnp.float32)
    fits: list[float] = []
    for it in range(n_invocations):
        factors = hooi_invocation(
            t, factors, jax.random.fold_in(key, 1000 + it),
            lanczos_iters=lanczos_iters, use_kernels=use_kernels,
        )
        core = core_from_factors(coords, values, factors)
        dec = Decomposition(core=core, factors=factors)
        fits.append(fit_score(t, dec))
        if verbose:  # pragma: no cover
            print(f"  HOOI invocation {it}: fit={fits[-1]:.4f}")
    core = core_from_factors(coords, values, factors)
    return Decomposition(core=core, factors=factors), fits
