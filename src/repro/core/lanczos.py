"""Matrix-free Lanczos (Golub–Kahan) bidiagonalization for the SVD step.

The paper's framework performs the SVD of the penultimate matrix Z_(n)
(L_n x K_hat_n) through an *oracle model*: the method only ever asks for the
two products  x_out = Z @ x_in  and  y_out = y_in @ Z.  This file implements
the driver; callers supply the oracle as a pair of closures, which is what
lets the distributed runtime answer queries with local matmuls + collectives
(paper §3 'SVD Component').

Per the paper (§7.1, following SLEPc), we run ``2*K`` bidiagonalization
iterations for K requested singular vectors, i.e. ``Q_n = 4*K`` oracle
queries. Full (two-pass CGS) reorthogonalization keeps float32 stable.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["LanczosResult", "lanczos_bidiag", "svd_via_lanczos"]

_EPS = 1e-30


class LanczosResult(NamedTuple):
    left_vectors: jnp.ndarray  # (nrows, k) leading left singular vectors
    singular_values: jnp.ndarray  # (k,)
    n_queries: int  # oracle queries consumed (Q_n in the paper)


def _reorth(v: jnp.ndarray, basis: jnp.ndarray, filled: int) -> jnp.ndarray:
    """CGS2 re-orthogonalization of v against the first ``filled`` columns.

    ``basis`` is a preallocated (dim, niter) buffer; columns >= filled are
    zero, so a full matmul is safe (and static-shaped for jit).
    """
    del filled  # zero columns contribute nothing; kept for readability
    for _ in range(2):  # "twice is enough"
        v = v - basis @ (basis.T @ v)
    return v


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _lanczos_impl(matvec, rmatvec, nrows, ncols, niter, key):
    """Unrolled GK bidiagonalization (niter is small: 2K)."""
    dtype = jnp.float32
    V = jnp.zeros((ncols, niter), dtype)  # right Lanczos vectors
    U = jnp.zeros((nrows, niter), dtype)  # left Lanczos vectors
    alphas = jnp.zeros((niter,), dtype)
    betas = jnp.zeros((niter,), dtype)  # betas[i] couples step i -> i+1

    key, ku, kv = jax.random.split(key, 3)
    r_u = jax.random.normal(ku, (nrows, niter), dtype)  # breakdown restarts
    r_v = jax.random.normal(kv, (ncols, niter), dtype)

    v0 = jax.random.normal(key, (ncols,), dtype)
    v0 = v0 / (jnp.linalg.norm(v0) + _EPS)

    def body(i, carry):
        U, V, alphas, betas, v, u_prev, beta_prev, scale = carry
        V = V.at[:, i].set(v)
        u = matvec(v) - beta_prev * u_prev
        u = _reorth(u, U, i)
        alpha = jnp.linalg.norm(u)
        scale = jnp.maximum(scale, alpha)
        # Lucky breakdown: restart with a fresh direction, record alpha = 0 so
        # the restart never mixes into the computed singular vectors.
        ok = alpha > 1e-6 * scale
        u_new = _reorth(r_u[:, i], U, i)
        u_new = u_new / (jnp.linalg.norm(u_new) + _EPS)
        u = jnp.where(ok, u / (alpha + _EPS), u_new)
        alpha = jnp.where(ok, alpha, 0.0)
        U = U.at[:, i].set(u)
        alphas = alphas.at[i].set(alpha)

        w = rmatvec(u) - alpha * v
        V2 = V  # v not yet appended at i+1; V has cols < i+1 filled
        w = _reorth(w, V2, i + 1)
        beta = jnp.linalg.norm(w)
        scale = jnp.maximum(scale, beta)
        ok_b = beta > 1e-6 * scale
        v_new = _reorth(r_v[:, i], V2, i + 1)
        v_new = v_new / (jnp.linalg.norm(v_new) + _EPS)
        v = jnp.where(ok_b, w / (beta + _EPS), v_new)
        beta = jnp.where(ok_b, beta, 0.0)
        betas = betas.at[i].set(beta)
        return (U, V, alphas, betas, v, u, beta, scale)

    carry = (U, V, alphas, betas, v0, jnp.zeros((nrows,), dtype),
             jnp.array(0.0, dtype), jnp.array(_EPS, dtype))
    U, V, alphas, betas, *_ = jax.lax.fori_loop(0, niter, body, carry)

    # Z V = U B with B *upper* bidiagonal: alphas on the diagonal, betas on
    # the superdiagonal (Z v_{i+1} = beta_i u_i + alpha_{i+1} u_{i+1}).
    B = jnp.diag(alphas) + jnp.diag(betas[:-1], k=1)
    return U, V, B


def lanczos_bidiag(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    rmatvec: Callable[[jnp.ndarray], jnp.ndarray],
    nrows: int,
    ncols: int,
    k: int,
    niter: int | None = None,
    key: jax.Array | None = None,
) -> LanczosResult:
    """Leading-k left singular vectors of the oracle matrix Z.

    matvec : x (ncols,) -> Z @ x (nrows,)
    rmatvec: u (nrows,) -> Z.T @ u (ncols,)
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    if niter is None:
        niter = 2 * k  # paper / SLEPc convention
    niter = int(min(niter, nrows, ncols))
    niter = max(niter, min(k, nrows, ncols))
    U, V, B = _lanczos_impl(matvec, rmatvec, nrows, ncols, niter, key)
    # SVD of the small bidiagonal matrix
    P, S, _ = jnp.linalg.svd(B, full_matrices=False)
    kk = min(k, niter)
    left = U @ P[:, :kk]  # (nrows, kk)
    if kk < k:  # rank-deficient edge: complete with orthonormal columns
        key2 = jax.random.fold_in(key, 1)
        extra = jax.random.normal(key2, (nrows, k - kk), left.dtype)
        extra = extra - left @ (left.T @ extra)
        q, _ = jnp.linalg.qr(extra)
        left = jnp.concatenate([left, q], axis=1)
        S = jnp.concatenate([S[:kk], jnp.zeros((k - kk,), S.dtype)])
    return LanczosResult(left, S[:k], n_queries=2 * niter)


def svd_via_lanczos(Z: jnp.ndarray, k: int, key: jax.Array | None = None,
                    niter: int | None = None) -> LanczosResult:
    """Convenience wrapper: explicit (single-rank) Z."""
    return lanczos_bidiag(
        lambda x: Z @ x,
        lambda u: Z.T @ u,
        Z.shape[0],
        Z.shape[1],
        k,
        niter=niter,
        key=key,
    )
