"""Matrix-free Lanczos (Golub–Kahan) bidiagonalization for the SVD step.

The paper's framework performs the SVD of the penultimate matrix Z_(n)
(L_n x K_hat_n) through an *oracle model*: the method only ever asks for the
two products  x_out = Z @ x_in  and  y_out = y_in @ Z.  This file implements
the driver; callers supply the oracle as a pair of closures, which is what
lets the distributed runtime answer queries with local matmuls + collectives
(paper §3 'SVD Component').

This is the repo's ONE Lanczos implementation. ``gk_bidiag`` is the single
GK body; the u-space (left/row space) may be *sharded* over a named mesh
axis, in which case every u-space inner product and the breakdown-restart
key go through that axis (``axis="ranks"`` is what the distributed boundary
backend passes from inside ``shard_map``). With ``axis=None`` the body
reduces to the classic replicated driver. ``svd_from_bidiag`` owns the
shared small-SVD + rank-deficiency completion postlude, space-aware the
same way.

Per the paper (§7.1, following SLEPc), we run ``2*K`` bidiagonalization
iterations for K requested singular vectors, i.e. ``Q_n = 4*K`` oracle
queries. Full (two-pass CGS) reorthogonalization keeps float32 stable.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["LanczosResult", "lanczos_bidiag", "svd_via_lanczos",
           "gk_bidiag", "gk_block_bidiag", "svd_from_bidiag",
           "lanczos_niter", "effective_block_size", "block_start_panel"]

_EPS = 1e-30


class LanczosResult(NamedTuple):
    left_vectors: jnp.ndarray  # (nrows, k) leading left singular vectors
    singular_values: jnp.ndarray  # (k,)
    n_queries: int  # oracle queries consumed (Q_n in the paper)


def lanczos_niter(k: int, nrows: int, ncols: int, block_size: int = 1) -> int:
    """The paper/SLEPc iteration count, clamped to the operator's rank cap.

    Shared by the local driver and the distributed mode steps so both sides
    of the engine issue the same number of oracle queries (a precondition
    for their trajectories to coincide at P=1).

    With ``block_size = s > 1`` the count is in *block* iterations: each
    iteration services ``s`` Krylov directions per oracle pass, so the
    vector-iteration budget shrinks to ``ceil(base / s)`` blocks (the last
    block may overshoot the rank cap; breakdown restarts absorb the tail).
    """
    base = int(min(2 * k, nrows, ncols))
    if block_size <= 1:
        return base
    s = min(int(block_size), max(base, 1))
    return -(-base // s)


def effective_block_size(
    k: int, nrows: int, ncols: int, block_size: int
) -> int:
    """Clamp a requested panel width to the operator's vector-iteration
    budget, so a tail panel never exceeds the Krylov directions available
    (``s <= min(2k, nrows, ncols) <= ncols`` keeps the start panel
    column-independent)."""
    base = lanczos_niter(k, nrows, ncols)
    return max(1, min(int(block_size), base))


def block_start_panel(key: jax.Array, ncols: int, block_size: int) -> jnp.ndarray:
    """Deterministic orthonormal start panel V_1 (ncols, s).

    Derived from ``fold_in(key, 3)`` — the same stream the vector driver
    uses for v0 — so the fused Z-build stage and the block driver agree on
    the first panel without communicating.
    """
    g = jax.random.normal(
        jax.random.fold_in(key, 3), (ncols, block_size), jnp.float32
    )
    q, _ = jnp.linalg.qr(g)
    return q


def _space_reduce(axis: str | None) -> Callable[[jnp.ndarray], jnp.ndarray]:
    if axis is None:
        return lambda x: x
    return lambda x: jax.lax.psum(x, axis)


def gk_bidiag(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    rmatvec: Callable[[jnp.ndarray], jnp.ndarray],
    dim_u: int,
    ncols: int,
    niter: int,
    key: jax.Array,
    axis: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The GK bidiagonalization body — the repo's one Lanczos sweep.

    ``dim_u`` is the (per-device, when ``axis`` is set) left-space dimension.
    With ``axis`` given, u-space inner products are ``psum`` over that mesh
    axis and each device draws distinct breakdown-restart directions (the
    concatenation over devices is the global restart vector). The v-space
    (K_hat) is always replicated. Returns ``(U, B)`` with ``B`` upper
    bidiagonal: ``Z V = U B``.
    """
    _ps = _space_reduce(axis)
    dtype = jnp.float32
    V = jnp.zeros((ncols, niter), dtype)  # right Lanczos vectors
    U = jnp.zeros((dim_u, niter), dtype)  # left Lanczos vectors
    alphas = jnp.zeros((niter,), dtype)
    betas = jnp.zeros((niter,), dtype)  # betas[i] couples step i -> i+1

    ku = jax.random.fold_in(key, 17)
    if axis is not None:  # per-device distinct restart directions
        ku = jax.random.fold_in(ku, jax.lax.axis_index(axis))
    kv = jax.random.fold_in(key, 29)
    r_u = jax.random.normal(ku, (dim_u, niter), dtype)  # breakdown restarts
    r_v = jax.random.normal(kv, (ncols, niter), dtype)

    v0 = jax.random.normal(jax.random.fold_in(key, 3), (ncols,), dtype)
    v0 = v0 / (jnp.linalg.norm(v0) + _EPS)

    def u_reorth(u, basis):
        # CGS2 ("twice is enough"); zero columns of the preallocated basis
        # contribute nothing, so a full static-shaped matmul is safe
        for _ in range(2):
            u = u - basis @ _ps(basis.T @ u)
        return u

    def v_reorth(w, basis):
        for _ in range(2):
            w = w - basis @ (basis.T @ w)
        return w

    def body(i, carry):
        U, V, alphas, betas, v, u_prev, beta_prev, scale = carry
        V = V.at[:, i].set(v)
        u = matvec(v) - beta_prev * u_prev
        u = u_reorth(u, U)
        alpha = jnp.sqrt(_ps(jnp.sum(u * u)))
        scale = jnp.maximum(scale, alpha)
        # Lucky breakdown: restart with a fresh direction, record alpha = 0
        # so the restart never mixes into the computed singular vectors.
        ok = alpha > 1e-6 * scale
        u_new = u_reorth(r_u[:, i], U)
        u_new = u_new / (jnp.sqrt(_ps(jnp.sum(u_new * u_new))) + _EPS)
        u = jnp.where(ok, u / (alpha + _EPS), u_new)
        alpha = jnp.where(ok, alpha, 0.0)
        U = U.at[:, i].set(u)
        alphas = alphas.at[i].set(alpha)

        w = rmatvec(u) - alpha * v
        w = v_reorth(w, V)
        beta = jnp.linalg.norm(w)
        scale = jnp.maximum(scale, beta)
        ok_b = beta > 1e-6 * scale
        v_new = v_reorth(r_v[:, i], V)
        v_new = v_new / (jnp.linalg.norm(v_new) + _EPS)
        v = jnp.where(ok_b, w / (beta + _EPS), v_new)
        beta = jnp.where(ok_b, beta, 0.0)
        betas = betas.at[i].set(beta)
        return (U, V, alphas, betas, v, u, beta, scale)

    carry = (U, V, alphas, betas, v0, jnp.zeros((dim_u,), dtype),
             jnp.array(0.0, dtype), jnp.array(_EPS, dtype))
    U, V, alphas, betas, *_ = jax.lax.fori_loop(0, niter, body, carry)

    # Z V = U B with B *upper* bidiagonal: alphas on the diagonal, betas on
    # the superdiagonal (Z v_{i+1} = beta_i u_i + alpha_{i+1} u_{i+1}).
    B = jnp.diag(alphas) + jnp.diag(betas[:-1], k=1)
    return U, B


def gk_block_bidiag(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    rmatvec: Callable[[jnp.ndarray], jnp.ndarray],
    dim_u: int,
    ncols: int,
    niter: int,
    block_size: int,
    key: jax.Array,
    axis: str | None = None,
    first_panel: jnp.ndarray | None = None,
    first_product: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Block (s-step) GK bidiagonalization: ``Z V = U B`` with B banded.

    ``niter`` counts *block* iterations; matvec/rmatvec consume and produce
    ``(., s)`` panels, so each oracle pass over Z services ``s`` Krylov
    directions. The returned ``U`` is ``(dim_u, niter*s)`` and ``B`` is the
    block upper bidiagonal ``(niter*s, niter*s)`` matrix with the panel-QR
    triangular factors ``A_i`` on the diagonal blocks and ``B_{i-1}^T`` on
    the superdiagonal blocks — ``svd_from_bidiag`` consumes it unchanged.

    ``first_panel``/``first_product`` let an upstream stage hand over the
    start panel ``V_1`` (any orthonormal ``(ncols, s)`` panel, replicated
    across devices) and optionally the already-computed product
    ``Z @ V_1``. Two producers use the seam: the fused Z-build stage passes
    exactly ``block_start_panel(key, ncols, block_size)`` (the default, so
    resumed and cold drivers walk the same Krylov space), and the sketched
    warm start (``core/sketch.py``) passes a randomized range-finder panel
    seeded by the previous factors, so the driver only *refines* an
    already-good subspace. Space-awareness matches ``gk_bidiag``: with
    ``axis`` set, the u-space is sharded and all u inner products psum over
    the mesh axis.
    """
    _ps = _space_reduce(axis)
    dtype = jnp.float32
    s = int(block_size)
    m = int(niter)
    total = m * s

    ku = jax.random.fold_in(key, 17)
    if axis is not None:  # per-device distinct restart directions
        ku = jax.random.fold_in(ku, jax.lax.axis_index(axis))
    kv = jax.random.fold_in(key, 29)
    r_u = jax.random.normal(ku, (dim_u, total), dtype)  # breakdown restarts
    r_v = jax.random.normal(kv, (ncols, total), dtype)

    if first_panel is None:
        first_panel = block_start_panel(key, ncols, s)

    U = jnp.zeros((dim_u, total), dtype)
    V = jnp.zeros((ncols, total), dtype)
    B = jnp.zeros((total, total), dtype)

    def panel_reorth(W, basis, reduce_fn):
        # CGS2 against the full preallocated basis; zero columns are inert
        for _ in range(2):
            W = W - basis @ reduce_fn(basis.T @ W)
        return W

    def panel_qr(W, basis, restarts, reduce_fn, scale):
        """Column-MGS QR of the panel with per-column breakdown restarts.

        Restart columns get a fresh direction orthogonal to ``basis`` and
        the panel built so far, with a zero diagonal R entry so they never
        mix into the computed singular vectors (same contract as the vector
        driver's lucky-breakdown handling).
        """
        cols = []
        R = jnp.zeros((s, s), dtype)
        for j in range(s):
            w = W[:, j]
            for _pass in range(2):  # MGS twice within the panel
                for jj in range(j):
                    r = reduce_fn(jnp.sum(cols[jj] * w))
                    w = w - r * cols[jj]
                    R = R.at[jj, j].add(r)
            nrm = jnp.sqrt(reduce_fn(jnp.sum(w * w)))
            scale = jnp.maximum(scale, nrm)
            ok = nrm > 1e-6 * scale
            c = restarts[:, j]
            for _pass in range(2):
                c = c - basis @ reduce_fn(basis.T @ c)
                for jj in range(j):
                    c = c - reduce_fn(jnp.sum(cols[jj] * c)) * cols[jj]
            c = c / (jnp.sqrt(reduce_fn(jnp.sum(c * c))) + _EPS)
            q = jnp.where(ok, w / (nrm + _EPS), c)
            R = R.at[j, j].set(jnp.where(ok, nrm, 0.0))
            cols.append(q)
        return jnp.stack(cols, axis=1), R, scale

    _id = lambda x: x  # noqa: E731 — v-space is replicated
    Vi = first_panel
    Uprev = jnp.zeros((dim_u, s), dtype)
    Bprev = jnp.zeros((s, s), dtype)
    scale = jnp.array(_EPS, dtype)
    for i in range(m):
        V = jax.lax.dynamic_update_slice(V, Vi, (0, i * s))
        # Z V_i = U_{i-1} B_{i-1}^T + U_i A_i
        ZV = first_product if (i == 0 and first_product is not None) \
            else matvec(Vi)
        W = ZV - Uprev @ Bprev.T
        W = panel_reorth(W, U, _ps)
        Ui, Ai, scale = panel_qr(W, U, r_u[:, i * s:(i + 1) * s], _ps, scale)
        U = jax.lax.dynamic_update_slice(U, Ui, (0, i * s))
        B = jax.lax.dynamic_update_slice(B, Ai, (i * s, i * s))

        # Z^T U_i = V_i A_i^T + V_{i+1} B_i
        G = rmatvec(Ui) - Vi @ Ai.T
        G = panel_reorth(G, V, _id)
        Vn, Bi, scale = panel_qr(G, V, r_v[:, i * s:(i + 1) * s], _id, scale)
        if i + 1 < m:
            B = jax.lax.dynamic_update_slice(B, Bi.T, (i * s, (i + 1) * s))
        Uprev, Bprev, Vi = Ui, Bi, Vn
    return U, B


def _complete_columns(
    left: jnp.ndarray, m: int, key: jax.Array, axis: str | None
) -> jnp.ndarray:
    """Append ``m`` orthonormal columns to ``left`` (rank-deficient edge).

    Column-by-column CGS2 with space-aware inner products, so the completed
    basis is globally orthonormal even when the rows are sharded.
    """
    _ps = _space_reduce(axis)
    key = jax.random.fold_in(key, 1)
    if axis is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axis))
    extra = jax.random.normal(key, (left.shape[0], m), left.dtype)
    basis = left
    for j in range(m):
        c = extra[:, j]
        for _ in range(2):
            c = c - basis @ _ps(basis.T @ c)
        c = c / (jnp.sqrt(_ps(jnp.sum(c * c))) + _EPS)
        basis = jnp.concatenate([basis, c[:, None]], axis=1)
    return basis


def svd_from_bidiag(
    U: jnp.ndarray,
    B: jnp.ndarray,
    k: int,
    key: jax.Array,
    axis: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Left singular vectors from the GK output: SVD of the small bidiagonal
    matrix, projected through U, completed to ``k`` orthonormal columns when
    the iteration count could not reach ``k`` (rank-deficient operators)."""
    P, S, _ = jnp.linalg.svd(B, full_matrices=False)
    niter = int(B.shape[0])
    kk = min(k, niter)
    left = U @ P[:, :kk]
    if kk < k:
        left = _complete_columns(left, k - kk, key, axis)
        S = jnp.concatenate([S[:kk], jnp.zeros((k - kk,), S.dtype)])
    return left, S[:k]


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _lanczos_impl(matvec, rmatvec, nrows, ncols, niter, key):
    """Jitted replicated instantiation of the shared body."""
    return gk_bidiag(matvec, rmatvec, nrows, ncols, niter, key, axis=None)


def lanczos_bidiag(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    rmatvec: Callable[[jnp.ndarray], jnp.ndarray],
    nrows: int,
    ncols: int,
    k: int,
    niter: int | None = None,
    key: jax.Array | None = None,
) -> LanczosResult:
    """Leading-k left singular vectors of the oracle matrix Z.

    matvec : x (ncols,) -> Z @ x (nrows,)
    rmatvec: u (nrows,) -> Z.T @ u (ncols,)
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    if niter is None:
        niter = lanczos_niter(k, nrows, ncols)
    else:
        niter = int(min(niter, nrows, ncols))
        niter = max(niter, min(k, nrows, ncols))
    U, B = _lanczos_impl(matvec, rmatvec, nrows, ncols, niter, key)
    left, S = svd_from_bidiag(U, B, k, key, axis=None)
    return LanczosResult(left, S, n_queries=2 * niter)


def svd_via_lanczos(Z: jnp.ndarray, k: int, key: jax.Array | None = None,
                    niter: int | None = None) -> LanczosResult:
    """Convenience wrapper: explicit (single-rank) Z."""
    return lanczos_bidiag(
        lambda x: Z @ x,
        lambda u: Z.T @ u,
        Z.shape[0],
        Z.shape[1],
        k,
        niter=niter,
        key=key,
    )
