"""Sparse tensor container in coordinate (COO) format.

The paper's framework (Kaya & Uçar [15]) represents the input sparse tensor as a
set of non-zero *elements*, each a coordinate vector plus a value. We keep the
host-side representation in numpy (partitioning is a host-side, real-time
algorithm in the paper) and convert per-device shards to jax arrays at the
runtime boundary.

A mode-n *slice* is the set of elements sharing the n-th coordinate. Slice
cardinality histograms drive every distribution scheme, so they are first-class
here.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Sequence

import numpy as np

__all__ = ["SparseTensor", "read_tns", "write_tns"]


@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """N-dimensional sparse tensor in COO format.

    Attributes:
      coords: int32/int64 array of shape (nnz, N); 0-based coordinates.
      values: float array of shape (nnz,).
      shape:  tuple of N mode lengths (L_1, ..., L_N).
    """

    coords: np.ndarray
    values: np.ndarray
    shape: tuple[int, ...]

    def __post_init__(self):
        coords = np.asarray(self.coords)
        values = np.asarray(self.values)
        if coords.ndim != 2:
            raise ValueError(f"coords must be 2-D (nnz, N), got {coords.shape}")
        if values.ndim != 1 or values.shape[0] != coords.shape[0]:
            raise ValueError(
                f"values must be 1-D with len == nnz, got {values.shape} vs "
                f"{coords.shape[0]} coords"
            )
        if len(self.shape) != coords.shape[1]:
            raise ValueError(
                f"shape has {len(self.shape)} modes but coords has {coords.shape[1]}"
            )
        if coords.size and (coords.min() < 0):
            raise ValueError("coordinates must be non-negative")
        for n, L in enumerate(self.shape):
            if coords.size and int(coords[:, n].max()) >= L:
                raise ValueError(
                    f"mode-{n} coordinate {int(coords[:, n].max())} out of bounds "
                    f"for length {L}"
                )
        object.__setattr__(self, "coords", coords)
        object.__setattr__(self, "values", values)
        object.__setattr__(self, "shape", tuple(int(L) for L in self.shape))

    # ---------------------------------------------------------------- basic
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def nnz(self) -> int:
        return int(self.coords.shape[0])

    @property
    def sparsity(self) -> float:
        total = float(np.prod([float(L) for L in self.shape]))
        return self.nnz / total if total else 0.0

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"SparseTensor(shape={self.shape}, nnz={self.nnz}, "
            f"sparsity={self.sparsity:.2e})"
        )

    # ------------------------------------------------------------- slicing
    def slice_sizes(self, mode: int) -> np.ndarray:
        """Cardinality |Slice_n^l| for every l in [0, L_n)."""
        return np.bincount(self.coords[:, mode], minlength=self.shape[mode])

    def nonempty_slices(self, mode: int) -> np.ndarray:
        """Indices l with |Slice_n^l| > 0."""
        return np.nonzero(self.slice_sizes(mode))[0]

    def sorted_by_mode(self, mode: int) -> "SparseTensor":
        """Elements stably sorted by their mode-n coordinate."""
        order = np.argsort(self.coords[:, mode], kind="stable")
        return SparseTensor(self.coords[order], self.values[order], self.shape)

    def permute_mode(self, mode: int, perm: np.ndarray) -> "SparseTensor":
        """Relabel mode-n indices: new coordinate = perm[old coordinate]."""
        coords = self.coords.copy()
        coords[:, mode] = np.asarray(perm)[coords[:, mode]]
        return SparseTensor(coords, self.values, self.shape)

    # --------------------------------------------------------------- dense
    def todense(self) -> np.ndarray:
        """Materialize as a dense numpy array (tests / small tensors only)."""
        total = int(np.prod(self.shape))
        if total > 200_000_000:
            raise MemoryError(f"refusing to densify {self.shape}")
        out = np.zeros(self.shape, dtype=np.float64)
        np.add.at(out, tuple(self.coords.T), self.values)
        return out

    @staticmethod
    def fromdense(arr: np.ndarray, tol: float = 0.0) -> "SparseTensor":
        mask = np.abs(arr) > tol
        coords = np.argwhere(mask)
        values = arr[mask].astype(np.float64)
        return SparseTensor(coords, values, arr.shape)

    def dedup(self) -> "SparseTensor":
        """Merge duplicate coordinates (sum values)."""
        flat = np.ravel_multi_index(tuple(self.coords.T), self.shape)
        uniq, inv = np.unique(flat, return_inverse=True)
        vals = np.zeros(len(uniq), dtype=self.values.dtype)
        np.add.at(vals, inv, self.values)
        coords = np.stack(np.unravel_index(uniq, self.shape), axis=1)
        return SparseTensor(coords, vals, self.shape)

    def norm(self) -> float:
        return float(np.linalg.norm(self.values))

    def fingerprint(self) -> str:
        """Content hash of (shape, coords, values) — stable across processes.

        Memoized on the instance (coords/values are treated as immutable, as
        everywhere else in the codebase). This is the cache key used by
        repro.core.plan to reuse partition work across HOOI/benchmark calls.
        """
        cached = getattr(self, "_fingerprint", None)
        if cached is not None:
            return cached
        h = hashlib.sha1()
        h.update(repr(self.shape).encode())
        h.update(np.ascontiguousarray(self.coords).tobytes())
        h.update(np.ascontiguousarray(self.values).tobytes())
        fp = h.hexdigest()
        object.__setattr__(self, "_fingerprint", fp)
        return fp

    # -------------------------------------------------------------- select
    def take(self, idx: np.ndarray) -> "SparseTensor":
        return SparseTensor(self.coords[idx], self.values[idx], self.shape)


# ------------------------------------------------------------------ FROSTT IO
def read_tns(path: str) -> SparseTensor:
    """Read a FROSTT ``.tns`` file (1-based coords, whitespace separated)."""
    rows = np.loadtxt(path, dtype=np.float64, ndmin=2, comments=("#", "%"))
    coords = rows[:, :-1].astype(np.int64) - 1
    values = rows[:, -1]
    shape = tuple(int(coords[:, n].max()) + 1 for n in range(coords.shape[1]))
    return SparseTensor(coords, values, shape)


def write_tns(path: str, t: SparseTensor) -> None:
    with open(path, "w") as f:
        for c, v in zip(t.coords, t.values):
            f.write(" ".join(str(int(x) + 1) for x in c)
                    + f" {float(v)!r}\n")
