"""Stochastic-refine sampling: deterministic minibatches for streamed appends.

The refresh ladder's fourth rung (``stochastic-refine``) updates factors
from a *sample* of a streamed append's elements instead of a full O(nnz)
sweep — the SGD_Tucker observation (arXiv 2012.03550) that factor updates
from sampled nnz subsets converge at a fraction of the cost, grafted onto
this repo's engine seams. This module owns everything that must be
*bitwise deterministic* about that: which elements enter a minibatch, how
the replay reservoir revisits the already-refined prefix, the step-size
schedule, and the factor blend.

Determinism contract: every selection is a pure function of
``(absolute element index, seed)`` through a splitmix64-style hash — the
same keyed-hash family ``engine.objective.holdout_mask`` uses. The two
consumers draw from **domain-separated key streams** (a per-use additive
constant mixed into the hash input), so the holdout split and the training
sampler are statistically independent even under identical seeds; the
holdout stream keeps the historical domain 0, so existing masks are
bitwise unchanged. Appending batches never reshuffles earlier decisions
(per-index hashing, like the holdout mask), and a fixed seed + fixed
append schedule reproduces the exact sampled indices.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "HOLDOUT_DOMAIN",
    "SAMPLE_DOMAIN",
    "RESERVOIR_DOMAIN",
    "splitmix64",
    "sample_unit",
    "sample_batch",
    "next_pow2",
    "SampledBatch",
    "step_eta",
    "blend_factor",
]

# Domain constants: additive 64-bit offsets mixed into the hash input so
# each consumer draws an independent key stream from the same (index, seed)
# pair. HOLDOUT_DOMAIN is 0 — the historical ``holdout_mask`` stream, kept
# bitwise so existing completion splits (and the plans/caches keyed on
# them) are unchanged. The other domains are arbitrary odd constants,
# distinct from 0 and from each other; a collision would correlate the
# holdout split with the training sampler (held-out entries would be
# preferentially re-sampled whenever seeds align).
HOLDOUT_DOMAIN = 0
SAMPLE_DOMAIN = 0xA5A5F00D5EEDC0DE
RESERVOIR_DOMAIN = 0x3C6EF372FE94F82B

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_SEED_MIX = np.uint64(0xD1B54A32D192ED03)


def splitmix64(idx, seed: int, domain: int = 0) -> np.ndarray:
    """Vectorized splitmix64 finalizer over ``idx * GOLDEN + seed * MIX +
    domain`` — the one keyed-hash primitive behind every deterministic
    per-element decision (holdout masks, minibatch sampling, the replay
    reservoir). ``domain=0`` reproduces the historical holdout stream
    bit-for-bit."""
    with np.errstate(over="ignore"):
        z = (np.asarray(idx, dtype=np.uint64) * _GOLDEN
             + np.uint64(int(seed) % (1 << 64)) * _SEED_MIX
             + np.uint64(int(domain) % (1 << 64)))
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def sample_unit(idx, seed: int, domain: int = 0) -> np.ndarray:
    """Uniform [0, 1) variates from the keyed hash (53-bit mantissa)."""
    z = splitmix64(idx, seed, domain)
    return (z >> np.uint64(11)).astype(np.float64) / float(1 << 53)


@dataclasses.dataclass(frozen=True)
class SampledBatch:
    """One deterministic minibatch: replay reservoir + sampled new entries.

    ``indices`` are absolute element indices into the source view (replay
    entries first, then the sampled new-batch entries, both in ascending
    index order within their group) — the audit trail the property tests
    assert bitwise. ``coords``/``values`` are the gathered elements,
    zero-padded to ``padded_nnz`` (next power of two) so nearby batch
    sizes share one compiled step: padding rows carry coordinate 0 and
    value 0.0, which contribute nothing to a scatter-add Z build.
    """

    indices: np.ndarray  # (S,) int64 absolute indices, replay then new
    coords: np.ndarray  # (padded_nnz, N) int64
    values: np.ndarray  # (padded_nnz,) float64
    sample_nnz: int  # sampled new-batch entries
    replay_nnz: int  # replay-reservoir entries
    padded_nnz: int


def next_pow2(n: int) -> int:
    """Smallest power of two >= n — the shared pad granularity for every
    shape that keys a compiled stochastic-path computation."""
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


_next_pow2 = next_pow2


def sample_batch(coords: np.ndarray, values: np.ndarray, covered: int,
                 fraction: float, seed: int,
                 replay_nnz: int = 1024) -> SampledBatch:
    """Build the stochastic-refine minibatch for one streamed append.

    ``covered`` is the number of leading elements already incorporated
    into the factors (by full sweeps or earlier refines); the *new batch*
    is everything after it. Selection is per absolute index — element
    ``i >= covered`` enters iff ``sample_unit(i, seed, SAMPLE_DOMAIN) <
    fraction`` — so appending further batches never changes which earlier
    elements were sampled. The replay reservoir draws ``min(replay_nnz,
    covered)`` counter-based indices from the refined prefix
    (``splitmix64(j, seed, RESERVOIR_DOMAIN) % covered``, ``j`` a draw
    counter), anchoring the minibatch update against drift away from the
    already-fit prefix. ``fraction >= 1`` takes the whole new batch.
    """
    coords = np.asarray(coords)
    values = np.asarray(values)
    nnz = int(coords.shape[0])
    covered = min(max(int(covered), 0), nnz)
    if not 0.0 < float(fraction) <= 1.0:
        raise ValueError(
            f"sample fraction must be in (0, 1], got {fraction}")

    new_idx = np.arange(covered, nnz, dtype=np.int64)
    if float(fraction) < 1.0 and len(new_idx):
        keep = sample_unit(new_idx, seed, SAMPLE_DOMAIN) < float(fraction)
        new_idx = new_idx[keep]

    n_replay = min(max(int(replay_nnz), 0), covered)
    if n_replay:
        draws = splitmix64(np.arange(n_replay, dtype=np.uint64), seed,
                           RESERVOIR_DOMAIN)
        replay_idx = np.sort((draws % np.uint64(covered)).astype(np.int64))
    else:
        replay_idx = np.zeros(0, dtype=np.int64)

    indices = np.concatenate([replay_idx, new_idx])
    padded = _next_pow2(max(len(indices), 1))
    pc = np.zeros((padded, coords.shape[1]), dtype=np.int64)
    pv = np.zeros(padded, dtype=np.float64)
    pc[: len(indices)] = coords[indices]
    pv[: len(indices)] = values[indices]
    return SampledBatch(indices=indices, coords=pc, values=pv,
                        sample_nnz=int(len(new_idx)),
                        replay_nnz=int(n_replay), padded_nnz=int(padded))


def step_eta(base: float, decay: float, step_index: int) -> float:
    """Per-refine step size: ``base / (1 + decay * t)`` — the classic
    Robbins-Monro-style decay, reset whenever a full correction sweep
    re-anchors the factors (``step_index`` counts refines since the last
    full sweep)."""
    return float(base) / (1.0 + float(decay) * max(int(step_index), 0))


def _blend_impl(F_old, F_hat, eta):
    import jax.numpy as jnp

    F_old = jnp.asarray(F_old)
    F_hat = jnp.asarray(F_hat)
    u, _, vt = jnp.linalg.svd(F_hat.T @ F_old, full_matrices=False)
    aligned = F_hat @ (u @ vt)
    mix = (1.0 - eta) * F_old + eta * aligned
    q, r = jnp.linalg.qr(mix)
    # sign-fix the QR so the blend is continuous in eta (qr's sign choice
    # flips with the data otherwise)
    signs = jnp.sign(jnp.diagonal(r))
    return q * jnp.where(signs == 0, 1.0, signs)[None, :]


_blend_jit = None


def blend_factor(F_old, F_hat, eta: float):
    """Blend the minibatch oracle's basis into the carried factor.

    An oracle solve is only defined up to column rotation/sign, so a naive
    convex combination can *cancel* matched directions. The blend first
    aligns ``F_hat`` to ``F_old`` by the orthogonal Procrustes rotation
    (``R = U Vᵀ`` from the K×K SVD of ``F_hatᵀ F_old`` — O(K³), trivial
    next to the solve), then re-orthonormalizes the stepped combination::

        Q, _ = qr((1 - eta) · F_old + eta · F_hat R)

    ``eta = 1`` adopts the aligned minibatch basis outright; ``eta -> 0``
    keeps the carried factor. Returns an orthonormal (L, K) factor.

    Jitted on first use (``eta`` traced, so the step-size decay never
    recompiles): the chain is a handful of tiny ops, and per-refine eager
    dispatch would otherwise dominate the whole minibatch pass.
    """
    global _blend_jit
    if _blend_jit is None:
        import jax

        _blend_jit = jax.jit(_blend_impl)
    return _blend_jit(F_old, F_hat, float(eta))
