"""Performance metrics for distribution schemes (paper §4).

Per mode n, for a policy pi_n:

  Metric 1  E_max = max_p |E_n^p|                  (TTM load balance)
  Metric 2  R_sum = sum_p R_n^p                    (SVD load + oracle comm)
  Metric 3  R_max = max_p R_n^p                    (SVD load balance)

plus the derived quantities used in the paper's experimental section:
normalized SVD redundancy, oracle communication volume Q_n*(R_sum - L_n),
factor-matrix transfer volume (uni- and multi-policy), FLOP counts and the
memory model of §7.3.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .coo import SparseTensor
from .distribution import Scheme

__all__ = ["ModeMetrics", "SchemeMetrics", "mode_metrics", "scheme_metrics",
           "MetricsExtender"]


@dataclasses.dataclass(frozen=True)
class ModeMetrics:
    mode: int
    P: int
    nnz: int
    L: int  # mode length
    L_nonempty: int  # non-empty slices (empty slices have no sharers)
    E_max: int
    E_avg: float
    R_sum: int
    R_max: int
    R_avg: float

    # ------- derived (paper §4.2, §7.2) -------
    @property
    def ttm_imbalance(self) -> float:
        """max/avg element load; 1.0 is perfect (paper Fig 12a)."""
        return self.E_max / max(self.E_avg, 1e-12)

    @property
    def svd_redundancy(self) -> float:
        """R_sum normalized by optimal L_nonempty; 1.0 is optimal (Fig 12b)."""
        return self.R_sum / max(self.L_nonempty, 1)

    @property
    def svd_imbalance(self) -> float:
        """max/avg local penultimate rows; 1.0 is perfect (Fig 12c)."""
        return self.R_max / max(self.R_avg, 1e-12)

    def oracle_comm_per_query(self) -> int:
        """Units (scalars) moved per Lanczos matrix-vector product (§4.2)."""
        return self.R_sum - self.L_nonempty


@dataclasses.dataclass(frozen=True)
class SchemeMetrics:
    scheme: str
    P: int
    per_mode: tuple[ModeMetrics, ...]
    core_dims: tuple[int, ...]
    fm_volume: int  # factor-matrix transfer units, all modes (§4.2)
    svd_volume: int  # oracle comm units, all modes, all queries

    # FLOP model (§4.3): TTM = nnz * prod_{j != n} K_j mults (+adds) per mode;
    # SVD oracle = Q_n * K_hat_n * R_sum per mode (x2 for the two products).
    ttm_flops: int
    svd_flops: int
    ttm_flops_max: int  # on the bottleneck rank (determines wall time)
    svd_flops_max: int

    @property
    def total_flops(self) -> int:
        return self.ttm_flops + self.svd_flops

    @property
    def critical_path_flops(self) -> int:
        return self.ttm_flops_max + self.svd_flops_max

    def memory_bytes_per_rank(self, value_bytes: int = 8, coord_bytes: int = 8) -> dict:
        """Paper §7.3 memory model: tensor copies + penultimate + factors."""
        mm = self.per_mode
        N = len(mm)
        copies = 1 if self.scheme in ("medium", "hypergraph", "random") else N
        elem_bytes = value_bytes + coord_bytes * N
        tensor = copies * max(m.E_max for m in mm) * elem_bytes
        khat = [int(np.prod([self.core_dims[j] for j in range(N) if j != n]))
                for n in range(N)]
        penult = sum(mm[n].R_max * khat[n] * value_bytes for n in range(N))
        factors = sum(mm[n].L * self.core_dims[n] * value_bytes for n in range(N))
        return {
            "tensor": int(tensor),
            "penultimate": int(penult),
            "factors": int(factors),
            "total": int(tensor + penult + factors),
        }


def _r_per_rank(t: SparseTensor, policy: np.ndarray, mode: int, P: int) -> np.ndarray:
    """R_n^p for all p: number of distinct slices each rank shares."""
    pair = t.coords[:, mode].astype(np.int64) * P + policy
    uniq = np.unique(pair)
    ranks = (uniq % P).astype(np.int64)
    return np.bincount(ranks, minlength=P)


def mode_metrics(t: SparseTensor, policy: np.ndarray, mode: int, P: int) -> ModeMetrics:
    counts = np.bincount(policy, minlength=P)
    r = _r_per_rank(t, policy, mode, P)
    L_ne = int((t.slice_sizes(mode) > 0).sum())
    return ModeMetrics(
        mode=mode,
        P=P,
        nnz=t.nnz,
        L=t.shape[mode],
        L_nonempty=L_ne,
        E_max=int(counts.max()) if len(counts) else 0,
        E_avg=t.nnz / P,
        R_sum=int(r.sum()),
        R_max=int(r.max()) if len(r) else 0,
        R_avg=float(r.sum()) / P,
    )


def _fm_volume(t: SparseTensor, scheme: Scheme, core: Sequence[int]) -> int:
    """Factor-matrix transfer volume (paper §4.2).

    Row F_n[l,:] must reach every rank that owns an element of Slice_n^l under
    any policy pi_j, j != n (for uni-policy this reduces to sharers of the
    slice). The producing owner sigma_n(l) is one of the sharers under pi_n;
    we charge (|need(l)| - 1) rows of K_n entries, clamped at >= 0, using the
    best case that the owner is itself a needer.
    """
    from .distribution import row_owner_map

    total = 0
    N = t.ndim
    for n in range(N):
        L = t.shape[n]
        slc = t.coords[:, n].astype(np.int64)
        need_pairs = []
        for j in range(N):
            if j == n:
                continue
            need_pairs.append(slc * scheme.P + scheme.policy(j))
        pairs = np.unique(np.concatenate(need_pairs))
        # subtract one per slice for the producing owner if it is a needer
        sigma = row_owner_map(t, scheme.policy(n), n, scheme.P)
        slices_in_pairs = (pairs // scheme.P).astype(np.int64)
        ranks_in_pairs = (pairs % scheme.P).astype(np.int64)
        owner_hit = sigma[slices_in_pairs] == ranks_in_pairs
        rows_to_send = len(pairs) - int(owner_hit.sum())
        total += rows_to_send * int(core[n])
    return total


def scheme_metrics(
    t: SparseTensor,
    scheme: Scheme,
    core: Sequence[int],
    lanczos_queries: Sequence[int] | None = None,
) -> SchemeMetrics:
    """Aggregate §4 metrics for a scheme over all modes.

    ``lanczos_queries``: Q_n per mode; defaults to 4*K_n (2K_n Lanczos
    iterations, two oracle products each — paper §4.3 / SLEPc convention).
    """
    N = t.ndim
    core = tuple(int(k) for k in core)
    if lanczos_queries is None:
        lanczos_queries = [4 * core[n] for n in range(N)]
    per_mode = tuple(
        mode_metrics(t, scheme.policy(n), n, scheme.P) for n in range(N)
    )
    khat = [int(np.prod([core[j] for j in range(N) if j != n])) for n in range(N)]

    # FLOPs (multiply-accumulate counted as 2 flops)
    ttm = 0
    ttm_max = 0
    svd = 0
    svd_max = 0
    for n in range(N):
        m = per_mode[n]
        # Kronecker contribution of one element: khat[n] mults (+ adds into row)
        ttm += 2 * t.nnz * khat[n]
        ttm_max += 2 * m.E_max * khat[n]
        q = int(lanczos_queries[n])
        svd += q * m.R_sum * khat[n] * 2
        svd_max += q * m.R_max * khat[n] * 2
    svd_vol = sum(
        int(lanczos_queries[n]) * per_mode[n].oracle_comm_per_query()
        for n in range(N)
    )
    fm_vol = _fm_volume(t, scheme, core)
    return SchemeMetrics(
        scheme=scheme.name,
        P=scheme.P,
        per_mode=per_mode,
        core_dims=core,
        fm_volume=int(fm_vol),
        svd_volume=int(svd_vol),
        ttm_flops=int(ttm),
        svd_flops=int(svd),
        ttm_flops_max=int(ttm_max),
        svd_flops_max=int(svd_max),
    )


class MetricsExtender:
    """Incrementally maintained ``SchemeMetrics`` under streaming appends.

    A full ``scheme_metrics`` recompute is O(nnz * N^2) host work — paid on
    every batch, it would defeat the streaming scheduler's "repartition is
    O(batch)" contract. This class pays that cost *once* (at plan adoption)
    to build per-mode incremental state, then ``extend`` folds a batch of
    appended elements in O(batch * N^2) and returns metrics **identical** to
    a from-scratch recompute on the extended scheme (same tie-breaks, same
    integer arithmetic — asserted by the equivalence test).

    Per-mode state and how each §4 quantity extends:

      * element counts per rank  -> E_max   (bincount of the new policy tail)
      * (slice, rank) pair counts -> R_sum/R_max (a pair new to the dict
        means that rank shares one more distinct slice)
      * per-slice nnz            -> L_nonempty (0 -> positive transitions)
      * live ``row_owner_map`` argmax: the owner of slice l is the rank with
        the lexicographically greatest (count, rank) among sharers — counts
        only grow, so the argmax can only move to a pair the batch touched
      * fm need-set (slice*P + rank pairs over policies j != n) plus a
        per-slice "owner is a needer" flag -> fm_volume; only slices touched
        by the batch can change their flag, so the update stays O(batch).

    Duplicate coordinates count as distinct elements, exactly as
    ``scheme_metrics`` counts them (streaming value-updates append dups).
    """

    def __init__(self, t: SparseTensor, scheme: Scheme,
                 core: Sequence[int],
                 lanczos_queries: Sequence[int] | None = None):
        from .distribution import row_owner_map

        N = t.ndim
        P = scheme.P
        self.P = P
        self.shape = tuple(t.shape)
        self.core = tuple(int(k) for k in core)
        self.name = scheme.name
        if lanczos_queries is None:
            lanczos_queries = [4 * self.core[n] for n in range(N)]
        self.queries = tuple(int(q) for q in lanczos_queries)
        self.nnz = t.nnz
        coords = np.asarray(t.coords)
        self._e_per_rank = []
        self._r_per_rank = []
        self._pair_counts: list[dict] = []
        self._owner = []
        self._slice_nnz = []
        self._L_ne = []
        self._fm_pairs: list[set] = []
        self._hit_flags = []
        self._fm_hits = []
        for n in range(N):
            pol = np.asarray(scheme.policy(n))
            slc = coords[:, n].astype(np.int64)
            self._e_per_rank.append(np.bincount(pol, minlength=P)
                                    .astype(np.int64))
            pair = slc * P + pol
            uniq, counts = np.unique(pair, return_counts=True)
            self._pair_counts.append(
                dict(zip(uniq.tolist(), counts.tolist())))
            self._r_per_rank.append(
                np.bincount((uniq % P).astype(np.int64), minlength=P)
                .astype(np.int64))
            self._owner.append(row_owner_map(t, pol, n, P))
            snnz = np.bincount(slc, minlength=t.shape[n]).astype(np.int64)
            self._slice_nnz.append(snnz)
            self._L_ne.append(int((snnz > 0).sum()))
            need = [slc * P + np.asarray(scheme.policy(j))
                    for j in range(N) if j != n]
            fm = np.unique(np.concatenate(need)) if need else \
                np.zeros(0, np.int64)
            self._fm_pairs.append(set(fm.tolist()))
            L = t.shape[n]
            key = np.arange(L, dtype=np.int64) * P + self._owner[n]
            flags = np.isin(key, fm)
            self._hit_flags.append(flags)
            self._fm_hits.append(int(flags.sum()))

    def extend(self, new_coords: np.ndarray, scheme: Scheme) -> SchemeMetrics:
        """Fold ``new_coords`` into the state; ``scheme`` is the *extended*
        scheme (``extend_scheme`` output — its policy tails carry the batch's
        rank assignments). Returns the metrics of the extended state."""
        new_coords = np.asarray(new_coords)
        B = len(new_coords)
        N = len(self.shape)
        P = self.P
        for n in range(N):
            pol_full = np.asarray(scheme.policy(n))
            if len(pol_full) != self.nnz + B:
                raise ValueError(
                    f"mode {n} policy has {len(pol_full)} entries, expected "
                    f"{self.nnz} tracked + {B} appended — scheme is not the "
                    "extension of the tracked state")
            tail = pol_full[self.nnz:].astype(np.int64)
            slc = new_coords[:, n].astype(np.int64)
            self._e_per_rank[n] += np.bincount(tail, minlength=P)
            # distinct (slice, rank) pairs: dict miss -> R grows
            pair = slc * P + tail
            puniq, pcnt = np.unique(pair, return_counts=True)
            pc = self._pair_counts[n]
            for p, c in zip(puniq.tolist(), pcnt.tolist()):
                old = pc.get(p, 0)
                if old == 0:
                    self._r_per_rank[n][p % P] += 1
                pc[p] = old + c
                # live owner argmax: (count, rank) lexicographic, exactly
                # row_owner_map's sort-and-keep-last tie-break
                l, r = p // P, p % P
                o = int(self._owner[n][l])
                if o < 0 or (old + c, r) > (int(pc.get(l * P + o, 0)), o):
                    self._owner[n][l] = r
            snnz = self._slice_nnz[n]
            suniq, scnt = np.unique(slc, return_counts=True)
            self._L_ne[n] += int((snnz[suniq] == 0).sum())
            snnz[suniq] += scnt
            # fm need-set: this element's row must reach its ranks under
            # every other mode's policy
            fm = self._fm_pairs[n]
            for j in range(N):
                if j == n:
                    continue
                tj = np.asarray(scheme.policy(j))[self.nnz:].astype(np.int64)
                fm.update((slc * P + tj).tolist())
            # re-derive the "owner is a needer" flag for touched slices only
            for l in suniq.tolist():
                new_flag = (l * P + int(self._owner[n][l])) in fm
                if new_flag != bool(self._hit_flags[n][l]):
                    self._fm_hits[n] += 1 if new_flag else -1
                    self._hit_flags[n][l] = new_flag
        self.nnz += B
        return self.metrics()

    def metrics(self) -> SchemeMetrics:
        """Assemble ``SchemeMetrics`` from the tracked state — the same
        arithmetic as ``scheme_metrics``, fed from incremental counters."""
        N = len(self.shape)
        per_mode = []
        for n in range(N):
            e = self._e_per_rank[n]
            r = self._r_per_rank[n]
            per_mode.append(ModeMetrics(
                mode=n,
                P=self.P,
                nnz=self.nnz,
                L=self.shape[n],
                L_nonempty=self._L_ne[n],
                E_max=int(e.max()) if len(e) else 0,
                E_avg=self.nnz / self.P,
                R_sum=int(r.sum()),
                R_max=int(r.max()) if len(r) else 0,
                R_avg=float(r.sum()) / self.P,
            ))
        core = self.core
        khat = [int(np.prod([core[j] for j in range(N) if j != n]))
                for n in range(N)]
        ttm = ttm_max = svd = svd_max = 0
        for n in range(N):
            m = per_mode[n]
            ttm += 2 * self.nnz * khat[n]
            ttm_max += 2 * m.E_max * khat[n]
            q = self.queries[n]
            svd += q * m.R_sum * khat[n] * 2
            svd_max += q * m.R_max * khat[n] * 2
        svd_vol = sum(self.queries[n] * per_mode[n].oracle_comm_per_query()
                      for n in range(N))
        fm_vol = sum((len(self._fm_pairs[n]) - self._fm_hits[n]) * core[n]
                     for n in range(N))
        return SchemeMetrics(
            scheme=self.name,
            P=self.P,
            per_mode=tuple(per_mode),
            core_dims=core,
            fm_volume=int(fm_vol),
            svd_volume=int(svd_vol),
            ttm_flops=int(ttm),
            svd_flops=int(svd),
            ttm_flops_max=int(ttm_max),
            svd_flops_max=int(svd_max),
        )
