"""Measured-cost calibration for the real-time scheme selector.

The paper's selector compares candidate schemes with an analytic model:
``total_s = critical_path_flops / flop_rate + comm_bytes / net_bandwidth``.
The *ratios* between candidates are driven by the §4 metrics, but the two
rates decide how flops trade against bytes — and the right trade-off is a
property of the machine, not the paper. This module makes the rates a
first-class ``CostModel`` that can be

  * left at the built-in order-of-magnitude defaults (selection then behaves
    exactly as before),
  * fitted from measured executor sweep times
    (``HooiExecutor.calibration_samples()`` -> ``fit_cost_model``), and
  * installed process-wide with ``set_cost_model`` — the plan cache keys on
    the model version, so every subsequent ``plan(..., "auto")`` re-scores
    candidates under the calibrated rates.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Mapping, Sequence

import numpy as np

__all__ = [
    "CostModel",
    "DEFAULT_COST_MODEL",
    "current_cost_model",
    "current_cost_model_state",
    "set_cost_model",
    "cost_model_version",
    "fit_cost_model",
]


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-rank effective rates behind ``PlanCost``.

    ``source`` records provenance ("default" or "fitted:<n samples>") so
    reported selections can say which model produced them.

    The optional per-phase rates split the single ``flop_rate`` into the two
    phases of a HOOI mode step — the TTM Z build (streaming scatter/matmul;
    on TPU the Pallas ``kron_segsum`` kernel) and the Lanczos/SVD oracle
    (dense matvecs). They default to ``flop_rate``, so a model fitted
    without per-phase samples behaves exactly as before; a per-phase fit
    (``fit_cost_model`` on samples carrying ``ttm_flops``/``svd_flops``)
    lets the ``auto`` selector trade E_max against R_max under the rates the
    kernels actually achieve.
    """

    flop_rate: float = 5.0e10  # flop/s per rank (combined, both phases)
    net_bandwidth: float = 1.0e10  # bytes/s per link
    ttm_flop_rate: float | None = None  # TTM (Z-build) phase; None -> flop_rate
    svd_flop_rate: float | None = None  # Lanczos/SVD phase; None -> flop_rate
    # TTM rate measured under bf16 contributions (samples labelled
    # precision="bf16"); drives the "auto" precision policy — None = unknown
    ttm_flop_rate_bf16: float | None = None
    # per-comm-backend effective bandwidths (the engine's psum vs boundary
    # collectives stress the interconnect differently); None -> net_bandwidth
    psum_bandwidth: float | None = None
    boundary_bandwidth: float | None = None
    # FLOPs per factor entry per ADMM iteration (NN objective's eager refine:
    # scaled X/W/Y updates are a handful of elementwise ops per entry); folded
    # into the svd phase by the plan cost — see Objective.extra_svd_flops
    admm_flops_per_entry: float = 6.0
    # stochastic-refine rung: modeled seconds for a sampled pass are
    # (sampled_nnz / total_nnz) * sampled_pass_overhead * full_sweep_seconds.
    # The overhead multiplier absorbs everything a minibatch pays that a
    # full sweep amortizes — single-device execution (no P-way split), the
    # O(nnz) fit/core accounting on the full snapshot, pow2 shape padding.
    # See core/plan.py::stochastic_refine_seconds.
    sampled_pass_overhead: float = 2.0
    source: str = "default"

    def __post_init__(self):
        if self.flop_rate <= 0 or self.net_bandwidth <= 0:
            raise ValueError(
                f"rates must be positive: flop_rate={self.flop_rate}, "
                f"net_bandwidth={self.net_bandwidth}"
            )
        for name in ("ttm_flop_rate", "svd_flop_rate", "ttm_flop_rate_bf16",
                     "psum_bandwidth", "boundary_bandwidth"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")
        if self.sampled_pass_overhead <= 0:
            raise ValueError(
                f"sampled_pass_overhead must be positive, got "
                f"{self.sampled_pass_overhead}")

    def phase_rates(self) -> tuple[float, float]:
        """(ttm_rate, svd_rate), falling back to the combined rate."""
        return (self.ttm_flop_rate or self.flop_rate,
                self.svd_flop_rate or self.flop_rate)

    def bandwidth_for(self, backend: str | None = None) -> float:
        """Effective bytes/s for a comm backend, falling back to the
        combined ``net_bandwidth`` (``local`` moves no collective bytes but
        is charged the base rate for its residual fm traffic)."""
        if backend == "psum" and self.psum_bandwidth is not None:
            return self.psum_bandwidth
        if backend == "boundary" and self.boundary_bandwidth is not None:
            return self.boundary_bandwidth
        return self.net_bandwidth

    def flops_seconds(self, flops: float) -> float:
        return float(flops) / self.flop_rate

    def phase_seconds(self, ttm_flops: float, svd_flops: float
                      ) -> tuple[float, float]:
        rt, rs = self.phase_rates()
        return float(ttm_flops) / rt, float(svd_flops) / rs

    def comm_seconds(self, nbytes: float, backend: str | None = None) -> float:
        return float(nbytes) / self.bandwidth_for(backend)

    def predict_seconds(self, flops: float, nbytes: float) -> float:
        return self.flops_seconds(flops) + self.comm_seconds(nbytes)


DEFAULT_COST_MODEL = CostModel()

_LOCK = threading.Lock()
_CURRENT = DEFAULT_COST_MODEL
_VERSION = 0  # bumped on set_cost_model; part of the plan cache key


def current_cost_model() -> CostModel:
    """The process-wide model ``repro.core.plan`` scores candidates with."""
    with _LOCK:
        return _CURRENT


def current_cost_model_state() -> tuple[CostModel, int]:
    """(model, version) read atomically — callers that key caches on the
    version must score with the model read in the same snapshot."""
    with _LOCK:
        return _CURRENT, _VERSION


def set_cost_model(model: CostModel | None) -> CostModel:
    """Install ``model`` (None restores the default); returns the new model.

    Bumps the model version, which is part of the plan cache key — cached
    plans scored under the old rates are not silently reused.
    """
    global _CURRENT, _VERSION
    if model is not None and not isinstance(model, CostModel):
        raise TypeError(f"expected CostModel, got {type(model).__name__}")
    with _LOCK:
        _CURRENT = DEFAULT_COST_MODEL if model is None else model
        _VERSION += 1
        return _CURRENT


def cost_model_version() -> int:
    with _LOCK:
        return _VERSION


# ------------------------------------------------------------------ fitting
def _fit_bf16_ttm_rate(use: Sequence[Mapping], cm: CostModel) -> CostModel:
    """Attach the bf16 TTM rate when bf16-labelled pure-TTM samples exist.

    ``HooiExecutor.profile_phases(precision="bf16")`` appends phase="ttm"
    probes (``svd_flops=0, comm_bytes=0``) labelled with the precision that
    ran; the bf16 rate is the robust one-parameter estimate
    ``sum(flops) / sum(seconds)`` over those, attached only when physical.
    The ``"auto"`` precision policy (``engine.zbuild.resolve_precision``)
    compares it against the fitted f32 TTM rate.
    """
    flop_sum = sec_sum = 0.0
    for s in use:
        if s.get("precision") != "bf16" or s.get("phase") != "ttm":
            continue
        f = float(s.get("ttm_flops", 0.0))
        sec = float(s.get("seconds", 0.0))
        if f > 0 and sec > 0:
            flop_sum += f
            sec_sum += sec
    if flop_sum <= 0 or sec_sum <= 0:
        return cm
    rate = flop_sum / sec_sum
    if not np.isfinite(rate) or rate <= 0:
        return cm
    return dataclasses.replace(cm, ttm_flop_rate_bf16=rate,
                               source=cm.source + "+bf16")


def _fit_backend_bandwidths(use: Sequence[Mapping],
                            cm: CostModel) -> CostModel:
    """Attach per-backend effective bandwidths when samples are labelled.

    Executor samples carry the comm backend they ran (``"psum"`` /
    ``"boundary"``; per-mode mixes are labelled ``"mixed"`` and skipped).
    For each backend with positive comm residual after the fitted compute
    phases, the effective bandwidth is total bytes / total residual seconds
    — a deliberately robust one-parameter estimate, only attached when it
    is physical (positive, finite)."""
    updates: dict[str, float] = {}
    for backend, field in (("psum", "psum_bandwidth"),
                           ("boundary", "boundary_bandwidth")):
        byte_sum = resid_sum = 0.0
        for s in use:
            if s.get("comm_backend") != backend:
                continue
            b = float(s.get("comm_bytes", 0.0))
            if b <= 0:
                continue
            tt, sv = cm.phase_seconds(
                float(s.get("ttm_flops", s["critical_path_flops"])),
                float(s.get("svd_flops", 0.0)))
            resid = float(s["seconds"]) - (tt + sv)
            if resid > 0:
                byte_sum += b
                resid_sum += resid
        if byte_sum > 0 and resid_sum > 0:
            bw = byte_sum / resid_sum
            if np.isfinite(bw):
                updates[field] = bw
    if not updates:
        return cm
    return dataclasses.replace(cm, source=cm.source + "+backends", **updates)


def _fit_phases(use: Sequence[Mapping], base: CostModel) -> CostModel | None:
    """Per-phase fit: seconds ~= ttm/r_ttm + svd/r_svd + bytes/bw.

    Needs the (ttm_flops, svd_flops) columns to be independent — e.g. the
    executor's ``profile_phases`` pure-TTM probe next to full sweeps, or
    sweeps over plans with different E_max/R_max ratios. Returns None when
    the phase columns are degenerate or the fit is unphysical, so the caller
    falls back to the single-rate fit.
    """
    A2 = np.array([[float(s["ttm_flops"]), float(s["svd_flops"])]
                   for s in use])
    y = np.array([float(s["seconds"]) for s in use])
    scale2 = np.maximum(A2.max(axis=0), 1e-30)
    if (A2.max(axis=0) <= 0).any() \
            or np.linalg.matrix_rank(A2 / scale2) < 2:
        return None
    bts = np.array([float(s.get("comm_bytes", 0.0)) for s in use])
    # comm column: joint-fit only when it adds rank; otherwise pin to base
    A3 = np.column_stack([A2, bts])
    scale3 = np.maximum(A3.max(axis=0), 1e-30)
    if bts.max() > 0 and np.linalg.matrix_rank(A3 / scale3) == 3:
        x, *_ = np.linalg.lstsq(A3 / scale3, y, rcond=None)
        x = x / scale3
        if (x > 0).all():
            return CostModel(
                flop_rate=2.0 / (x[0] + x[1]),
                net_bandwidth=1.0 / x[2],
                ttm_flop_rate=1.0 / x[0],
                svd_flop_rate=1.0 / x[1],
                source=f"fitted-phases:{len(use)}",
            )
    resid = y - bts / base.net_bandwidth
    if (resid <= 0).any():  # comm effectively free (shared-memory mesh)
        resid = y
    x, *_ = np.linalg.lstsq(A2 / scale2, resid, rcond=None)
    x = x / scale2
    if (x <= 0).any():
        return None
    return CostModel(
        flop_rate=2.0 / (x[0] + x[1]),
        net_bandwidth=base.net_bandwidth,
        ttm_flop_rate=1.0 / x[0],
        svd_flop_rate=1.0 / x[1],
        source=f"fitted-phases:{len(use)}",
    )


def fit_cost_model(
    samples: Sequence[Mapping],
    base: CostModel | None = None,
    warm_only: bool = True,
) -> CostModel:
    """Least-squares fit of (flop_rate, net_bandwidth) from measured sweeps.

    Each sample is a mapping with ``critical_path_flops``, ``comm_bytes`` and
    measured ``seconds`` for one HOOI sweep (``HooiExecutor`` records exactly
    these). We solve ``seconds ~= flops * x0 + bytes * x1`` for nonnegative
    ``x0 = 1/flop_rate``, ``x1 = 1/net_bandwidth``.

    When every sample additionally carries per-phase ``ttm_flops`` /
    ``svd_flops`` columns (the executor records them; its
    ``profile_phases`` probe contributes a pure-TTM sample that makes the
    design full-rank), the TTM and Lanczos/SVD rates are fitted separately
    and returned as ``ttm_flop_rate`` / ``svd_flop_rate`` — ``auto``
    selection then re-scores candidates under kernel-speed rates. A
    degenerate or unphysical per-phase design falls back to the single-rate
    fit below.

    ``warm_only`` drops samples flagged ``warm=False`` (sweeps that paid jit
    compilation — those times measure XLA, not the machine's rates). When the
    design matrix is degenerate (one plan measured, or comm negligible on a
    shared-memory mesh), the comm term is pinned to ``base`` and only the
    flop rate is fitted — that is the dominant term for the paper's
    computation-bound workloads anyway.
    """
    base = base or DEFAULT_COST_MODEL
    all_use = [s for s in samples if not warm_only or s.get("warm", True)]
    if not all_use:
        raise ValueError("no usable samples (all cold or empty)")
    # bf16-labelled samples feed only the dedicated bf16 TTM rate — mixing
    # them into the main design would bias the f32 phase rates
    use = [s for s in all_use if s.get("precision", "f32") != "bf16"] \
        or all_use
    if all("ttm_flops" in s and "svd_flops" in s for s in use):
        phased = _fit_phases(use, base)
        if phased is not None:
            return _fit_bf16_ttm_rate(
                all_use, _fit_backend_bandwidths(use, phased))
    A = np.array(
        [[float(s["critical_path_flops"]), float(s["comm_bytes"])] for s in use]
    )
    y = np.array([float(s["seconds"]) for s in use])
    if (y <= 0).any() or (A[:, 0] <= 0).any():
        raise ValueError("samples need positive seconds and flops")

    def _flops_only() -> CostModel:
        # pin comm at base rate, fit the flop term on the residual; if the
        # pinned comm model over-predicts any sample (comm is effectively
        # free, e.g. a shared-memory mesh), attribute the whole measured
        # time to flops rather than inverting a clamped-to-zero residual
        # into an absurdly fast machine
        resid = y - A[:, 1] / base.net_bandwidth
        if (resid <= 0).any():
            resid = y
        x0 = float(resid @ A[:, 0]) / float(A[:, 0] @ A[:, 0])
        return CostModel(
            flop_rate=1.0 / max(x0, 1e-18),
            net_bandwidth=base.net_bandwidth,
            source=f"fitted:{len(use)}",
        )

    # column scaling for conditioning; rank check decides 1- vs 2-term fit
    scale = A.max(axis=0)
    if scale[1] <= 0 or np.linalg.matrix_rank(A / np.maximum(scale, 1e-30)) < 2:
        return _fit_bf16_ttm_rate(
            all_use, _fit_backend_bandwidths(use, _flops_only()))
    x, *_ = np.linalg.lstsq(A / scale, y, rcond=None)
    x = x / scale
    if x[0] <= 0 or x[1] <= 0:  # unphysical joint fit -> robust 1-term fit
        return _fit_bf16_ttm_rate(
            all_use, _fit_backend_bandwidths(use, _flops_only()))
    return _fit_bf16_ttm_rate(all_use, _fit_backend_bandwidths(use, CostModel(
        flop_rate=1.0 / x[0],
        net_bandwidth=1.0 / x[1],
        source=f"fitted:{len(use)}",
    )))
