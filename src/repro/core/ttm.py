"""TTM-chain via the per-element Kronecker reformulation (paper §3 + Appendix A).

Conventions (fixed across the whole repo):

* ``unfold(T, n)`` = ``np.moveaxis(T, n, 0).reshape(L_n, -1)`` — columns are
  C-order flattenings of the remaining modes in increasing mode order (largest
  remaining mode varies fastest).
* The matching per-element contribution is therefore
  ``contr_n(e) = val(e) * kron(F_{j1}[l_{j1}], ..., F_{jr}[l_{jr}])`` with
  ``j1 < j2 < ... < jr`` the modes != n and ``np.kron`` semantics (second
  operand fastest).

Everything here is pure jnp (device-agnostic); the Pallas kernels in
``repro.kernels`` implement the same contract for the TPU hot path and are
verified against these functions.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "unfold",
    "fold",
    "dense_ttm",
    "dense_ttm_chain",
    "kron_contributions",
    "penultimate",
    "penultimate_local",
    "core_from_factors",
]


# --------------------------------------------------------------------- dense
def unfold(T: jnp.ndarray, mode: int) -> jnp.ndarray:
    """Mode-n unfolding, L_n x prod(other)."""
    return jnp.moveaxis(T, mode, 0).reshape(T.shape[mode], -1)


def fold(M: jnp.ndarray, mode: int, shape: Sequence[int]) -> jnp.ndarray:
    """Inverse of :func:`unfold`."""
    shape = list(shape)
    rest = [shape[j] for j in range(len(shape)) if j != mode]
    T = M.reshape([shape[mode]] + rest)
    return jnp.moveaxis(T, 0, mode)


def dense_ttm(T: jnp.ndarray, mode: int, A: jnp.ndarray) -> jnp.ndarray:
    """T x_mode A  (A: K x L_mode). Dense oracle."""
    moved = jnp.moveaxis(T, mode, -1)
    out = jnp.tensordot(moved, A.T, axes=([-1], [0]))
    return jnp.moveaxis(out, -1, mode)


def dense_ttm_chain(
    T: jnp.ndarray, mats: dict[int, jnp.ndarray]
) -> jnp.ndarray:
    """Apply T x_j mats[j] for every j in mats (commutative, paper §2.1)."""
    out = T
    for j in sorted(mats):
        out = dense_ttm(out, j, mats[j])
    return out


# -------------------------------------------------------------------- sparse
def kron_contributions(
    coords: jnp.ndarray,  # (nnz, N) int32
    values: jnp.ndarray,  # (nnz,)
    factors: Sequence[jnp.ndarray],  # F_j: (L_j, K_j)
    mode: int,
) -> jnp.ndarray:
    """contr_n(e) for every element: (nnz, K_hat_n).

    K_hat_n = prod_{j != n} K_j. Batched Kronecker built by successive
    outer products in increasing mode order (keeps C-order convention).
    """
    nnz = values.shape[0]
    cur = values[:, None]  # (nnz, 1)
    for j in range(len(factors)):
        if j == mode:
            continue
        rows = jnp.take(factors[j], coords[:, j], axis=0)  # (nnz, K_j)
        # explicit width (not -1): must also trace for nnz == 0
        cur = (cur[:, :, None] * rows[:, None, :]).reshape(
            nnz, cur.shape[1] * rows.shape[1])
    return cur


def penultimate(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_rows: int,
) -> jnp.ndarray:
    """Global penultimate matrix Z_(n): (L_n, K_hat_n), eq. (1) of the paper."""
    contribs = kron_contributions(coords, values, factors, mode)
    return jax.ops.segment_sum(contribs, coords[:, mode], num_segments=num_rows)


def penultimate_local(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    local_rows: jnp.ndarray,  # (nnz,) dense-renumbered local row ids
    factors: Sequence[jnp.ndarray],
    mode: int,
    num_local_rows: int,
) -> jnp.ndarray:
    """Local copy Z^p with empty rows truncated (paper §3 'TTM Component').

    ``local_rows`` is the dense renumbering of the mode-n coordinates of the
    elements owned by this rank (padding elements must carry value 0 and any
    valid row id).
    """
    contribs = kron_contributions(coords, values, factors, mode)
    return jax.ops.segment_sum(contribs, local_rows, num_segments=num_local_rows)


def core_from_factors(
    coords: jnp.ndarray,
    values: jnp.ndarray,
    factors: Sequence[jnp.ndarray],
) -> jnp.ndarray:
    """Core G = T x_1 F_1^T x_2 ... x_N F_N^T  (paper Fig 2 last step).

    Computed element-wise: G = sum_e val(e) * outer(F_1[l_1], ..., F_N[l_N]).
    Returns a (K_1, ..., K_N) tensor.
    """
    nnz = values.shape[0]
    cur = values[:, None]
    for j in range(len(factors)):
        rows = jnp.take(factors[j], coords[:, j], axis=0)
        cur = (cur[:, :, None] * rows[:, None, :]).reshape(nnz, -1)
    core_flat = cur.sum(axis=0)
    return core_flat.reshape(tuple(f.shape[1] for f in factors))
