"""Train/serve step builders: loss, grads, optimizer, microbatching, remat.

These are the functions the launcher jits (with in/out shardings from
launch/sharding.py) and the dry-run lowers. They are mesh-agnostic: all
distribution comes from pjit shardings; nothing here names an axis except
the optional gradient-compression pod axis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from .grad_compress import CompressConfig, compress_grads, init_error_state
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_state", "make_train_step",
           "make_prefill_step", "make_decode_step"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    err: Any | None  # gradient-compression error feedback (or None)


def make_train_state(cfg: ArchConfig, key, opt_cfg: AdamWConfig | None = None,
                     compress: bool = False, dtype=jnp.float32) -> TrainState:
    params = tfm.init_params(cfg, key, dtype=dtype)
    return TrainState(
        params=params,
        opt=adamw_init(params),
        err=init_error_state(params) if compress else None,
    )


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1, remat: bool = True,
                    compress: CompressConfig | None = None, hint=None,
                    act_dtype=None, moe_groups: int = 1):
    """Returns train_step(state, batch, key) -> (state, metrics).

    ``microbatches > 1`` accumulates gradients over lax.scan-sliced chunks of
    the global batch (activation memory / overlap lever; the accumulation
    loop also gives XLA a natural compute/comm overlap window under pjit).
    """

    def loss_fn(params, batch):
        if act_dtype is not None:
            # mixed precision: cast fp32 master params to the compute dtype
            # for the whole forward/backward; grads flow back in fp32.
            params = jax.tree.map(
                lambda p: p.astype(act_dtype)
                if p.dtype == jnp.float32 else p, params)
        return tfm.lm_loss(params, cfg, batch, remat=remat, hint=hint,
                           act_dtype=act_dtype, moe_groups=moe_groups)

    def train_step(state: TrainState, batch: dict, key) -> tuple[TrainState, dict]:
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def slice_mb(x, i):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def acc_body(carry, i):
                gsum, lsum = carry
                mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
                (l, _m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}

        err = state.err
        cstats = {}
        if compress is not None and err is not None:
            grads, err, cstats = compress_grads(grads, err, compress, key)

        params, opt, ometrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        out = {"loss": loss, **ometrics, **cstats}
        out.update({k: v for k, v in (metrics or {}).items()})
        return TrainState(params=params, opt=opt, err=err), out

    return train_step


# ------------------------------------------------------------------ serving
def make_prefill_step(cfg: ArchConfig, s_max: int, cache_dtype=jnp.bfloat16,
                      hint=None, moe_groups: int = 1):
    """prefill(params, batch) -> (last_logits, sampled_first_token).

    Runs the full-sequence forward (the quadratic part of serving). The KV
    cache for the subsequent decode loop is built by the decode path itself
    in this framework's benchmarks; prefill cost is what the roofline cell
    measures.
    """

    def prefill(params, batch):
        logits, _aux = tfm.forward(params, cfg, tokens=batch.get("tokens"),
                                   embeds=batch.get("embeds"), hint=hint,
                                   moe_groups=moe_groups)
        last = logits[:, -1, :]
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return last, tok

    return prefill


def make_decode_step(cfg: ArchConfig):
    """decode(params, cache, tokens, pos) -> (next_tokens, cache).

    One new token against a KV cache of length s_max (the decode_* and
    long_* roofline cells lower exactly this function).
    """

    def decode(params, cache, tokens, pos):
        logits, cache = tfm.decode_step(params, cfg, cache, tokens, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True)
        return nxt.astype(jnp.int32), cache

    return decode
