"""Low-rank (Tucker-2 / PowerSGD-style) gradient compression with error
feedback — the paper's machinery applied to the training stack.

The paper's thesis is that in Tucker/HOOI the *computation* dominates, so a
scheme may spend extra communication to buy balanced compute. Cross-pod
training inverts the regime: the pod-interconnect (DCN) is the scarce
resource, so we spend extra computation (a tiny factorization — exactly a
rank-r Tucker-2 of each gradient matrix) to cut its traffic. Same math, dual
trade-off; see DESIGN.md §3.

For each 2-D (or reshaped) gradient G (m x n):
    P = G V ; P = QR(P) ; V' = G^T P        (one subspace iteration)
    all-reduce P, V' (m*r + n*r words instead of m*n)
    Ĝ = P V'^T ;  error e = G - Ĝ kept locally, added to the next step's G
Error feedback makes the compressed SGD/Adam sequence converge to the same
region (Karimireddy et al.); rank and the axis threshold are configurable.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["CompressConfig", "init_error_state", "compress_grads"]


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    rank: int = 8
    min_size: int = 65536  # leave small tensors uncompressed
    axis_name: str | None = None  # collective axis ("pod"); None = no comm


def _as_matrix(g: jnp.ndarray) -> tuple[jnp.ndarray, tuple]:
    """Reshape any >=2D tensor to 2D (leading dims folded)."""
    shape = g.shape
    m = int(shape[0]) if len(shape) == 2 else int(jnp.prod(
        jnp.asarray(shape[:-1])))
    return g.reshape(m, shape[-1]), shape


def init_error_state(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _compress_one(g: jnp.ndarray, err: jnp.ndarray, cfg: CompressConfig,
                  key) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """Returns (decompressed mean-gradient, new error, words_sent)."""
    if g.ndim < 2 or g.size < cfg.min_size:
        out = g.astype(jnp.float32) + 0.0
        if cfg.axis_name:
            out = jax.lax.pmean(out, cfg.axis_name)
        return out.astype(g.dtype), err, g.size

    gf = g.astype(jnp.float32) + err
    G, orig_shape = _as_matrix(gf)
    m, n = G.shape
    r = min(cfg.rank, m, n)
    V = jax.random.normal(key, (n, r), jnp.float32)
    P = G @ V
    if cfg.axis_name:
        P = jax.lax.pmean(P, cfg.axis_name)
    Q, _ = jnp.linalg.qr(P)  # (m, r) orthonormal
    Vt = Q.T @ G  # (r, n)
    if cfg.axis_name:
        Vt = jax.lax.pmean(Vt, cfg.axis_name)
    Ghat = Q @ Vt
    new_err = (G - Ghat).reshape(orig_shape)
    return Ghat.reshape(orig_shape).astype(g.dtype), new_err, (m * r + r * n)


def compress_grads(grads, err_state, cfg: CompressConfig, key):
    """Apply rank-r compression + error feedback to a grad pytree.

    Returns (grads, new_err_state, stats) where stats reports the analytic
    compression ratio (words sent / dense words).
    """
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(err_state)
    outs, new_errs, sent, dense = [], [], 0, 0
    for i, (g, e) in enumerate(zip(leaves, err_leaves)):
        gg, ee, words = _compress_one(g, e, cfg, jax.random.fold_in(key, i))
        outs.append(gg)
        new_errs.append(ee)
        sent += int(words)
        dense += int(g.size)
    stats = {"compression_ratio": sent / max(dense, 1)}
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_errs), stats)
