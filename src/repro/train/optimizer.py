"""AdamW + gradient clipping + LR schedules, pure JAX (no optax dependency).

Optimizer state is a pytree congruent with params, so it inherits param
shardings under pjit (ZeRO-style: fully sharded states for free).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray  # ()
    mu: dict  # first moment, congruent with params
    nu: dict  # second moment


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step with global-norm clipping. Returns (params, state,
    metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu), {
        "grad_norm": gnorm, "lr": lr}
