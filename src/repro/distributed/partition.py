"""Scheme -> padded per-device arrays (the SPMD runtime's view of a policy).

The paper's runtime hands each MPI rank a ragged list of elements. SPMD
hardware wants identical static shapes everywhere, so load imbalance
literally becomes padding (dead work on every device) — this is where Lite's
``E_max <= ceil(|E|/P)`` and ``R_max <= ceil(L/P)+2`` bounds pay off: they
minimize exactly the two padded dimensions (E_pad, R_pad).

Also computed here: the *row relabeling* for the optimized collective path.
We permute mode-n row ids so that every device's owned rows (sigma_n) are a
contiguous block — then the paper's point-to-point owner reduction becomes a
reduce-scatter, and the only cross-device rows are the split (stage-2)
slices, of which Lite guarantees <= 2 per device.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.coo import SparseTensor
from repro.core.distribution import Scheme, row_owner_map

__all__ = [
    "ModePartition",
    "make_mode_partition",
    "make_mode_partitions",
    "comm_model",
    "round_up_pow2",
]


def round_up_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1) — the pad quantum for streaming.

    Compiled mode steps are keyed on the padded dimensions, so any growth in
    E_pad/R_pad forces a re-jit. Quantizing pads geometrically gives shape
    *stability* under appends: a batch that grows the bottleneck rank's
    element count by less than the remaining pow2 slack keeps every compiled
    step valid (at most 2x padding waste — dead scatter work on values that
    are zero anyway).
    """
    return 1 << max(int(x) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class ModePartition:
    """Everything one HOOI mode step needs, padded to static shapes.

    Sentinel conventions (chosen so jnp scatter/gather `mode='drop'/'fill'`
    handles padding with no branches):
      * padding elements: values 0, local_row = the rank's *last real* row
        (``max(r_p - 1, 0)``) — value 0 makes them no-ops in the scatter-add,
        and reusing the last real id keeps each rank's element list sorted by
        dense local row id (the Pallas kron_segsum precondition)
      * padding local rows: row_gid = L_perm (== P*Lp, out of range)
      * non-boundary rows: bnd_slot = S_pad (out of range)
    """

    mode: int
    P: int
    L: int
    N: int
    E_pad: int
    R_pad: int
    Lp: int  # owned rows per device (ceil(L/P)), post-relabel
    S_pad: int  # global boundary (split-row) slots

    coords: np.ndarray  # (P, E_pad, N) int32 — original coords (mode col too)
    values: np.ndarray  # (P, E_pad) f32
    local_rows: np.ndarray  # (P, E_pad) int32 in [0, R_pad)
    row_gid: np.ndarray  # (P, R_pad) int32 — *relabelled* global row id
    row_owned: np.ndarray  # (P, R_pad) bool — owner(sigma) == this device
    bnd_slot: np.ndarray  # (P, R_pad) int32 — slot id if foreign else S_pad
    own_bnd_slot: np.ndarray  # (P, B_pad) int32 — slots this device owns
    own_bnd_off: np.ndarray  # (P, B_pad) int32 — offset of that row in shard
    B_pad: int

    row_perm: np.ndarray  # (L,) old gid -> new gid
    inv_perm: np.ndarray  # (L,) new gid -> old gid

    # bookkeeping for reporting
    r_per_rank: np.ndarray  # (P,)
    e_per_rank: np.ndarray  # (P,)


def make_mode_partition(
    t: SparseTensor, scheme: Scheme, mode: int, *, pad_geometric: bool = False
) -> ModePartition:
    """Build the padded SPMD view of ``scheme`` along ``mode``.

    ``pad_geometric=True`` rounds every padded dimension (E_pad, R_pad,
    S_pad, B_pad) up to the next power of two — the streaming scheduler's
    compiled-shape stability knob (see ``round_up_pow2``). Default off:
    one-shot decompositions keep the tight pads.
    """
    quant = round_up_pow2 if pad_geometric else (lambda x: max(int(x), 1))
    P = scheme.P
    N = t.ndim
    L = t.shape[mode]
    policy = scheme.policy(mode).astype(np.int64)
    sigma = row_owner_map(t, policy, mode, P)  # (L,) owner per global row

    # ---- row relabeling: sort rows by (owner, gid) -> contiguous ownership
    order = np.lexsort((np.arange(L), sigma))
    # devices own exactly ceil(L/P) consecutive new ids; pad L to P*Lp
    Lp = -(-L // P)
    # new id of old row order[i] is i, BUT contiguity must respect quotas:
    # owner counts may differ from Lp; we re-balance by assigning overflow
    # rows of heavily-owning devices to the global tail. Simpler and exact:
    # give each device its sigma rows; devices with > Lp rows spill the
    # excess (empty-slice rows preferentially) to devices with < Lp.
    sizes = t.slice_sizes(mode)
    counts = np.bincount(sigma, minlength=P)
    new_gid = np.full(L, -1, dtype=np.int64)
    spill: list[int] = []
    next_free = np.zeros(P, dtype=np.int64)
    # prefer keeping non-empty rows with their sigma owner
    for p in range(P):
        rows_p = np.nonzero(sigma == p)[0]
        if len(rows_p) > Lp:
            # spill empty rows first (no traffic impact), then smallest slices
            keep_order = np.lexsort((rows_p, -sizes[rows_p]))
            keep = rows_p[keep_order[:Lp]]
            spill.extend(rows_p[keep_order[Lp:]].tolist())
            rows_p = keep
        new_gid[rows_p] = p * Lp + np.arange(len(rows_p))
        next_free[p] = len(rows_p)
    if spill:
        spill_arr = np.asarray(spill, dtype=np.int64)
        si = 0
        for p in range(P):
            free = Lp - next_free[p]
            if free <= 0:
                continue
            take = spill_arr[si : si + free]
            new_gid[take] = p * Lp + next_free[p] + np.arange(len(take))
            si += len(take)
        assert si == len(spill_arr)
    assert (new_gid >= 0).all()
    row_perm = new_gid
    inv_perm = np.zeros(P * Lp, dtype=np.int64)
    inv_perm[:] = L  # sentinel for padded ids
    inv_perm[row_perm] = np.arange(L)
    inv_perm = inv_perm[: P * Lp]
    owner_of_new = np.arange(P * Lp) // Lp

    # ---- per-device element lists, padded
    e_per_rank = np.bincount(policy, minlength=P)
    E_pad = quant(int(e_per_rank.max()))
    coords = np.zeros((P, E_pad, N), dtype=np.int32)
    values = np.zeros((P, E_pad), dtype=np.float32)
    local_rows = np.zeros((P, E_pad), dtype=np.int32)
    row_gid_l: list[np.ndarray] = []
    r_per_rank = np.zeros(P, dtype=np.int64)

    elem_new_gid = row_perm[t.coords[:, mode]]
    for p in range(P):
        idx = np.nonzero(policy == p)[0]
        k = len(idx)
        # sort by new gid => local dense renumbering is monotone (kernel req)
        sub = idx[np.argsort(elem_new_gid[idx], kind="stable")]
        gids, lrows = np.unique(elem_new_gid[sub], return_inverse=True)
        coords[p, :k] = t.coords[sub]
        values[p, :k] = t.values[sub]
        local_rows[p, :k] = lrows
        r_per_rank[p] = len(gids)
        row_gid_l.append(gids)
    R_pad = quant(int(r_per_rank.max()))
    # padding elements -> last local row with value 0 (kernel-safe)
    for p in range(P):
        k = int(e_per_rank[p])
        if k < E_pad:
            local_rows[p, k:] = max(int(r_per_rank[p]) - 1, 0)

    L_sent = P * Lp  # out-of-range gid sentinel
    row_gid = np.full((P, R_pad), L_sent, dtype=np.int32)
    row_owned = np.zeros((P, R_pad), dtype=bool)
    for p in range(P):
        g = row_gid_l[p]
        row_gid[p, : len(g)] = g
        row_owned[p, : len(g)] = owner_of_new[g] == p

    # ---- boundary (foreign) rows: local rows owned elsewhere
    bnd_pairs = []  # (device, local_row_idx, new_gid)
    for p in range(P):
        foreign = np.nonzero(~row_owned[p] & (row_gid[p] < L_sent))[0]
        for r in foreign:
            bnd_pairs.append((p, int(r), int(row_gid[p, r])))
    S = len(bnd_pairs)
    S_pad = quant(S)
    bnd_slot = np.full((P, R_pad), S_pad, dtype=np.int32)
    for s, (p, r, g) in enumerate(bnd_pairs):
        bnd_slot[p, r] = s
    # owner side: for each slot, the owning device and the offset in its shard
    own_lists: list[list[tuple[int, int]]] = [[] for _ in range(P)]
    for s, (_p, _r, g) in enumerate(bnd_pairs):
        op = int(owner_of_new[g])
        own_lists[op].append((s, g - op * Lp))
    B_pad = quant(max((len(x) for x in own_lists), default=0))
    own_bnd_slot = np.full((P, B_pad), S_pad, dtype=np.int32)
    own_bnd_off = np.full((P, B_pad), Lp, dtype=np.int32)  # Lp = drop sentinel
    for p in range(P):
        for j, (s, off) in enumerate(own_lists[p]):
            own_bnd_slot[p, j] = s
            own_bnd_off[p, j] = off

    return ModePartition(
        mode=mode, P=P, L=L, N=N, E_pad=E_pad, R_pad=R_pad, Lp=Lp,
        S_pad=S_pad, coords=coords, values=values, local_rows=local_rows,
        row_gid=row_gid, row_owned=row_owned, bnd_slot=bnd_slot,
        own_bnd_slot=own_bnd_slot, own_bnd_off=own_bnd_off, B_pad=B_pad,
        row_perm=row_perm, inv_perm=inv_perm,
        r_per_rank=r_per_rank, e_per_rank=e_per_rank,
    )


def make_mode_partitions(
    t: SparseTensor, scheme: Scheme, *, pad_geometric: bool = False
) -> tuple[ModePartition, ...]:
    """All N mode partitions for a scheme (the padded SPMD view of a plan)."""
    return tuple(make_mode_partition(t, scheme, n, pad_geometric=pad_geometric)
                 for n in range(t.ndim))


def comm_model(mp: ModePartition, khat: int, niter: int) -> dict:
    """Analytic bytes moved per device per HOOI mode (f32).

    psum of an n-vector moves ~2n(P-1)/P words per device (ring allreduce).
    """
    ring = 2.0 * (mp.P - 1) / mp.P
    q = 2 * niter  # oracle queries (matvec+rmatvec per iteration)
    base = q * (mp.P * mp.Lp * ring + khat * ring) * 4
    opt = q * (mp.S_pad * ring + khat * ring) * 4
    return {"baseline_bytes": base, "liteopt_bytes": opt,
            "boundary_rows": mp.S_pad}
