"""Distributed HOOI — thin compatibility wrapper over ``HooiExecutor``.

The engine (mesh ownership, compiled-step cache, device-upload cache,
calibration sampling, and both collective paths) lives in
``repro.distributed.executor``; this module keeps the historical
``dist_hooi(...)`` entry point and re-exports so existing call sites work
unchanged. Repeated calls share a process-wide executor per (P, mesh), so
the second decomposition on a cached plan performs no new jit compilations
and no new host->device uploads — the device-side analogue of the plan
cache's host-side amortization.
"""

from __future__ import annotations

from typing import Sequence

from repro.core.coo import SparseTensor
from repro.core.distribution import Scheme
from repro.core.hooi import Decomposition
from repro.core.plan import PartitionPlan
from .executor import (  # noqa: F401 — historical re-exports
    DistHooiStats,
    HooiExecutor,
    comm_model,
    make_ranks_mesh,
    shared_executor,
)
from .partition import ModePartition, make_mode_partition  # noqa: F401

__all__ = ["dist_hooi", "make_ranks_mesh", "comm_model", "DistHooiStats",
           "HooiExecutor", "shared_executor"]


def dist_hooi(
    t: SparseTensor,
    core_dims: Sequence[int],
    P_ranks: int,
    scheme: str | Scheme | PartitionPlan = "lite",
    n_invocations: int = 3,
    path: str = "liteopt",
    seed: int = 0,
    mesh=None,
    plan_seed: int = 0,
    executor: HooiExecutor | None = None,
    use_kernel: bool | None = None,
    use_fused_oracle: bool | None = None,
    precision: str | None = None,
    lanczos_block: int | None = None,
    fused_zbuild: bool | None = None,
    warm_start: str | None = None,
    pad_geometric: bool = False,
    objective=None,
) -> tuple[Decomposition, DistHooiStats]:
    """Distributed HOOI: partition with ``scheme``, run on a 'ranks' mesh.

    ``scheme`` is the string sugar (any name ``repro.core.plan.plan``
    accepts, including ``"auto"``), a prebuilt ``Scheme``, or a full
    ``PartitionPlan``. String/Scheme forms go through the content-keyed plan
    cache, so repeated calls on the same tensor skip all host-side
    partitioning work. ``seed`` drives the factor initialization;
    ``plan_seed`` is threaded to randomized distribution schemes (medium's
    index permutations, coarse's block strategy) and participates in the
    plan cache key. ``executor`` overrides the shared per-(P, mesh) engine.

    ``path`` selects the comm-backend family (``"baseline"`` -> psum,
    ``"liteopt"`` -> boundary, ``"auto"`` -> per mode from the plan's
    analytic comm model; P=1 always runs the collective-free ``local``
    backend — the same engine instantiation as single-process ``hooi``).
    ``use_kernel`` picks the Z-build variant (None = Pallas kron_segsum on
    TPU when it fits VMEM, True = force kernel, False = jnp reference; see
    ``repro.engine.zbuild.resolve_kernel``) and ``use_fused_oracle``
    (None/False = off) routes the Lanczos oracle products through the fused
    Pallas kernel. ``precision``/``lanczos_block``/``fused_zbuild`` are the
    roofline knobs (bf16 Z-build contributions, s-step Lanczos panels, the
    fused Z-build→first-oracle stage) and ``warm_start`` the sketched
    oracle warm start (``"none"``/``"sketch"``/``"auto"``; None honors
    ``REPRO_WARM_START`` — see ``docs/sketch.md``) — see
    ``HooiExecutor.run``; each ``None`` honors its ``REPRO_*`` environment
    override. ``pad_geometric``
    quantizes partition pads to powers of two (streaming shape stability;
    part of the plan-cache key — see ``repro.core.plan.plan``).
    ``objective`` selects what the sweeps optimize (None honors
    ``REPRO_OBJECTIVE``; a name or an ``engine.objective.Objective``) — see
    ``docs/objectives.md``.
    """
    ex = executor if executor is not None else shared_executor(P_ranks, mesh)
    if ex.P != P_ranks:
        raise ValueError(f"executor has P={ex.P}, asked for {P_ranks}")
    return ex.run(t, core_dims, scheme, n_invocations=n_invocations,
                  path=path, seed=seed, plan_seed=plan_seed,
                  use_kernel=use_kernel, use_fused_oracle=use_fused_oracle,
                  precision=precision, lanczos_block=lanczos_block,
                  fused_zbuild=fused_zbuild, warm_start=warm_start,
                  pad_geometric=pad_geometric, objective=objective)
