"""Distributed HOOI on a JAX device mesh (shard_map) — the paper's runtime.

Two collective paths per mode step:

* ``baseline`` — the paper's framework mapped 1:1 onto SPMD: the oracle
  answer x_out lives replicated in the full row space, aggregated with a
  `psum` over the padded row vector (the all-reduce analogue of the MPI
  point-to-point owner reduction). Comm per query: O(L) per device.

* ``liteopt`` — the beyond-paper TPU-native path (DESIGN.md §2): rows are
  relabelled so each device owns a contiguous block; x_out is produced
  *sharded* (each owner materializes only its rows) and the only cross-
  device traffic is the tiny boundary vector of split-slice rows — size
  R_sum - L <= P for Lite (Theorem 6.1.2). Comm per query: O(S_pad) ~ O(P).
  The Lanczos u-basis is row-sharded too, cutting both memory and FLOPs of
  reorthogonalization by P.

Both paths share all math with repro.core (same oracles, same Lanczos
recurrence) and are tested to produce factor matrices spanning the same
subspace as the single-process reference.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.coo import SparseTensor
from repro.core.distribution import Scheme
from repro.core.hooi import Decomposition, fit_score, random_factors
from repro.core.plan import PartitionPlan, plan as build_plan, plan_cache_stats
from repro.core.ttm import core_from_factors, kron_contributions
from repro.jax_compat import make_mesh_auto, shard_map_compat
from .partition import ModePartition, comm_model, make_mode_partition  # noqa: F401 — comm_model re-exported

__all__ = ["dist_hooi", "make_ranks_mesh", "comm_model", "DistHooiStats"]

_EPS = 1e-30


def make_ranks_mesh(P_ranks: int):
    devs = jax.devices()
    if len(devs) < P_ranks:
        raise ValueError(
            f"need {P_ranks} devices, have {len(devs)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count"
        )
    return make_mesh_auto((P_ranks,), ("ranks",), devices=devs[:P_ranks])


# ---------------------------------------------------------------- Lanczos
def _dist_lanczos(matvec, rmatvec, dim_u, ncols, niter, key, u_psum: bool):
    """GK bidiagonalization where the u-space may be sharded over 'ranks'.

    All u-space inner products go through _psum when u_psum (sharded rows);
    the v-space (K_hat) is always replicated.
    """
    def _ps(x):
        return jax.lax.psum(x, "ranks") if u_psum else x

    dtype = jnp.float32
    V = jnp.zeros((ncols, niter), dtype)
    U = jnp.zeros((dim_u, niter), dtype)
    alphas = jnp.zeros((niter,), dtype)
    betas = jnp.zeros((niter,), dtype)

    ku = jax.random.fold_in(key, 17)
    if u_psum:  # per-device distinct restart directions
        ku = jax.random.fold_in(ku, jax.lax.axis_index("ranks"))
    kv = jax.random.fold_in(key, 29)
    r_u = jax.random.normal(ku, (dim_u, niter), dtype)
    r_v = jax.random.normal(kv, (ncols, niter), dtype)

    v0 = jax.random.normal(jax.random.fold_in(key, 3), (ncols,), dtype)
    v0 = v0 / (jnp.linalg.norm(v0) + _EPS)

    def u_reorth(u, basis):
        for _ in range(2):
            u = u - basis @ _ps(basis.T @ u)
        return u

    def v_reorth(w, basis):
        for _ in range(2):
            w = w - basis @ (basis.T @ w)
        return w

    def body(i, carry):
        U, V, alphas, betas, v, u_prev, beta_prev, scale = carry
        V = V.at[:, i].set(v)
        u = matvec(v) - beta_prev * u_prev
        u = u_reorth(u, U)
        alpha = jnp.sqrt(_ps(jnp.sum(u * u)))
        scale = jnp.maximum(scale, alpha)
        ok = alpha > 1e-6 * scale
        u_new = u_reorth(r_u[:, i], U)
        u_new = u_new / (jnp.sqrt(_ps(jnp.sum(u_new * u_new))) + _EPS)
        u = jnp.where(ok, u / (alpha + _EPS), u_new)
        alpha = jnp.where(ok, alpha, 0.0)
        U = U.at[:, i].set(u)
        alphas = alphas.at[i].set(alpha)

        w = rmatvec(u) - alpha * v
        w = v_reorth(w, V)
        beta = jnp.linalg.norm(w)
        scale = jnp.maximum(scale, beta)
        ok_b = beta > 1e-6 * scale
        v_new = v_reorth(r_v[:, i], V)
        v_new = v_new / (jnp.linalg.norm(v_new) + _EPS)
        v = jnp.where(ok_b, w / (beta + _EPS), v_new)
        beta = jnp.where(ok_b, beta, 0.0)
        betas = betas.at[i].set(beta)
        return (U, V, alphas, betas, v, u, beta, scale)

    carry = (U, V, alphas, betas, v0, jnp.zeros((dim_u,), dtype),
             jnp.array(0.0, dtype), jnp.array(_EPS, dtype))
    U, V, alphas, betas, *_ = jax.lax.fori_loop(0, niter, body, carry)
    B = jnp.diag(alphas) + jnp.diag(betas[:-1], k=1)
    return U, B


# ------------------------------------------------------------- mode step
def _build_local_z(coords, values, local_rows, factors, mode, R_pad):
    contribs = kron_contributions(coords, values, factors, mode)
    return jax.ops.segment_sum(contribs, local_rows, num_segments=R_pad)


def _mode_step_fn(
    mp_static: dict,
    path: str,
    K_n: int,
    niter: int,
    # --- sharded per-device arrays (leading 'ranks' axis stripped) ---
    coords, values, local_rows, row_gid, row_owned, bnd_slot,
    own_bnd_slot, own_bnd_off,
    # --- replicated ---
    factors, key,
):
    mode = mp_static["mode"]
    R_pad = mp_static["R_pad"]
    Lp = mp_static["Lp"]
    S_pad = mp_static["S_pad"]
    L_sent = mp_static["P"] * Lp
    p = jax.lax.axis_index("ranks")
    # shard_map keeps a leading size-1 'ranks' axis on sharded operands
    (coords, values, local_rows, row_gid, row_owned, bnd_slot,
     own_bnd_slot, own_bnd_off) = (
        x[0] for x in (coords, values, local_rows, row_gid, row_owned,
                       bnd_slot, own_bnd_slot, own_bnd_off))

    Z = _build_local_z(coords, values, local_rows, factors, mode, R_pad)
    Khat = Z.shape[1]

    if path == "baseline":
        # replicated row space (size L_sent); psum of the full row vector
        def matvec(x):
            local = Z @ x  # (R_pad,)
            out = jnp.zeros((L_sent,), Z.dtype).at[row_gid].add(
                local, mode="drop")
            return jax.lax.psum(out, "ranks")

        def rmatvec(u):
            y_loc = u.at[row_gid].get(mode="fill", fill_value=0.0)
            return jax.lax.psum(y_loc @ Z, "ranks")

        U, B = _dist_lanczos(matvec, rmatvec, L_sent, Khat, niter, key,
                             u_psum=False)
        Pb, S, _ = jnp.linalg.svd(B, full_matrices=False)
        F_full = U @ Pb[:, :K_n]  # (L_sent, K_n) replicated
        F_shard = jax.lax.dynamic_slice_in_dim(F_full, p * Lp, Lp, 0)
        return F_shard, S[:K_n]

    # ---- liteopt: sharded row space --------------------------------------
    off = row_gid - p * Lp  # owned rows: in [0, Lp); foreign/pad: out of range

    def matvec(x):
        local = Z @ x  # (R_pad,)
        owned_contrib = jnp.where(row_owned, local, 0.0)
        shard = jnp.zeros((Lp,), Z.dtype).at[
            jnp.where(row_owned, off, Lp)
        ].add(owned_contrib, mode="drop")
        # boundary rows -> tiny global slot vector (size S_pad ~ O(P))
        bvec = jnp.zeros((S_pad,), Z.dtype).at[bnd_slot].add(
            local, mode="drop")  # owned/pad rows have slot S_pad -> dropped
        bvec = jax.lax.psum(bvec, "ranks")
        add = bvec.at[own_bnd_slot].get(mode="fill", fill_value=0.0)
        shard = shard.at[own_bnd_off].add(add, mode="drop")
        return shard  # (Lp,) sharded over ranks

    def rmatvec(u_shard):
        # owners publish boundary-row values into the tiny slot vector
        vals = u_shard.at[own_bnd_off].get(mode="fill", fill_value=0.0)
        ybnd = jnp.zeros((S_pad,), Z.dtype).at[own_bnd_slot].set(
            vals, mode="drop")
        ybnd = jax.lax.psum(ybnd, "ranks")
        y_own = u_shard.at[off].get(mode="fill", fill_value=0.0)
        y_for = ybnd.at[bnd_slot].get(mode="fill", fill_value=0.0)
        y_loc = jnp.where(row_owned, y_own, y_for)
        return jax.lax.psum(y_loc @ Z, "ranks")

    U, B = _dist_lanczos(matvec, rmatvec, Lp, Khat, niter, key, u_psum=True)
    Pb, S, _ = jnp.linalg.svd(B, full_matrices=False)
    F_shard = U @ Pb[:, :K_n]  # (Lp, K_n) sharded
    return F_shard, S[:K_n]


@dataclasses.dataclass
class DistHooiStats:
    fits: list
    comm: dict  # analytic per-mode comm model
    r_pad: dict
    e_pad: dict
    scheme: str = ""  # concrete scheme that ran (auto resolves to a candidate)
    selection: dict | None = None  # auto only: candidate -> modeled total_s
    partition_build_s: float = 0.0  # host-side plan construction this call
    plan_cache_hit: bool = False
    plan_cache: dict | None = None  # global plan-cache counters after this call


def dist_hooi(
    t: SparseTensor,
    core_dims: Sequence[int],
    P_ranks: int,
    scheme: str | Scheme | PartitionPlan = "lite",
    n_invocations: int = 3,
    path: str = "liteopt",
    seed: int = 0,
    mesh=None,
) -> tuple[Decomposition, DistHooiStats]:
    """Distributed HOOI: partition with ``scheme``, run on a 'ranks' mesh.

    ``scheme`` is the string sugar (any name ``repro.core.plan.plan`` accepts,
    including ``"auto"``), a prebuilt ``Scheme``, or a full ``PartitionPlan``.
    String/Scheme forms go through the content-keyed plan cache, so repeated
    calls on the same tensor skip all host-side partitioning work.
    """
    assert path in ("baseline", "liteopt")
    misses_before = plan_cache_stats()["misses"]
    t_plan = time.perf_counter()
    if isinstance(scheme, PartitionPlan):
        pl = scheme
        if pl.P != P_ranks:
            raise ValueError(f"plan built for P={pl.P}, asked for {P_ranks}")
    else:
        pl = build_plan(t, scheme, P_ranks, core_dims=tuple(core_dims),
                        path=path, seed=0)
    partition_build_s = time.perf_counter() - t_plan
    cache_hit = (not isinstance(scheme, PartitionPlan)
                 and plan_cache_stats()["misses"] == misses_before)
    mesh = mesh or make_ranks_mesh(P_ranks)
    N = t.ndim
    key = jax.random.PRNGKey(seed)
    factors = random_factors(t.shape, core_dims, key)

    parts = pl.parts
    comm = {n: comm_model(parts[n],
                          int(np.prod([core_dims[j] for j in range(N) if j != n])),
                          2 * int(core_dims[n]))
            for n in range(N)}

    # one jitted shard_map per mode
    steps = []
    for n in range(N):
        mp = parts[n]
        mp_static = dict(mode=mp.mode, R_pad=mp.R_pad, Lp=mp.Lp,
                         S_pad=mp.S_pad, P=mp.P)
        fn = functools.partial(
            _mode_step_fn, mp_static, path, int(core_dims[n]),
            2 * int(core_dims[n]),
        )
        sharded = P("ranks")
        smap = shard_map_compat(
            fn, mesh,
            in_specs=(sharded,) * 8 + (P(), P()),
            out_specs=(P("ranks"), P()),
        )
        steps.append(jax.jit(smap))

    dev_args = []
    for mp in parts:
        dev_args.append(tuple(jnp.asarray(x) for x in (
            mp.coords, mp.values, mp.local_rows, mp.row_gid, mp.row_owned,
            mp.bnd_slot, mp.own_bnd_slot, mp.own_bnd_off)))

    coords_j = jnp.asarray(t.coords, jnp.int32)
    values_j = jnp.asarray(t.values, jnp.float32)
    fits = []
    for it in range(n_invocations):
        for n in range(N):
            mp = parts[n]
            kk = jax.random.fold_in(key, 1000 + it * N + n)
            F_new, _sv = steps[n](*dev_args[n], factors, kk)
            # F_new rows are in relabelled space; restore original order
            F_old = jnp.asarray(F_new)[jnp.asarray(mp.row_perm)]
            factors[n] = F_old
        core = core_from_factors(coords_j, values_j, factors)
        fits.append(fit_score(t, Decomposition(core=core, factors=factors)))

    core = core_from_factors(coords_j, values_j, factors)
    stats = DistHooiStats(
        fits=fits, comm=comm,
        r_pad={n: parts[n].R_pad for n in range(N)},
        e_pad={n: parts[n].E_pad for n in range(N)},
        scheme=pl.name,
        selection=pl.candidates,
        partition_build_s=partition_build_s,
        plan_cache_hit=cache_hit,
        plan_cache=plan_cache_stats(),
    )
    return Decomposition(core=core, factors=factors), stats
