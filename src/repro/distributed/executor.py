"""HooiExecutor: the reusable distributed-HOOI engine.

``dist_hooi`` used to be a monolith: every call re-jitted N shard_map mode
steps and re-uploaded every padded ``ModePartition`` array, so the
device-side distribution cost was paid on every run — the opposite of the
paper's amortization story. The executor makes reuse structural. It owns

  * the ``ranks`` device mesh (built once per executor),

  * a **compiled-step cache**: jitted shard_map mode steps keyed on the
    static step signature ``(path, mode, R_pad, Lp, S_pad, P, K_n, niter)``
    — two tensors whose partitions pad to the same shapes share one XLA
    compilation (jit re-specializes per concrete array shapes; the executor
    counts a compilation exactly when a (step, shapes) pair is first seen,
    which is jit's own cache-miss condition),

  * a **device-upload cache**: the per-mode device arrays for a plan, keyed
    weakly on ``PartitionPlan`` *identity* (the plan cache's same-object
    contract exists precisely so this works) — repeated runs, and
    interleaved runs on different cached tensors sharing one mesh
    (multi-tensor batching), skip all host->device transfer.

Every ``run`` also records measured per-sweep wall times next to the plan's
modeled flops/bytes; ``calibration_samples()`` feeds
``repro.core.calibrate.fit_cost_model`` so the analytic rates behind the
``auto`` selector can be fitted to the actual machine.

Two collective paths per mode step (unchanged math, shared with repro.core):

* ``baseline`` — the paper's framework mapped 1:1 onto SPMD: the oracle
  answer x_out lives replicated in the full row space, aggregated with a
  `psum` over the padded row vector (the all-reduce analogue of the MPI
  point-to-point owner reduction). Comm per query: O(L) per device.

* ``liteopt`` — the beyond-paper TPU-native path (DESIGN.md §2): rows are
  relabelled so each device owns a contiguous block; x_out is produced
  *sharded* (each owner materializes only its rows) and the only cross-
  device traffic is the tiny boundary vector of split-slice rows — size
  R_sum - L <= P for Lite (Theorem 6.1.2). Comm per query: O(S_pad) ~ O(P).
  The Lanczos u-basis is row-sharded too, cutting both memory and FLOPs of
  reorthogonalization by P.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import threading
import time
import weakref
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.coo import SparseTensor
from repro.core.distribution import Scheme
from repro.core.hooi import Decomposition, fit_score, random_factors
from repro.core.plan import PartitionPlan, plan as build_plan, plan_cache_stats
from repro.core.ttm import core_from_factors, kron_contributions
from repro.jax_compat import make_mesh_auto, shard_map_compat
from repro.kernels import ops as kernel_ops
from .partition import comm_model, make_mode_partition  # noqa: F401 — re-export

__all__ = [
    "HooiExecutor",
    "shared_executor",
    "make_ranks_mesh",
    "DistHooiStats",
    "comm_model",
]

_EPS = 1e-30
MAX_CALIBRATION_SAMPLES = 1024
MAX_COMPILED_STEPS = 256  # jitted shard_map executables held per executor


def make_ranks_mesh(P_ranks: int):
    devs = jax.devices()
    if len(devs) < P_ranks:
        raise ValueError(
            f"need {P_ranks} devices, have {len(devs)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count"
        )
    return make_mesh_auto((P_ranks,), ("ranks",), devices=devs[:P_ranks])


# ---------------------------------------------------------------- Lanczos
def _dist_lanczos(matvec, rmatvec, dim_u, ncols, niter, key, u_psum: bool):
    """GK bidiagonalization where the u-space may be sharded over 'ranks'.

    All u-space inner products go through _psum when u_psum (sharded rows);
    the v-space (K_hat) is always replicated.
    """
    def _ps(x):
        return jax.lax.psum(x, "ranks") if u_psum else x

    dtype = jnp.float32
    V = jnp.zeros((ncols, niter), dtype)
    U = jnp.zeros((dim_u, niter), dtype)
    alphas = jnp.zeros((niter,), dtype)
    betas = jnp.zeros((niter,), dtype)

    ku = jax.random.fold_in(key, 17)
    if u_psum:  # per-device distinct restart directions
        ku = jax.random.fold_in(ku, jax.lax.axis_index("ranks"))
    kv = jax.random.fold_in(key, 29)
    r_u = jax.random.normal(ku, (dim_u, niter), dtype)
    r_v = jax.random.normal(kv, (ncols, niter), dtype)

    v0 = jax.random.normal(jax.random.fold_in(key, 3), (ncols,), dtype)
    v0 = v0 / (jnp.linalg.norm(v0) + _EPS)

    def u_reorth(u, basis):
        for _ in range(2):
            u = u - basis @ _ps(basis.T @ u)
        return u

    def v_reorth(w, basis):
        for _ in range(2):
            w = w - basis @ (basis.T @ w)
        return w

    def body(i, carry):
        U, V, alphas, betas, v, u_prev, beta_prev, scale = carry
        V = V.at[:, i].set(v)
        u = matvec(v) - beta_prev * u_prev
        u = u_reorth(u, U)
        alpha = jnp.sqrt(_ps(jnp.sum(u * u)))
        scale = jnp.maximum(scale, alpha)
        ok = alpha > 1e-6 * scale
        u_new = u_reorth(r_u[:, i], U)
        u_new = u_new / (jnp.sqrt(_ps(jnp.sum(u_new * u_new))) + _EPS)
        u = jnp.where(ok, u / (alpha + _EPS), u_new)
        alpha = jnp.where(ok, alpha, 0.0)
        U = U.at[:, i].set(u)
        alphas = alphas.at[i].set(alpha)

        w = rmatvec(u) - alpha * v
        w = v_reorth(w, V)
        beta = jnp.linalg.norm(w)
        scale = jnp.maximum(scale, beta)
        ok_b = beta > 1e-6 * scale
        v_new = v_reorth(r_v[:, i], V)
        v_new = v_new / (jnp.linalg.norm(v_new) + _EPS)
        v = jnp.where(ok_b, w / (beta + _EPS), v_new)
        beta = jnp.where(ok_b, beta, 0.0)
        betas = betas.at[i].set(beta)
        return (U, V, alphas, betas, v, u, beta, scale)

    carry = (U, V, alphas, betas, v0, jnp.zeros((dim_u,), dtype),
             jnp.array(0.0, dtype), jnp.array(_EPS, dtype))
    U, V, alphas, betas, *_ = jax.lax.fori_loop(0, niter, body, carry)
    B = jnp.diag(alphas) + jnp.diag(betas[:-1], k=1)
    return U, B


# ------------------------------------------------------------- mode step
def _build_local_z(coords, values, local_rows, factors, mode, R_pad,
                   use_kernel=False):
    """Local penultimate Z^p — the §4.3 TTM hot spot.

    ``use_kernel`` routes through the Pallas ``kron_segsum`` kernel (the
    one-hot-matmul reformulation); partition.py emits per-rank elements
    already sorted by dense local row id, so the sorted fast path applies
    with no runtime argsort. The flag is static (baked into the trace) and
    must be part of the compiled-step cache key.
    """
    if use_kernel:
        return kernel_ops.penultimate_sorted(
            coords, values, local_rows, factors, mode, R_pad,
            use_kernel=True)
    contribs = kron_contributions(coords, values, factors, mode)
    return jax.ops.segment_sum(contribs, local_rows, num_segments=R_pad)


def _zbuild_step_fn(
    mp_static: dict,
    use_kernel: bool,
    # --- sharded per-device arrays (leading 'ranks' axis stripped) ---
    coords, values, local_rows,
    # --- replicated ---
    factors,
):
    """TTM-only step: just the local Z build (per-phase calibration probe)."""
    coords, values, local_rows = (x[0] for x in (coords, values, local_rows))
    Z = _build_local_z(coords, values, local_rows, factors,
                       mp_static["mode"], mp_static["R_pad"],
                       use_kernel=use_kernel)
    return Z[None]


def _mode_step_fn(
    mp_static: dict,
    path: str,
    K_n: int,
    niter: int,
    # --- sharded per-device arrays (leading 'ranks' axis stripped) ---
    coords, values, local_rows, row_gid, row_owned, bnd_slot,
    own_bnd_slot, own_bnd_off,
    # --- replicated ---
    factors, key,
):
    mode = mp_static["mode"]
    R_pad = mp_static["R_pad"]
    Lp = mp_static["Lp"]
    S_pad = mp_static["S_pad"]
    L_sent = mp_static["P"] * Lp
    p = jax.lax.axis_index("ranks")
    # shard_map keeps a leading size-1 'ranks' axis on sharded operands
    (coords, values, local_rows, row_gid, row_owned, bnd_slot,
     own_bnd_slot, own_bnd_off) = (
        x[0] for x in (coords, values, local_rows, row_gid, row_owned,
                       bnd_slot, own_bnd_slot, own_bnd_off))

    Z = _build_local_z(coords, values, local_rows, factors, mode, R_pad,
                       use_kernel=mp_static.get("use_kernel", False))
    Khat = Z.shape[1]

    if path == "baseline":
        # replicated row space (size L_sent); psum of the full row vector
        def matvec(x):
            local = Z @ x  # (R_pad,)
            out = jnp.zeros((L_sent,), Z.dtype).at[row_gid].add(
                local, mode="drop")
            return jax.lax.psum(out, "ranks")

        def rmatvec(u):
            y_loc = u.at[row_gid].get(mode="fill", fill_value=0.0)
            return jax.lax.psum(y_loc @ Z, "ranks")

        U, B = _dist_lanczos(matvec, rmatvec, L_sent, Khat, niter, key,
                             u_psum=False)
        Pb, S, _ = jnp.linalg.svd(B, full_matrices=False)
        F_full = U @ Pb[:, :K_n]  # (L_sent, K_n) replicated
        F_shard = jax.lax.dynamic_slice_in_dim(F_full, p * Lp, Lp, 0)
        return F_shard, S[:K_n]

    # ---- liteopt: sharded row space --------------------------------------
    off = row_gid - p * Lp  # owned rows: in [0, Lp); foreign/pad: out of range

    def matvec(x):
        local = Z @ x  # (R_pad,)
        owned_contrib = jnp.where(row_owned, local, 0.0)
        shard = jnp.zeros((Lp,), Z.dtype).at[
            jnp.where(row_owned, off, Lp)
        ].add(owned_contrib, mode="drop")
        # boundary rows -> tiny global slot vector (size S_pad ~ O(P))
        bvec = jnp.zeros((S_pad,), Z.dtype).at[bnd_slot].add(
            local, mode="drop")  # owned/pad rows have slot S_pad -> dropped
        bvec = jax.lax.psum(bvec, "ranks")
        add = bvec.at[own_bnd_slot].get(mode="fill", fill_value=0.0)
        shard = shard.at[own_bnd_off].add(add, mode="drop")
        return shard  # (Lp,) sharded over ranks

    def rmatvec(u_shard):
        # owners publish boundary-row values into the tiny slot vector
        vals = u_shard.at[own_bnd_off].get(mode="fill", fill_value=0.0)
        ybnd = jnp.zeros((S_pad,), Z.dtype).at[own_bnd_slot].set(
            vals, mode="drop")
        ybnd = jax.lax.psum(ybnd, "ranks")
        y_own = u_shard.at[off].get(mode="fill", fill_value=0.0)
        y_for = ybnd.at[bnd_slot].get(mode="fill", fill_value=0.0)
        y_loc = jnp.where(row_owned, y_own, y_for)
        return jax.lax.psum(y_loc @ Z, "ranks")

    U, B = _dist_lanczos(matvec, rmatvec, Lp, Khat, niter, key, u_psum=True)
    Pb, S, _ = jnp.linalg.svd(B, full_matrices=False)
    F_shard = U @ Pb[:, :K_n]  # (Lp, K_n) sharded
    return F_shard, S[:K_n]


# ------------------------------------------------------------------- stats
@dataclasses.dataclass
class DistHooiStats:
    fits: list
    comm: dict  # analytic per-mode comm model
    r_pad: dict
    e_pad: dict
    scheme: str = ""  # concrete scheme that ran (auto resolves to a candidate)
    selection: dict | None = None  # auto only: candidate -> modeled total_s
    partition_build_s: float = 0.0  # host-side plan construction this call
    plan_cache_hit: bool = False
    plan_cache: dict | None = None  # global plan-cache counters after this call
    # ---- executor counters, deltas for this call ----
    step_compilations: int = 0  # new XLA mode-step compilations this call
    step_cache_hits: int = 0  # mode-step invocations served from cache
    uploads: int = 0  # host->device arrays transferred this call
    upload_cache_hit: bool = False  # plan's device arrays were already resident
    executor: dict | None = None  # cumulative HooiExecutor.stats() snapshot
    # mode -> True if the Z build ran through the Pallas kron_segsum kernel
    z_kernel: dict | None = None


@dataclasses.dataclass
class _PlanUpload:
    """Device-resident arrays for one plan (the upload cache's payload)."""

    dev_args: tuple  # per-mode 8-tuples of sharded jnp arrays
    row_perms: tuple  # per-mode (L,) jnp index arrays (relabel -> original)
    coords: jnp.ndarray  # full-tensor COO (core / fit evaluation)
    values: jnp.ndarray
    n_arrays: int


# ---------------------------------------------------------------- executor
class HooiExecutor:
    """Runs distributed HOOI sweeps on one ``ranks`` mesh, caching both the
    compiled mode steps and the per-plan device uploads across runs.

    One executor per mesh; ``shared_executor(P)`` hands out a process-wide
    instance so independent ``dist_hooi`` calls amortize automatically.
    """

    def __init__(self, P_ranks: int, mesh=None):
        self.P = int(P_ranks)
        self.mesh = mesh if mesh is not None else make_ranks_mesh(self.P)
        self._lock = threading.RLock()
        self._steps: dict[tuple, object] = {}  # static sig -> jitted callable
        self._seen_shapes: set[tuple] = set()  # (static sig, arg shapes)
        self._uploads: "weakref.WeakKeyDictionary[PartitionPlan, _PlanUpload]" \
            = weakref.WeakKeyDictionary()
        # an auto plan is a dataclasses.replace copy of its winning
        # candidate, sharing the same parts tuple: dedupe uploads on the
        # parts' identity so the arrays go to device once. While an upload
        # is alive, some plan in _uploads holds its parts, so id() is stable.
        self._uploads_by_parts: "weakref.WeakValueDictionary[int, _PlanUpload]" \
            = weakref.WeakValueDictionary()
        # calibration records; bounded so a long-lived shared executor does
        # not grow without limit (recent sweeps are the relevant ones anyway)
        self._samples: "collections.deque[dict]" = collections.deque(
            maxlen=MAX_CALIBRATION_SAMPLES)
        self._stats = {
            "runs": 0,
            "step_compilations": 0,
            "step_cache_hits": 0,
            "uploads": 0,
            "upload_cache_hits": 0,
        }

    # ------------------------------------------------------------ kernels
    def resolve_kernel(self, mp, core_dims: Sequence[int],
                       use_kernel: bool | None) -> bool:
        """Static kernel/fallback decision for one mode step.

        ``None`` (the default) engages the Pallas ``kron_segsum`` kernel only
        on a real TPU backend (off-TPU the kernel runs in interpret mode,
        which is far slower than the jnp reference) and only when the Z tile
        passes the VMEM gate. ``True`` forces the kernel wherever the gate
        admits the shape (differential tests); ``False`` forces the jnp
        ``segment_sum`` reference. The resolved choice is part of the
        compiled-step cache key: kernel and fallback variants of the same
        shapes are distinct executables.
        """
        if use_kernel is False:
            return False
        Ka, Kb = kernel_ops.split_kron_dims(core_dims, mp.mode)
        fits = kernel_ops.kernel_fits_vmem(mp.R_pad, Ka, Kb)
        if use_kernel is None:
            return fits and jax.default_backend() == "tpu"
        return fits

    # ------------------------------------------------------------- caches
    def _step_key(self, mp, path: str, K_n: int, niter: int,
                  use_kernel: bool = False) -> tuple:
        # the static signature of one mode step: everything baked into the
        # trace besides array shapes (which jit itself specializes on) —
        # including the Z-build variant (Pallas kernel vs jnp reference)
        return (path, "kern" if use_kernel else "ref", mp.mode, mp.R_pad,
                mp.Lp, mp.S_pad, self.P, K_n, niter)

    def _get_step(self, mp, path: str, K_n: int, use_kernel: bool = False):
        niter = 2 * K_n
        skey = self._step_key(mp, path, K_n, niter, use_kernel)
        with self._lock:
            step = self._steps.get(skey)
            if step is not None:
                # LRU touch: hot steps survive the executable bound
                self._steps[skey] = self._steps.pop(skey)
            else:
                mp_static = dict(mode=mp.mode, R_pad=mp.R_pad, Lp=mp.Lp,
                                 S_pad=mp.S_pad, P=mp.P,
                                 use_kernel=use_kernel)
                if path == "zbuild":
                    fn = functools.partial(_zbuild_step_fn, mp_static,
                                           use_kernel)
                    smap = shard_map_compat(
                        fn, self.mesh,
                        in_specs=(P("ranks"),) * 3 + (P(),),
                        out_specs=P("ranks"),
                    )
                else:
                    fn = functools.partial(_mode_step_fn, mp_static, path,
                                           K_n, niter)
                    smap = shard_map_compat(
                        fn, self.mesh,
                        in_specs=(P("ranks"),) * 8 + (P(), P()),
                        out_specs=(P("ranks"), P()),
                    )
                step = jax.jit(smap)
                self._steps[skey] = step
                while len(self._steps) > MAX_COMPILED_STEPS:
                    old = next(iter(self._steps))
                    del self._steps[old]
                    # a re-created callable gets a fresh jit cache: its
                    # compilations must be counted again
                    self._seen_shapes = {
                        s for s in self._seen_shapes if s[0] != old}
        return skey, step

    def _note_shapes(self, skey, shapes, tally: dict) -> None:
        # jit compiles exactly when it first sees a shape signature for this
        # callable; mirror that condition to count compilations faithfully.
        # ``tally`` is the per-run ledger: concurrent runs on one shared
        # executor must not read each other's work out of the cumulative
        # counters.
        with self._lock:
            if (skey, shapes) in self._seen_shapes:
                self._stats["step_cache_hits"] += 1
                tally["step_cache_hits"] += 1
            else:
                self._seen_shapes.add((skey, shapes))
                self._stats["step_compilations"] += 1
                tally["step_compilations"] += 1

    def _call_step(self, skey, step, dev_args, factors, key, tally: dict):
        shapes = tuple(a.shape for a in dev_args) + tuple(
            f.shape for f in factors)
        self._note_shapes(skey, shapes, tally)
        return step(*dev_args, factors, key)

    def _get_upload(self, pl: PartitionPlan, t: SparseTensor,
                    tally: dict) -> _PlanUpload:
        with self._lock:
            up = self._uploads.get(pl)
            if up is None:
                up = self._uploads_by_parts.get(id(pl.parts))
                if up is not None:  # plan copy sharing resident arrays
                    self._uploads[pl] = up
            if up is not None:
                self._stats["upload_cache_hits"] += 1
                tally["upload_cache_hits"] += 1
                return up
        dev_args = tuple(
            tuple(jnp.asarray(x) for x in (
                mp.coords, mp.values, mp.local_rows, mp.row_gid,
                mp.row_owned, mp.bnd_slot, mp.own_bnd_slot, mp.own_bnd_off))
            for mp in pl.parts)
        row_perms = tuple(jnp.asarray(mp.row_perm) for mp in pl.parts)
        up = _PlanUpload(
            dev_args=dev_args,
            row_perms=row_perms,
            coords=jnp.asarray(t.coords, jnp.int32),
            values=jnp.asarray(t.values, jnp.float32),
            n_arrays=9 * len(pl.parts) + 2,
        )
        with self._lock:
            won = self._uploads.setdefault(pl, up)
            if won is up:
                self._uploads_by_parts[id(pl.parts)] = up
            # the setdefault loser still paid a (discarded) transfer: count
            # its arrays as uploads either way so stats reflect real traffic
            self._stats["uploads"] += up.n_arrays
            tally["uploads"] += up.n_arrays
        return won

    # ------------------------------------------------------------ observe
    def stats(self) -> dict:
        """Cumulative counters + cache occupancy."""
        with self._lock:
            return dict(self._stats, cached_steps=len(self._steps),
                        cached_plans=len(self._uploads))

    def calibration_samples(self) -> list[dict]:
        """Measured sweeps (flops/bytes/seconds) for ``fit_cost_model``."""
        with self._lock:
            return [dict(s) for s in self._samples]

    def profile_phases(
        self,
        t: SparseTensor,
        core_dims: Sequence[int],
        scheme: str | Scheme | PartitionPlan = "lite",
        *,
        path: str = "liteopt",
        plan_seed: int = 0,
        use_kernel: bool | None = None,
        repeats: int = 3,
        seed: int = 0,
    ) -> dict:
        """Measure per-phase sweep times: TTM (Z build) vs Lanczos/SVD.

        Runs the Z-build-only step (``zbuild`` — same kernel/fallback choice
        as a real sweep) and the full mode step per mode, compiled first and
        then timed over ``repeats`` warm calls. Appends two calibration
        samples — a pure-TTM one (``svd_flops=0, comm_bytes=0``) and a full
        sweep — so ``fit_cost_model`` gets a full-rank per-phase design even
        from a single plan. Returns per-mode and total timings.
        """
        assert path in ("baseline", "liteopt")
        tally = {"step_compilations": 0, "step_cache_hits": 0,
                 "uploads": 0, "upload_cache_hits": 0}
        if isinstance(scheme, PartitionPlan):
            pl = scheme
        else:
            pl = build_plan(t, scheme, self.P, core_dims=tuple(core_dims),
                            path=path, seed=plan_seed)
        N = t.ndim
        parts = pl.parts
        up = self._get_upload(pl, t, tally)
        key = jax.random.PRNGKey(seed)
        factors = random_factors(t.shape, core_dims, key)
        eff_dims = tuple(min(int(k), int(L))
                         for k, L in zip(core_dims, t.shape))
        z_kernel = {n: self.resolve_kernel(parts[n], eff_dims, use_kernel)
                    for n in range(N)}

        def _timed(fn, *args):
            out = fn(*args)  # compile + warm
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(repeats):
                out = fn(*args)
            jax.block_until_ready(out)
            return (time.perf_counter() - t0) / repeats

        per_mode = {}
        ttm_s = full_s = 0.0
        fshapes = tuple(f.shape for f in factors)
        for n in range(N):
            K_n = int(core_dims[n])
            zkey, zstep = self._get_step(parts[n], "zbuild", K_n,
                                         use_kernel=z_kernel[n])
            skey, step = self._get_step(parts[n], path, K_n,
                                        use_kernel=z_kernel[n])
            kk = jax.random.fold_in(key, 7000 + n)
            # register the shape signatures exactly like a run() would, so a
            # later run() on these shapes sees them as already-compiled (the
            # 0-new-compilations reuse contract) and its first sweep is not
            # mis-flagged cold
            self._note_shapes(
                zkey, tuple(a.shape for a in up.dev_args[n][:3]) + fshapes,
                tally)
            self._note_shapes(
                skey, tuple(a.shape for a in up.dev_args[n]) + fshapes,
                tally)
            tz = _timed(zstep, *up.dev_args[n][:3], factors)
            tf = _timed(step, *up.dev_args[n], factors, kk)
            per_mode[n] = {"ttm_s": tz, "full_s": tf,
                           "svd_s": max(tf - tz, 0.0)}
            ttm_s += tz
            full_s += tf
        m = pl.metrics
        with self._lock:
            self._samples.append({
                "critical_path_flops": m.ttm_flops_max,
                "ttm_flops": m.ttm_flops_max, "svd_flops": 0,
                "comm_bytes": 0.0, "seconds": ttm_s, "warm": True,
                "P": self.P, "path": path, "scheme": pl.name,
                "phase": "ttm", "kernel": all(z_kernel.values()),
            })
            self._samples.append({
                "critical_path_flops": m.critical_path_flops,
                "ttm_flops": m.ttm_flops_max,
                "svd_flops": m.svd_flops_max,
                "comm_bytes": pl.cost.comm_bytes, "seconds": full_s,
                "warm": True, "P": self.P, "path": path, "scheme": pl.name,
                "phase": "sweep", "kernel": all(z_kernel.values()),
            })
        return {"ttm_s": ttm_s, "full_s": full_s,
                "svd_s": max(full_s - ttm_s, 0.0),
                "per_mode": per_mode, "z_kernel": z_kernel}

    # ---------------------------------------------------------------- run
    def run(
        self,
        t: SparseTensor,
        core_dims: Sequence[int],
        scheme: str | Scheme | PartitionPlan = "lite",
        *,
        n_invocations: int = 3,
        path: str = "liteopt",
        seed: int = 0,
        plan_seed: int = 0,
        use_kernel: bool | None = None,
    ) -> tuple[Decomposition, DistHooiStats]:
        """One distributed HOOI decomposition on this executor's mesh.

        ``scheme`` is the string sugar (any name ``repro.core.plan.plan``
        accepts, including ``"auto"``), a prebuilt ``Scheme``, or a full
        ``PartitionPlan``. String/Scheme forms go through the content-keyed
        plan cache with ``plan_seed`` threaded to randomized schemes; a
        cached plan additionally reuses this executor's device uploads and
        compiled steps.

        ``use_kernel`` selects the Z-build variant per mode step (see
        ``resolve_kernel``): ``None`` auto-engages the Pallas kernel on TPU
        when the VMEM gate admits the shape, ``True`` forces it wherever it
        fits, ``False`` pins the jnp ``segment_sum`` reference. The gate is
        evaluated on the *actual* factor widths ``min(L_n, K_n)``
        (``random_factors``' reduced QR clamps K > L), not the raw request.
        """
        assert path in ("baseline", "liteopt")
        # per-run ledger: deltas must be this run's own work, not whatever
        # a concurrent run on the shared executor did meanwhile
        tally = {"step_compilations": 0, "step_cache_hits": 0,
                 "uploads": 0, "upload_cache_hits": 0}
        misses_before = plan_cache_stats()["misses"]
        t_plan = time.perf_counter()
        if isinstance(scheme, PartitionPlan):
            pl = scheme
            if pl.P != self.P:
                raise ValueError(
                    f"plan built for P={pl.P}, executor has P={self.P}")
            if pl.fingerprint is not None \
                    and pl.fingerprint != t.fingerprint():
                # the upload cache is keyed on plan identity: running a
                # plan against a different tensor would silently reuse the
                # original tensor's device arrays
                raise ValueError(
                    f"plan was built for tensor {pl.fingerprint[:12]}…, "
                    f"got {t.fingerprint()[:12]}…")
            if tuple(pl.core_dims) != tuple(int(k) for k in core_dims):
                raise ValueError(
                    f"plan modeled core_dims={pl.core_dims}, asked to run "
                    f"{tuple(core_dims)} — comm/calibration stats would "
                    "mix models; build a plan with matching core_dims")
            if pl.cost.path != path:
                raise ValueError(
                    f"plan costed for path={pl.cost.path!r}, running "
                    f"{path!r}")
        else:
            pl = build_plan(t, scheme, self.P, core_dims=tuple(core_dims),
                            path=path, seed=plan_seed)
        partition_build_s = time.perf_counter() - t_plan
        cache_hit = (not isinstance(scheme, PartitionPlan)
                     and plan_cache_stats()["misses"] == misses_before)

        N = t.ndim
        key = jax.random.PRNGKey(seed)
        factors = random_factors(t.shape, core_dims, key)
        parts = pl.parts
        comm = {n: pl.comm(n) for n in range(N)}

        # factor widths are min(L, K) (reduced QR) — gate on real shapes
        eff_dims = tuple(min(int(k), int(L))
                         for k, L in zip(core_dims, t.shape))
        z_kernel = {n: self.resolve_kernel(parts[n], eff_dims, use_kernel)
                    for n in range(N)}
        steps = [self._get_step(parts[n], path, int(core_dims[n]),
                                use_kernel=z_kernel[n])
                 for n in range(N)]
        up = self._get_upload(pl, t, tally)

        fits = []
        core = None
        for it in range(n_invocations):
            sweep_compiles = tally["step_compilations"]
            t_sweep = time.perf_counter()
            for n in range(N):
                kk = jax.random.fold_in(key, 1000 + it * N + n)
                skey, step = steps[n]
                F_new, _sv = self._call_step(skey, step, up.dev_args[n],
                                             factors, kk, tally)
                # F_new rows are in relabelled space; restore original order
                factors[n] = jnp.asarray(F_new)[up.row_perms[n]]
            jax.block_until_ready(factors)
            sweep_s = time.perf_counter() - t_sweep
            with self._lock:
                self._samples.append({
                    "critical_path_flops": pl.metrics.critical_path_flops,
                    # per-phase split (bottleneck-rank flops): lets
                    # fit_cost_model separate the TTM and Lanczos/SVD rates
                    "ttm_flops": pl.metrics.ttm_flops_max,
                    "svd_flops": pl.metrics.svd_flops_max,
                    "comm_bytes": pl.cost.comm_bytes,
                    "seconds": sweep_s,
                    # sweeps that paid jit time measure XLA, not the machine
                    "warm": tally["step_compilations"] == sweep_compiles,
                    "P": self.P,
                    "path": path,
                    "scheme": pl.name,
                    # True when every mode's Z build ran the Pallas kernel —
                    # rates fitted from kernel sweeps are kernel-speed rates
                    "kernel": all(z_kernel.values()),
                })
            core = core_from_factors(up.coords, up.values, factors)
            fits.append(fit_score(t, Decomposition(core=core,
                                                   factors=factors)))

        if core is None:  # n_invocations == 0: finalize the initial factors
            core = core_from_factors(up.coords, up.values, factors)
        with self._lock:
            self._stats["runs"] += 1
        stats = DistHooiStats(
            fits=fits, comm=comm,
            r_pad={n: parts[n].R_pad for n in range(N)},
            e_pad={n: parts[n].E_pad for n in range(N)},
            scheme=pl.name,
            selection=pl.candidates,
            partition_build_s=partition_build_s,
            plan_cache_hit=cache_hit,
            plan_cache=plan_cache_stats(),
            step_compilations=tally["step_compilations"],
            step_cache_hits=tally["step_cache_hits"],
            uploads=tally["uploads"],
            upload_cache_hit=tally["upload_cache_hits"] > 0,
            executor=self.stats(),
            z_kernel=z_kernel,
        )
        return Decomposition(core=core, factors=factors), stats


# ------------------------------------------------------- shared executors
_SHARED: dict[int, HooiExecutor] = {}  # default-mesh executors, keyed by P
# caller-provided meshes: content-keyed (jax Mesh equality/hash compare
# devices + axis names, so fresh-but-equal meshes share one executor) and
# LRU-bounded — an executor pins its mesh and compiled steps, and the old
# per-call dist_hooi never retained any of that
_SHARED_BY_MESH: dict[object, HooiExecutor] = {}
MAX_SHARED_MESH_EXECUTORS = 8
_SHARED_LOCK = threading.Lock()


def shared_executor(P_ranks: int, mesh=None) -> HooiExecutor:
    """Process-wide executor for (P, mesh) — what ``dist_hooi`` runs on.

    Sharing the executor is what makes repeated ``dist_hooi`` calls (and
    interleaved calls on different cached tensors — multi-tensor batching)
    skip jit and host->device transfer without any caller-side plumbing.
    """
    P_ranks = int(P_ranks)
    with _SHARED_LOCK:
        if mesh is None:
            ex = _SHARED.get(P_ranks)
            if ex is None:
                ex = HooiExecutor(P_ranks)
                _SHARED[P_ranks] = ex
            return ex
        ex = _SHARED_BY_MESH.get(mesh)
        if ex is not None and ex.P == P_ranks:
            # LRU touch: hot meshes survive the bound
            _SHARED_BY_MESH[mesh] = _SHARED_BY_MESH.pop(mesh)
            return ex
        ex = HooiExecutor(P_ranks, mesh=mesh)
        _SHARED_BY_MESH[mesh] = ex
        while len(_SHARED_BY_MESH) > MAX_SHARED_MESH_EXECUTORS:
            _SHARED_BY_MESH.pop(next(iter(_SHARED_BY_MESH)))
        return ex
