"""HooiExecutor: mesh, caching and calibration over engine-built steps.

``dist_hooi`` used to be a monolith: every call re-jitted N shard_map mode
steps and re-uploaded every padded ``ModePartition`` array, so the
device-side distribution cost was paid on every run — the opposite of the
paper's amortization story. The executor makes reuse structural, and since
the engine refactor it owns *no math of its own*: every mode step is
composed by ``repro.engine`` (Z-build -> oracle -> comm backend; the same
stages single-process ``repro.core.hooi`` runs) and the sweep loop is the
shared ``engine.sweep.run_hooi_sweeps``. What the executor owns:

  * the ``ranks`` device mesh (built once per executor),

  * a **compiled-step cache**: jitted shard_map mode steps keyed on the
    static step signature ``(backend, zbuild-variant, oracle-variant, mode,
    R_pad, Lp, S_pad, P, K_n, niter)`` — two tensors whose partitions pad
    to the same shapes share one XLA compilation (jit re-specializes per
    concrete array shapes; the executor counts a compilation exactly when a
    (step, shapes) pair is first seen, which is jit's own cache-miss
    condition),

  * a **device-upload cache**: the per-mode device arrays for a plan, keyed
    weakly on ``PartitionPlan`` *identity* (the plan cache's same-object
    contract exists precisely so this works) — repeated runs, and
    interleaved runs on different cached tensors sharing one mesh
    (multi-tensor batching), skip all host->device transfer.

Every ``run`` also records measured per-sweep wall times next to the plan's
modeled flops/bytes; ``calibration_samples()`` feeds
``repro.core.calibrate.fit_cost_model`` so the analytic rates behind the
``auto`` selector can be fitted to the actual machine.

Comm backends (``repro.engine.comm``; unchanged math, selected per mode):
``local`` for P=1 (no collectives — structural parity with single-process
HOOI), ``psum`` for the paper-faithful ``baseline`` path, ``boundary`` for
the TPU-native ``liteopt`` path; ``path="auto"`` picks per mode from the
plan's analytic comm model.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
import weakref
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.coo import SparseTensor
from repro.core.distribution import Scheme
from repro.core.hooi import Decomposition, random_factors
from repro.core.lanczos import effective_block_size, lanczos_niter
from repro.core.plan import (
    PartitionPlan,
    last_plan_call_cache_hit,
    plan as build_plan,
    plan_cache_stats,
)
from repro.core.sketch import (DEFAULT_POWER_ITERS, sketch_block_size,
                               sketch_niter)
from repro.core.stochastic import (blend_factor, next_pow2, sample_batch,
                                   step_eta)
from repro.engine import (
    ARRAY_FIELDS,
    choose_warm_start,
    count_z_passes,
    make_mode_step_fn,
    make_stochastic_step_fn,
    make_zbuild_step_fn,
    resolve_backend,
    resolve_block_size,
    resolve_fused_zbuild,
    resolve_precision,
    resolve_warm_start,
    run_hooi_sweeps,
)
from repro.engine import zbuild as engine_zbuild
from repro.engine.objective import resolve_objective
from repro.jax_compat import make_mesh_auto, shard_map_compat
from .partition import comm_model, make_mode_partition  # noqa: F401 — re-export

__all__ = [
    "HooiExecutor",
    "shared_executor",
    "make_ranks_mesh",
    "DistHooiStats",
    "comm_model",
]

MAX_CALIBRATION_SAMPLES = 1024
MAX_COMPILED_STEPS = 256  # jitted shard_map executables held per executor
MAX_STOCH_UPLOADS = 32  # resident stochastic minibatches per executor

RUN_PATHS = ("baseline", "liteopt", "auto")


def make_ranks_mesh(P_ranks: int):
    devs = jax.devices()
    if len(devs) < P_ranks:
        raise ValueError(
            f"need {P_ranks} devices, have {len(devs)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count"
        )
    return make_mesh_auto((P_ranks,), ("ranks",), devices=devs[:P_ranks])


# ------------------------------------------------------------------- stats
@dataclasses.dataclass
class DistHooiStats:
    fits: list
    comm: dict  # analytic per-mode comm model
    r_pad: dict
    e_pad: dict
    scheme: str = ""  # concrete scheme that ran (auto resolves to a candidate)
    selection: dict | None = None  # auto only: candidate -> modeled total_s
    partition_build_s: float = 0.0  # host-side plan construction this call
    plan_cache_hit: bool = False
    plan_cache: dict | None = None  # global plan-cache counters after this call
    # ---- executor counters, deltas for this call ----
    step_compilations: int = 0  # new XLA mode-step compilations this call
    step_cache_hits: int = 0  # mode-step invocations served from cache
    uploads: int = 0  # host->device arrays transferred this call
    upload_cache_hit: bool = False  # plan's device arrays were already resident
    executor: dict | None = None  # cumulative HooiExecutor.stats() snapshot
    # mode -> True if the Z build ran through the Pallas kron_segsum kernel
    z_kernel: dict | None = None
    # mode -> comm backend the step ran ("local" | "psum" | "boundary")
    comm_backends: dict | None = None
    # True when the Lanczos oracle products ran the fused Pallas kernel
    fused_oracle: bool = False
    # ---- roofline knobs (resolved values that actually ran) ----
    precision: str = "f32"  # Z-build contribution precision ("f32" | "bf16")
    # mode -> effective Lanczos panel width (1 = the vector driver)
    lanczos_block: dict | None = None
    # True when the Z build and first oracle product ran as one fused stage
    fused_zbuild: bool = False
    # mode -> counted HBM passes over Z per sweep (engine.count_z_passes)
    z_passes: dict | None = None
    # ---- streaming scheduler annotations (repro.engine.scheduler) ----
    # how the scheduler refreshed the plan for this run:
    # "plan" (first sight) | "reuse" | "repartition" | "reselect"
    stream_decision: str | None = None
    # §4 imbalance drift that drove the decision (refresh_decision output)
    stream_drift: dict | None = None
    # host-side producer time (snapshot + decision + plan + upload staging)
    # that ran *off* the device hot path, overlapped with earlier sweeps
    prepare_s: float = 0.0
    # ---- serving-tier annotations (repro.engine.pool / .router) ----
    # submit -> sweep start, minus the prepare work (pure queueing delay)
    queue_wait_s: float = 0.0
    # consumer-stage sweep wall seconds for this run
    run_s: float = 0.0
    # caller's SLO budget on submit -> result latency, and whether the run
    # met it (None/None when no deadline was given)
    slo_deadline_s: float | None = None
    slo_met: bool | None = None
    # pool lane (executor index) that ran this decomposition
    lane: int | None = None
    # ---- objective annotations (repro.engine.objective) ----
    # which sweep objective ran ("tucker" | "completion" | "nn")
    objective: str = "tucker"
    # objective extra per-sweep stats, e.g. completion's held-out RMSE
    # trajectory under "holdout_rmse"; None when the objective emits none
    objective_metrics: dict | None = None
    # ---- sketch warm start / adaptive rank (repro.core.sketch) ----
    # mode -> resolved warm-start mode that ran ("none" | "sketch")
    warm_start: dict | None = None
    # mode -> last-sweep singular-value estimates (numpy); the tail drives
    # the streaming scheduler's adapt_rank policy
    mode_spectra: dict | None = None
    # scheduler-filled: [(stream_len, core_dims), ...] rank trajectory for
    # the stream this run belongs to (None outside adaptive-rank streams)
    rank_trajectory: list | None = None
    # ---- stochastic-refine rung (run_stochastic / core.stochastic) ----
    # sample fraction the minibatch drew at (None outside the rung)
    sample_fraction: float | None = None
    # sampled new-batch elements that entered the minibatch
    sample_nnz: int | None = None
    # replay-reservoir elements drawn from the refined prefix
    replay_nnz: int | None = None
    # effective blend step size eta this refine applied (post-decay)
    step_size: float | None = None
    # scheduler-filled: final fit minus the last *full* run's final fit —
    # the rung's observable fit error, bounded by the correction sweep
    fit_delta: float | None = None


@dataclasses.dataclass
class _PlanUpload:
    """Device-resident arrays for one plan (the upload cache's payload)."""

    dev_args: tuple  # per-mode 8-tuples of sharded jnp arrays
    row_perms: tuple  # per-mode (L,) jnp index arrays (relabel -> original)
    coords: jnp.ndarray  # full-tensor COO (core / fit evaluation)
    values: jnp.ndarray
    n_arrays: int


@dataclasses.dataclass(frozen=True)
class _ModeSpec:
    """Static per-mode step parameters run() and profile_phases() share.

    Both must derive identical specs so a profiled step's shape signature
    counts as already-compiled for the subsequent run (and vice versa).
    """

    backend: str
    K_n: int
    niter: int  # block iterations when block_size > 1
    use_kernel: bool
    precision: str = "f32"
    block_size: int = 1  # effective (clamped) Lanczos panel width
    fused_zbuild: bool = False
    objective: str = "tucker"  # sweep objective the step runs under
    warm_start: str = "none"  # resolved oracle warm start ("none"|"sketch")


# ---------------------------------------------------------------- executor
class HooiExecutor:
    """Runs distributed HOOI sweeps on one ``ranks`` mesh, caching both the
    compiled mode steps and the per-plan device uploads across runs.

    One executor per mesh; ``shared_executor(P)`` hands out a process-wide
    instance so independent ``dist_hooi`` calls amortize automatically.
    """

    def __init__(self, P_ranks: int, mesh=None):
        self.P = int(P_ranks)
        self.mesh = mesh if mesh is not None else make_ranks_mesh(self.P)
        self._lock = threading.RLock()
        self._steps: dict[tuple, object] = {}  # static sig -> jitted callable
        self._seen_shapes: set[tuple] = set()  # (static sig, arg shapes)
        self._uploads: "weakref.WeakKeyDictionary[PartitionPlan, _PlanUpload]" \
            = weakref.WeakKeyDictionary()
        # an auto plan is a dataclasses.replace copy of its winning
        # candidate, sharing the same parts tuple: dedupe uploads on the
        # parts' identity so the arrays go to device once. While an upload
        # is alive, some plan in _uploads holds its parts, so id() is stable.
        self._uploads_by_parts: "weakref.WeakValueDictionary[int, _PlanUpload]" \
            = weakref.WeakValueDictionary()
        # stochastic-refine minibatch device arrays, LRU-keyed on
        # (fingerprint, objective token, fraction, seed, covered, replay) —
        # everything the deterministic sampler's output is a pure function
        # of, so a rerun on the same snapshot re-uses the resident arrays
        # (the rung's 0-new-uploads contract) while a new append (new
        # fingerprint/covered) uploads its own minibatch
        self._stoch_uploads: "collections.OrderedDict[tuple, tuple]" \
            = collections.OrderedDict()
        # calibration records; bounded so a long-lived shared executor does
        # not grow without limit (recent sweeps are the relevant ones anyway)
        self._samples: "collections.deque[dict]" = collections.deque(
            maxlen=MAX_CALIBRATION_SAMPLES)
        self._stats = {
            "runs": 0,
            "step_compilations": 0,
            "step_cache_hits": 0,
            "uploads": 0,
            "upload_cache_hits": 0,
        }

    # ------------------------------------------------------------ kernels
    def resolve_kernel(self, mp, core_dims: Sequence[int],
                       use_kernel: bool | None) -> bool:
        """Static kernel/fallback decision for one mode step's Z build
        (delegates to the engine's shared gate — see
        ``repro.engine.zbuild.resolve_kernel``)."""
        return engine_zbuild.resolve_kernel(mp.R_pad, core_dims, mp.mode,
                                            use_kernel)

    # ------------------------------------------------------------ planning
    def _check_plan(self, pl: PartitionPlan, t: SparseTensor,
                    core_dims: Sequence[int], path: str,
                    objective: str = "tucker") -> None:
        """Refuse a plan that does not describe (t, core_dims, path,
        objective) — the upload cache is keyed on plan identity, so a
        mismatched plan would silently run (and time) the wrong device
        arrays or score the wrong objective's cost."""
        if pl.P != self.P:
            raise ValueError(
                f"plan built for P={pl.P}, executor has P={self.P}")
        if pl.objective != objective:
            raise ValueError(
                f"plan was built for objective={pl.objective!r}, asked to "
                f"run {objective!r} — its view, metrics and cost describe "
                "a different training tensor; build a matching plan")
        if pl.fingerprint is not None \
                and pl.fingerprint != t.fingerprint():
            raise ValueError(
                f"plan was built for tensor {pl.fingerprint[:12]}…, "
                f"got {t.fingerprint()[:12]}…")
        if tuple(pl.core_dims) != tuple(int(k) for k in core_dims):
            raise ValueError(
                f"plan modeled core_dims={pl.core_dims}, asked to run "
                f"{tuple(core_dims)} — comm/calibration stats would "
                "mix models; build a plan with matching core_dims")
        if path != "auto" and pl.cost.path not in (path, "auto"):
            raise ValueError(
                f"plan costed for path={pl.cost.path!r}, running "
                f"{path!r}")

    def _mode_specs(self, pl: PartitionPlan, core_dims: Sequence[int],
                    path: str, use_kernel: bool | None,
                    precision: str = "f32", block_size: int = 1,
                    fused_zbuild: bool = False,
                    objective: str = "tucker",
                    warm_start: str = "none") -> list[_ModeSpec]:
        """Per-mode static step parameters for a plan.

        * ``backend``: from the plan's partition metrics (``path="auto"``
          compares the analytic per-mode comm models; P=1 is ``local``).
        * ``niter``: the shared Lanczos iteration count, clamped by the
          *true* row count and the effective K_hat — the same numbers the
          local engine path derives, so P=1 trajectories coincide. Counts
          *block* iterations when the mode runs the block driver.
        * ``use_kernel``: the VMEM-gated Z-build choice, evaluated on the
          actual factor widths ``min(L_n, K_n)`` (``random_factors``'
          reduced QR clamps K > L), not the raw request.
        * ``precision``/``block_size``/``fused_zbuild``: the *resolved*
          roofline knobs; ``block_size`` is clamped per mode to the
          operator's rank cap via ``effective_block_size``.
        * ``warm_start``: the resolved warm-start mode (``"auto"`` settles
          per mode via ``choose_warm_start`` on the same static geometry
          the local engine path sees, so P=1 parity holds). A sketch mode
          runs the reduced ``sketch_niter`` budget and structurally
          forgoes the fused first product (the panel depends on Z).
        """
        parts = pl.parts
        eff = tuple(min(int(k), int(mp.L))
                    for k, mp in zip(core_dims, parts))
        # a plan costed with path="auto" already chose per-mode backends
        # under the (possibly per-backend-calibrated) cost model — honor
        # that choice instead of re-deriving it from raw bytes
        recorded = None
        if path == "auto" and pl.cost.path == "auto" and self.P > 1 \
                and len(pl.cost.mode_backends) == len(parts):
            recorded = pl.cost.mode_backends
        specs = []
        for n, mp in enumerate(parts):
            K_n = int(core_dims[n])
            khat = int(np.prod([eff[j] for j in range(len(eff)) if j != n]))
            if recorded is not None:
                backend = resolve_backend(recorded[n], self.P)
            else:
                backend = resolve_backend(
                    path, self.P, pl.comm(n) if path == "auto" else None)
            s_eff = effective_block_size(K_n, int(mp.L), khat, block_size)
            ws = choose_warm_start(warm_start, K_n, int(mp.L), khat, s_eff,
                                   fused_zbuild)
            fz_n = fused_zbuild and ws != "sketch"
            if ws == "sketch":
                s_eff = sketch_block_size(K_n, int(mp.L), khat, block_size)
                niter = sketch_niter(K_n, int(mp.L), khat, s_eff)
            else:
                niter = lanczos_niter(K_n, int(mp.L), khat,
                                      s_eff if (fz_n or s_eff > 1) else 1)
            specs.append(_ModeSpec(
                backend=backend,
                K_n=K_n,
                niter=niter,
                use_kernel=self.resolve_kernel(mp, eff, use_kernel),
                precision=precision,
                block_size=s_eff,
                fused_zbuild=fz_n,
                objective=objective,
                warm_start=ws,
            ))
        return specs

    # ------------------------------------------------------------- caches
    def _step_key(self, mp, path: str, K_n: int, niter: int,
                  use_kernel: bool = False, use_fused: bool = False,
                  precision: str = "f32", block_size: int = 1,
                  fused_zbuild: bool = False,
                  objective: str = "tucker",
                  warm_start: str = "none") -> tuple:
        # the static signature of one mode step: everything baked into the
        # trace besides array shapes (which jit itself specializes on) —
        # the comm backend (or historical path alias), the Z-build variant
        # (Pallas kernel vs jnp reference), the oracle-product variant, the
        # roofline knobs (precision, Lanczos panel width, fused Z-build),
        # the objective, and the warm-start mode: distinct variants never
        # alias each other's compiled steps, so the rerun contract holds
        # per (objective, warm_start) variant.
        return (path, "kern" if use_kernel else "ref",
                "fused" if use_fused else "plain", mp.mode, mp.R_pad,
                mp.Lp, mp.S_pad, self.P, K_n, niter,
                precision, int(block_size),
                "fz" if fused_zbuild else "zb", objective, warm_start)

    def _get_step(self, mp, path: str, K_n: int, use_kernel: bool = False,
                  niter: int | None = None, use_fused: bool = False,
                  precision: str = "f32", block_size: int = 1,
                  fused_zbuild: bool = False, objective: str = "tucker",
                  warm_start: str = "none"):
        niter = 2 * K_n if niter is None else int(niter)
        skey = self._step_key(mp, path, K_n, niter, use_kernel, use_fused,
                              precision, block_size, fused_zbuild, objective,
                              warm_start)
        with self._lock:
            step = self._steps.get(skey)
            if step is not None:
                # LRU touch: hot steps survive the executable bound
                self._steps[skey] = self._steps.pop(skey)
            else:
                ms = dict(mode=mp.mode, R_pad=mp.R_pad, Lp=mp.Lp,
                          S_pad=mp.S_pad, P=mp.P, use_kernel=use_kernel,
                          use_fused=use_fused, precision=precision,
                          block_size=int(block_size),
                          fused_zbuild=fused_zbuild,
                          warm_start=warm_start)
                if path == "zbuild":
                    fn = make_zbuild_step_fn(ms, use_kernel,
                                             precision=precision)
                    smap = shard_map_compat(
                        fn, self.mesh,
                        in_specs=(P("ranks"),) * 3 + (P(),),
                        out_specs=P("ranks"),
                    )
                else:
                    backend = resolve_backend(path, self.P)
                    fn = make_mode_step_fn(ms, backend, K_n, niter)
                    smap = shard_map_compat(
                        fn, self.mesh,
                        in_specs=(P("ranks"),) * 8 + (P(), P()),
                        out_specs=(P("ranks"), P()),
                    )
                step = jax.jit(smap)
                self._steps[skey] = step
                while len(self._steps) > MAX_COMPILED_STEPS:
                    old = next(iter(self._steps))
                    del self._steps[old]
                    # a re-created callable gets a fresh jit cache: its
                    # compilations must be counted again
                    self._seen_shapes = {
                        s for s in self._seen_shapes if s[0] != old}
        return skey, step

    def _note_shapes(self, skey, shapes, tally: dict) -> None:
        # jit compiles exactly when it first sees a shape signature for this
        # callable; mirror that condition to count compilations faithfully.
        # ``tally`` is the per-run ledger: concurrent runs on one shared
        # executor must not read each other's work out of the cumulative
        # counters.
        with self._lock:
            if (skey, shapes) in self._seen_shapes:
                self._stats["step_cache_hits"] += 1
                tally["step_cache_hits"] += 1
            else:
                self._seen_shapes.add((skey, shapes))
                self._stats["step_compilations"] += 1
                tally["step_compilations"] += 1

    def _call_step(self, skey, step, dev_args, factors, key, tally: dict):
        shapes = tuple(a.shape for a in dev_args) + tuple(
            f.shape for f in factors)
        self._note_shapes(skey, shapes, tally)
        return step(*dev_args, factors, key)

    def _get_upload(self, pl: PartitionPlan, t: SparseTensor,
                    tally: dict) -> _PlanUpload:
        with self._lock:
            up = self._uploads.get(pl)
            if up is None:
                up = self._uploads_by_parts.get(id(pl.parts))
                if up is not None:  # plan copy sharing resident arrays
                    self._uploads[pl] = up
            if up is not None:
                self._stats["upload_cache_hits"] += 1
                tally["upload_cache_hits"] += 1
                return up
        # positional layout pinned by the engine's step functions
        dev_args = tuple(
            tuple(jnp.asarray(getattr(mp, f)) for f in ARRAY_FIELDS)
            for mp in pl.parts)
        row_perms = tuple(jnp.asarray(mp.row_perm) for mp in pl.parts)
        up = _PlanUpload(
            dev_args=dev_args,
            row_perms=row_perms,
            coords=jnp.asarray(t.coords, jnp.int32),
            values=jnp.asarray(t.values, jnp.float32),
            n_arrays=(len(ARRAY_FIELDS) + 1) * len(pl.parts) + 2,
        )
        with self._lock:
            won = self._uploads.setdefault(pl, up)
            if won is up:
                self._uploads_by_parts[id(pl.parts)] = up
            # the setdefault loser still paid a (discarded) transfer: count
            # its arrays as uploads either way so stats reflect real traffic
            self._stats["uploads"] += up.n_arrays
            tally["uploads"] += up.n_arrays
        return won

    # ------------------------------------------------------------ staging
    def stage_upload(self, pl: PartitionPlan, t: SparseTensor) -> dict:
        """Move a plan's device arrays host->device *now*, off the hot path.

        Safe to call from a producer thread (device puts are thread-safe;
        no computation is dispatched): the streaming scheduler stages
        uploads for tensor k+1 while the consumer thread sweeps tensor k,
        so the subsequent ``run`` on the same plan finds everything
        resident and its own upload tally is 0. Idempotent — a plan whose
        arrays are already resident transfers nothing.
        """
        tally = {"step_compilations": 0, "step_cache_hits": 0,
                 "uploads": 0, "upload_cache_hits": 0}
        self._get_upload(pl, t, tally)
        return {"uploads": tally["uploads"],
                "already_resident": tally["upload_cache_hits"] > 0}

    def prepare(
        self,
        t: SparseTensor,
        core_dims: Sequence[int],
        scheme: str | Scheme | PartitionPlan = "auto",
        *,
        path: str = "liteopt",
        plan_seed: int = 0,
        pad_geometric: bool = False,
        objective=None,
        metrics=None,
    ) -> tuple[PartitionPlan, dict]:
        """Host-side half of a run: build/fetch the plan and stage uploads.

        This is the submission API the streaming scheduler drives from its
        producer pool — everything here is host work (numpy partitioning +
        device puts), no compilation and no sweep. Returns the plan and the
        staging report; a following ``run(t, core_dims, plan)`` is then a
        pure device hot path. ``objective`` shapes the staged view
        (completion partitions and uploads only its training entries) and
        stamps the plan; pass the same objective to the following ``run``.
        ``metrics`` (prebuilt-``Scheme`` only) supplies incrementally
        maintained ``SchemeMetrics``, skipping the O(nnz) recompute — the
        scheduler's repartition path hands its ``MetricsExtender`` output
        here.
        """
        assert path in RUN_PATHS
        obj = resolve_objective(objective)
        t = obj.prepare_tensor(t)
        if isinstance(scheme, PartitionPlan):
            pl = scheme
            self._check_plan(pl, t, core_dims, path, obj.name)
        else:
            pl = build_plan(t, scheme, self.P, core_dims=tuple(core_dims),
                            path=path, seed=plan_seed,
                            pad_geometric=pad_geometric, objective=obj,
                            metrics=metrics)
        return pl, self.stage_upload(pl, t)

    # ------------------------------------------------------------ observe
    def stats(self) -> dict:
        """Cumulative counters + cache occupancy."""
        with self._lock:
            return dict(self._stats, cached_steps=len(self._steps),
                        cached_plans=len(self._uploads))

    def calibration_samples(self) -> list[dict]:
        """Measured sweeps (flops/bytes/seconds) for ``fit_cost_model``."""
        with self._lock:
            return [dict(s) for s in self._samples]

    def profile_phases(
        self,
        t: SparseTensor,
        core_dims: Sequence[int],
        scheme: str | Scheme | PartitionPlan = "lite",
        *,
        path: str = "liteopt",
        plan_seed: int = 0,
        use_kernel: bool | None = None,
        use_fused_oracle: bool | None = None,
        precision: str | None = None,
        lanczos_block: int | None = None,
        fused_zbuild: bool | None = None,
        warm_start: str | None = None,
        repeats: int = 3,
        seed: int = 0,
        objective=None,
    ) -> dict:
        """Measure per-phase sweep times: TTM (Z build) vs Lanczos/SVD.

        Runs the Z-build-only step (``zbuild`` — same kernel/fallback choice
        as a real sweep) and the full mode step per mode, compiled first and
        then timed over ``repeats`` warm calls. Appends two calibration
        samples — a pure-TTM one (``svd_flops=0, comm_bytes=0``) and a full
        sweep — so ``fit_cost_model`` gets a full-rank per-phase design even
        from a single plan. Returns per-mode and total timings.

        ``precision`` labels the appended samples, so ``fit_cost_model``
        can fit a separate bf16 TTM rate for the ``auto`` precision policy.
        """
        assert path in RUN_PATHS
        tally = {"step_compilations": 0, "step_cache_hits": 0,
                 "uploads": 0, "upload_cache_hits": 0}
        obj = resolve_objective(objective)
        t = obj.prepare_tensor(t)
        if isinstance(scheme, PartitionPlan):
            pl = scheme
            self._check_plan(pl, t, core_dims, path, obj.name)
        else:
            pl = build_plan(t, scheme, self.P, core_dims=tuple(core_dims),
                            path=path, seed=plan_seed, objective=obj)
        N = t.ndim
        parts = pl.parts
        prec = resolve_precision(precision)
        blk = resolve_block_size(lanczos_block)
        fz = resolve_fused_zbuild(fused_zbuild)
        warm = resolve_warm_start(warm_start)
        specs = self._mode_specs(pl, core_dims, path, use_kernel,
                                 precision=prec, block_size=blk,
                                 fused_zbuild=fz, objective=obj.name,
                                 warm_start=warm)
        up = self._get_upload(pl, t, tally)
        key = jax.random.PRNGKey(seed)
        factors = random_factors(t.shape, core_dims, key)
        z_kernel = {n: specs[n].use_kernel for n in range(N)}

        def _timed(fn, *args):
            out = fn(*args)  # compile + warm
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(repeats):
                out = fn(*args)
            jax.block_until_ready(out)
            return (time.perf_counter() - t0) / repeats

        per_mode = {}
        ttm_s = full_s = 0.0
        fshapes = tuple(f.shape for f in factors)
        for n in range(N):
            sp = specs[n]
            zkey, zstep = self._get_step(parts[n], "zbuild", sp.K_n,
                                         use_kernel=sp.use_kernel,
                                         precision=sp.precision)
            skey, step = self._get_step(parts[n], sp.backend, sp.K_n,
                                        use_kernel=sp.use_kernel,
                                        niter=sp.niter,
                                        use_fused=bool(use_fused_oracle),
                                        precision=sp.precision,
                                        block_size=sp.block_size,
                                        fused_zbuild=sp.fused_zbuild,
                                        objective=sp.objective,
                                        warm_start=sp.warm_start)
            kk = jax.random.fold_in(key, 7000 + n)
            # register the shape signatures exactly like a run() would, so a
            # later run() on these shapes sees them as already-compiled (the
            # 0-new-compilations reuse contract) and its first sweep is not
            # mis-flagged cold
            self._note_shapes(
                zkey, tuple(a.shape for a in up.dev_args[n][:3]) + fshapes,
                tally)
            self._note_shapes(
                skey, tuple(a.shape for a in up.dev_args[n]) + fshapes,
                tally)
            tz = _timed(zstep, *up.dev_args[n][:3], factors)
            tf = _timed(step, *up.dev_args[n], factors, kk)
            per_mode[n] = {"ttm_s": tz, "full_s": tf,
                           "svd_s": max(tf - tz, 0.0)}
            ttm_s += tz
            full_s += tf
        m = pl.metrics
        backend_label = _backend_label(specs)
        with self._lock:
            self._samples.append({
                "critical_path_flops": m.ttm_flops_max,
                "ttm_flops": m.ttm_flops_max, "svd_flops": 0,
                "comm_bytes": 0.0, "seconds": ttm_s, "warm": True,
                "P": self.P, "path": path, "scheme": pl.name,
                "phase": "ttm", "kernel": all(z_kernel.values()),
                "comm_backend": backend_label, "precision": prec,
            })
            self._samples.append({
                "critical_path_flops": m.critical_path_flops,
                "ttm_flops": m.ttm_flops_max,
                "svd_flops": m.svd_flops_max,
                "comm_bytes": _run_comm_bytes(pl, specs),
                "seconds": full_s,
                "warm": True, "P": self.P, "path": path, "scheme": pl.name,
                "phase": "sweep", "kernel": all(z_kernel.values()),
                "comm_backend": backend_label, "precision": prec,
            })
        return {"ttm_s": ttm_s, "full_s": full_s,
                "svd_s": max(full_s - ttm_s, 0.0),
                "per_mode": per_mode, "z_kernel": z_kernel}

    # ---------------------------------------------------------------- run
    def run(
        self,
        t: SparseTensor,
        core_dims: Sequence[int],
        scheme: str | Scheme | PartitionPlan = "lite",
        *,
        n_invocations: int = 3,
        path: str = "liteopt",
        seed: int = 0,
        plan_seed: int = 0,
        use_kernel: bool | None = None,
        use_fused_oracle: bool | None = None,
        precision: str | None = None,
        lanczos_block: int | None = None,
        fused_zbuild: bool | None = None,
        warm_start: str | None = None,
        init_factors: Sequence[jnp.ndarray] | None = None,
        pad_geometric: bool = False,
        objective=None,
    ) -> tuple[Decomposition, DistHooiStats]:
        """One distributed HOOI decomposition on this executor's mesh.

        ``scheme`` is the string sugar (any name ``repro.core.plan.plan``
        accepts, including ``"auto"``), a prebuilt ``Scheme``, or a full
        ``PartitionPlan``. String/Scheme forms go through the content-keyed
        plan cache with ``plan_seed`` threaded to randomized schemes; a
        cached plan additionally reuses this executor's device uploads and
        compiled steps.

        ``path`` selects the comm-backend family: ``"baseline"`` (psum),
        ``"liteopt"`` (boundary) or ``"auto"`` (per mode from the plan's
        analytic comm model); P=1 always resolves to the collective-free
        ``local`` backend. ``use_kernel`` selects the Z-build variant per
        mode step (see ``repro.engine.zbuild.resolve_kernel``);
        ``use_fused_oracle`` (None/False = off) routes the Lanczos oracle
        products through the fused Pallas kernel.

        Roofline knobs (resolved through the same engine resolvers
        single-process ``hooi`` uses, so P=1 parity holds per variant):
        ``precision`` — ``"f32"``/``"bf16"``/``"auto"``/None (None honors
        ``REPRO_PRECISION``); ``lanczos_block`` — requested s-step Lanczos
        panel width, clamped per mode (None honors
        ``REPRO_LANCZOS_BLOCK``); ``fused_zbuild`` — fuse the Z build with
        the first oracle panel product (None honors ``REPRO_FUSED_ZBUILD``).
        Every knob is part of the compiled-step cache key.

        ``pad_geometric`` must match how the tensor was prepared: it is
        part of the plan-cache key, so a ``prepare(..., pad_geometric=
        True)`` followed by a string/Scheme ``run`` with the default would
        silently build (and upload, and compile) a second tight-pad plan.

        ``warm_start`` — ``"none"``/``"sketch"``/``"auto"``/None (None
        honors ``REPRO_WARM_START``): seed the oracle's block driver with
        the factor-sketched range-finder panel under the reduced
        ``sketch_niter`` budget; ``"none"`` reproduces the historical
        trajectories bitwise. ``init_factors`` (default None = the
        seed-keyed ``random_factors``) carries previous factors into this
        run — the streaming scheduler hands the prior decomposition here so
        the sketch warm start persists across runs and across the
        ``reselect`` rung; widths are coerced to ``core_dims`` (truncate /
        orthonormal-complete) when adaptive rank changed them.
        """
        assert path in RUN_PATHS
        # per-run ledger: deltas must be this run's own work, not whatever
        # a concurrent run on the shared executor did meanwhile
        tally = {"step_compilations": 0, "step_cache_hits": 0,
                 "uploads": 0, "upload_cache_hits": 0}
        obj = resolve_objective(objective)
        t = obj.prepare_tensor(t)
        t_plan = time.perf_counter()
        if isinstance(scheme, PartitionPlan):
            pl = scheme
            self._check_plan(pl, t, core_dims, path, obj.name)
            cache_hit = False
        else:
            pl = build_plan(t, scheme, self.P, core_dims=tuple(core_dims),
                            path=path, seed=plan_seed,
                            pad_geometric=pad_geometric, objective=obj)
            # thread-local outcome: differencing the global miss counter
            # misreports hits when a concurrent submitter builds a plan in
            # the same window (the pool's producer threads routinely do)
            cache_hit = last_plan_call_cache_hit()
        partition_build_s = time.perf_counter() - t_plan

        N = t.ndim
        key = jax.random.PRNGKey(seed)
        if init_factors is None:
            factors = random_factors(t.shape, core_dims, key)
        else:
            factors = _coerce_factors(init_factors, t.shape, core_dims, key)
        parts = pl.parts
        comm = {n: pl.comm(n) for n in range(N)}

        fused = bool(use_fused_oracle)
        prec = resolve_precision(precision)
        blk = resolve_block_size(lanczos_block)
        fz = resolve_fused_zbuild(fused_zbuild)
        warm = resolve_warm_start(warm_start)
        specs = self._mode_specs(pl, core_dims, path, use_kernel,
                                 precision=prec, block_size=blk,
                                 fused_zbuild=fz, objective=obj.name,
                                 warm_start=warm)
        z_kernel = {n: specs[n].use_kernel for n in range(N)}
        steps = [self._get_step(parts[n], specs[n].backend, specs[n].K_n,
                                use_kernel=specs[n].use_kernel,
                                niter=specs[n].niter, use_fused=fused,
                                precision=specs[n].precision,
                                block_size=specs[n].block_size,
                                fused_zbuild=specs[n].fused_zbuild,
                                objective=specs[n].objective,
                                warm_start=specs[n].warm_start)
                 for n in range(N)]
        up = self._get_upload(pl, t, tally)
        backend_label = _backend_label(specs)
        run_bytes = _run_comm_bytes(pl, specs)

        spectra: dict = {}

        def mode_step(n, facs, kk):
            skey, step = steps[n]
            F_new, sv = self._call_step(skey, step, up.dev_args[n],
                                        facs, kk, tally)
            # last-sweep spectrum estimate per mode (overwritten each
            # sweep) — the adaptive-rank policy reads its tail
            spectra[n] = sv
            # F_new rows are in relabelled space; restore original order,
            # then let the objective post-process the full-row factor —
            # the exact update the local engine path applies, so P=1
            # parity covers every objective
            return obj.refine_factor(jnp.asarray(F_new)[up.row_perms[n]],
                                     jnp.asarray(sv))

        sweep_state = {"compiles": tally["step_compilations"]}

        def on_sweep(it, sweep_s, _fit):
            with self._lock:
                self._samples.append({
                    "critical_path_flops": pl.metrics.critical_path_flops,
                    # per-phase split (bottleneck-rank flops): lets
                    # fit_cost_model separate the TTM and Lanczos/SVD rates
                    "ttm_flops": pl.metrics.ttm_flops_max,
                    "svd_flops": pl.metrics.svd_flops_max,
                    "comm_bytes": run_bytes,
                    "seconds": sweep_s,
                    # sweeps that paid jit time measure XLA, not the machine
                    "warm": tally["step_compilations"]
                    == sweep_state["compiles"],
                    "P": self.P,
                    "path": path,
                    "scheme": pl.name,
                    # True when every mode's Z build ran the Pallas kernel —
                    # rates fitted from kernel sweeps are kernel-speed rates
                    "kernel": all(z_kernel.values()),
                    "comm_backend": backend_label,
                    "precision": prec,
                })
            sweep_state["compiles"] = tally["step_compilations"]

        objective_metrics: dict = {}
        dec, fits = run_hooi_sweeps(up.coords, up.values, t, factors, key,
                                    n_invocations, mode_step,
                                    on_sweep=on_sweep, objective=obj,
                                    metrics_out=objective_metrics)

        with self._lock:
            self._stats["runs"] += 1
        stats = DistHooiStats(
            fits=fits, comm=comm,
            r_pad={n: parts[n].R_pad for n in range(N)},
            e_pad={n: parts[n].E_pad for n in range(N)},
            scheme=pl.name,
            selection=pl.candidates,
            partition_build_s=partition_build_s,
            plan_cache_hit=cache_hit,
            plan_cache=plan_cache_stats(),
            step_compilations=tally["step_compilations"],
            step_cache_hits=tally["step_cache_hits"],
            uploads=tally["uploads"],
            upload_cache_hit=tally["upload_cache_hits"] > 0,
            executor=self.stats(),
            z_kernel=z_kernel,
            comm_backends={n: specs[n].backend for n in range(N)},
            fused_oracle=fused,
            precision=prec,
            lanczos_block={n: specs[n].block_size for n in range(N)},
            fused_zbuild=fz,
            z_passes={n: count_z_passes(
                specs[n].niter, specs[n].fused_zbuild,
                warm_start=specs[n].warm_start,
                power_iters=DEFAULT_POWER_ITERS
                if specs[n].warm_start == "sketch" else 0)
                for n in range(N)},
            objective=obj.name,
            objective_metrics=objective_metrics or None,
            warm_start={n: specs[n].warm_start for n in range(N)},
            mode_spectra={n: np.asarray(v) for n, v in spectra.items()}
            or None,
        )
        return dec, stats

    # ----------------------------------------------------- stochastic rung
    def _get_stoch_step(self, mode: int, num_rows: int, K_n: int, niter: int,
                        block_size: int, use_kernel: bool, precision: str,
                        objective: str, sample_fraction: float,
                        sample_seed: int):
        """Jitted minibatch step, cached in the same LRU as the shard_map
        steps. The key carries the sample fraction and seed (the ISSUE's
        rerun discipline: a rerun of the same sampled refine is 0 new jit,
        a different sampling policy never aliases a compiled step) plus
        every static trace parameter; the padded minibatch shape is jit's
        own specialization axis, counted by ``_note_shapes`` exactly like
        the distributed steps."""
        skey = ("stoch", int(mode), int(num_rows), int(K_n), int(niter),
                int(block_size), precision,
                "kern" if use_kernel else "ref", objective,
                float(sample_fraction), int(sample_seed))
        with self._lock:
            step = self._steps.get(skey)
            if step is not None:
                self._steps[skey] = self._steps.pop(skey)
            else:
                step = jax.jit(make_stochastic_step_fn(
                    int(mode), int(num_rows), int(K_n), int(niter),
                    int(block_size), use_kernel=use_kernel,
                    precision=precision))
                self._steps[skey] = step
                while len(self._steps) > MAX_COMPILED_STEPS:
                    old = next(iter(self._steps))
                    del self._steps[old]
                    self._seen_shapes = {
                        s for s in self._seen_shapes if s[0] != old}
        return skey, step

    def _get_stoch_upload(self, t: SparseTensor, obj, sb,
                          covered_nnz: int, sample_fraction: float,
                          sample_seed: int, replay_nnz: int,
                          tally: dict) -> tuple:
        """Device arrays for one stochastic refine: the padded minibatch
        plus the full-snapshot COO (fit/core accounting). The full arrays
        are zero-padded to the next power of two as well — coordinate-0 /
        value-0 rows contribute nothing to the elementwise core build, and
        the pow2 shape keeps the jitted full-pass core computation
        (``_get_stoch_core``) compiled across many appends. Keyed on
        everything ``sample_batch``'s output is a pure function of, so a
        rerun of the same refine transfers nothing."""
        ukey = (t.fingerprint(), obj.cache_token(), float(sample_fraction),
                int(sample_seed), int(covered_nnz), int(replay_nnz))
        with self._lock:
            up = self._stoch_uploads.get(ukey)
            if up is not None:
                self._stoch_uploads.move_to_end(ukey)
                self._stats["upload_cache_hits"] += 1
                tally["upload_cache_hits"] += 1
                return up
        pad = next_pow2(int(t.nnz)) - int(t.nnz)
        full_coords = np.pad(np.asarray(t.coords), ((0, pad), (0, 0)))
        full_values = np.pad(np.asarray(t.values), (0, pad))
        up = (jnp.asarray(sb.coords, jnp.int32),
              jnp.asarray(sb.values, jnp.float32),
              jnp.asarray(full_coords, jnp.int32),
              jnp.asarray(full_values, jnp.float32))
        with self._lock:
            won = self._stoch_uploads.setdefault(ukey, up)
            self._stoch_uploads.move_to_end(ukey)
            while len(self._stoch_uploads) > MAX_STOCH_UPLOADS:
                self._stoch_uploads.popitem(last=False)
            self._stats["uploads"] += len(up)
            tally["uploads"] += len(up)
        return won

    def _get_stoch_core(self):
        """Jitted full-pass core build (``core_from_factors``) for the
        stochastic rung's final fit accounting. One O(nnz) device pass per
        refine instead of the sweep loop's eager per-sweep build; the pow2
        padding of the full upload keeps its compiled shape stable across
        appends, so steady-state refines replay it with zero tracing."""
        skey = ("stochcore",)
        with self._lock:
            fn = self._steps.get(skey)
            if fn is not None:
                self._steps[skey] = self._steps.pop(skey)
            else:
                from repro.core.ttm import core_from_factors

                fn = jax.jit(core_from_factors)
                self._steps[skey] = fn
                while len(self._steps) > MAX_COMPILED_STEPS:
                    old = next(iter(self._steps))
                    del self._steps[old]
                    self._seen_shapes = {
                        s for s in self._seen_shapes if s[0] != old}
        return skey, fn

    def run_stochastic(
        self,
        t: SparseTensor,
        core_dims: Sequence[int],
        pl: PartitionPlan,
        *,
        init_factors: Sequence[jnp.ndarray],
        covered_nnz: int,
        sample_fraction: float,
        sample_seed: int = 0,
        replay_nnz: int = 1024,
        step_size: float = 0.5,
        step_decay: float = 0.5,
        step_index: int = 0,
        n_invocations: int = 1,
        seed: int = 0,
        use_kernel: bool | None = None,
        precision: str | None = None,
        objective=None,
    ) -> tuple[Decomposition, DistHooiStats]:
        """One stochastic-refine pass: update carried factors from a
        deterministic minibatch of the appended elements (plus a replay
        reservoir of the refined prefix) instead of a full sweep.

        ``pl`` is the stream's *adopted* plan — it stays untouched (its
        partitions describe the pre-append prefix; the whole point of the
        rung is not rebuilding them) and contributes its identity checks
        (P, objective, core_dims) and modeled cost only. The fingerprint is
        deliberately *not* checked against ``t``: the snapshot has grown
        past the plan by construction.

        Device work is O(minibatch): each mode runs the jitted
        single-device ``make_stochastic_step_fn`` (sampled Z-build through
        the same kernel/reference seam, sketch-seeded from the carried
        factor), the returned basis is Procrustes-blended into the carried
        factor at ``eta = step_size / (1 + step_decay * step_index)``
        (``core.stochastic``), and the objective's ``refine_factor`` runs
        after the blend — the same post-oracle discipline as the full path.
        The only O(nnz) device work is the final core/fit accounting: one
        jitted pass over the pow2-padded full snapshot per refine
        (``_get_stoch_core``), where a full sweep pays an O(nnz) Z-build
        per mode per invocation.

        ``init_factors`` is required: the rung refines carried factors;
        there is nothing to refine on a cold stream (the scheduler routes
        first sight to ``"plan"``).
        """
        tally = {"step_compilations": 0, "step_cache_hits": 0,
                 "uploads": 0, "upload_cache_hits": 0}
        obj = resolve_objective(objective)
        t = obj.prepare_tensor(t)
        if pl.P != self.P:
            raise ValueError(
                f"plan built for P={pl.P}, executor has P={self.P}")
        if pl.objective != obj.name:
            raise ValueError(
                f"plan was built for objective={pl.objective!r}, asked to "
                f"refine under {obj.name!r}")
        if tuple(pl.core_dims) != tuple(int(k) for k in core_dims):
            raise ValueError(
                f"plan modeled core_dims={pl.core_dims}, asked to refine "
                f"{tuple(core_dims)}")
        if init_factors is None:
            raise ValueError("stochastic refine needs carried factors "
                             "(init_factors) — a cold stream takes the "
                             "full plan path")

        N = t.ndim
        key = jax.random.PRNGKey(seed)
        factors = _coerce_factors(init_factors, t.shape, core_dims, key)
        sb = sample_batch(np.asarray(t.coords), np.asarray(t.values),
                          covered_nnz, sample_fraction, sample_seed,
                          replay_nnz=replay_nnz)
        up = self._get_stoch_upload(t, obj, sb, covered_nnz,
                                    sample_fraction, sample_seed,
                                    replay_nnz, tally)
        sb_coords, sb_values, full_coords, full_values = up

        prec = resolve_precision(precision)
        eff = tuple(min(int(k), int(L))
                    for k, L in zip(core_dims, t.shape))
        eta = step_eta(step_size, step_decay, step_index)
        steps = []
        z_kernel = {}
        lanczos_block = {}
        for n in range(N):
            L = int(t.shape[n])
            K_n = int(eff[n])
            khat = int(np.prod([eff[j] for j in range(N) if j != n]))
            s_eff = sketch_block_size(K_n, L, khat, 1)
            niter = sketch_niter(K_n, L, khat, s_eff)
            kern = engine_zbuild.resolve_kernel(L, eff, n, use_kernel)
            z_kernel[n] = kern
            lanczos_block[n] = s_eff
            steps.append(self._get_stoch_step(
                n, L, K_n, niter, s_eff, kern, prec, obj.name,
                sample_fraction, sample_seed))

        spectra: dict = {}

        def mode_step(n, facs, kk):
            skey, step = steps[n]
            shapes = (sb_coords.shape, sb_values.shape) + tuple(
                f.shape for f in facs)
            self._note_shapes(skey, shapes, tally)
            left, sv = step(sb_coords, sb_values, facs, kk)
            spectra[n] = sv
            blended = blend_factor(facs[n], left, eta)
            return obj.refine_factor(blended, jnp.asarray(sv))

        # the sweep loop runs over the MINIBATCH: its per-sweep core/fit
        # accounting is then O(minibatch) like the steps themselves. The
        # true core and fit are computed once afterwards from the padded
        # full snapshot via the jitted full-pass builder — one O(nnz)
        # device pass per refine, against a full sweep's one per mode
        # per invocation.
        dec, fits = run_hooi_sweeps(sb_coords, sb_values, t, factors,
                                    key, n_invocations, mode_step,
                                    objective=obj)
        ckey, core_fn = self._get_stoch_core()
        self._note_shapes(
            ckey, (full_coords.shape, full_values.shape) + tuple(
                f.shape for f in dec.factors), tally)
        core = obj.finalize_core(
            core_fn(full_coords, full_values, dec.factors), dec.factors)
        dec = Decomposition(core=core, factors=dec.factors)
        fits = fits[:-1] + [obj.fit(t, core, dec.factors)]
        objective_metrics: dict = {}
        obj.sweep_metrics(objective_metrics, t, core, dec.factors)
        with self._lock:
            self._stats["runs"] += 1
        stats = DistHooiStats(
            fits=fits, comm={},
            r_pad={}, e_pad={},
            scheme=pl.name,
            step_compilations=tally["step_compilations"],
            step_cache_hits=tally["step_cache_hits"],
            uploads=tally["uploads"],
            upload_cache_hit=tally["upload_cache_hits"] > 0,
            executor=self.stats(),
            z_kernel=z_kernel,
            comm_backends={n: "local" for n in range(N)},
            precision=prec,
            lanczos_block=lanczos_block,
            objective=obj.name,
            objective_metrics=objective_metrics or None,
            warm_start={n: "sketch" for n in range(N)},
            mode_spectra={n: np.asarray(v) for n, v in spectra.items()}
            or None,
            sample_fraction=float(sample_fraction),
            sample_nnz=int(sb.sample_nnz),
            replay_nnz=int(sb.replay_nnz),
            step_size=float(eta),
        )
        return dec, stats


def _coerce_factors(factors, shape: Sequence[int],
                    core_dims: Sequence[int],
                    key: jax.Array) -> list[jnp.ndarray]:
    """Fit carried-over factors to this run's (shape, core_dims).

    The streaming scheduler hands the previous run's factors back as
    ``init_factors`` so the sketch warm start seeds from real structure.
    When the adaptive-rank policy changed a mode's ``K_n`` the carried
    factor is truncated (shrink) or completed with an orthonormalized
    random complement (grow) — deterministic per (key, mode), mirroring
    ``random_factors``' key discipline.
    """
    out = []
    for n, (L, K) in enumerate(zip(shape, core_dims)):
        F = jnp.asarray(factors[n], jnp.float32)
        if int(F.shape[0]) != int(L):
            raise ValueError(
                f"init_factors[{n}] has {F.shape[0]} rows, tensor mode has "
                f"{L} — factors carry across runs on the same mode sizes")
        K = min(int(K), int(L))  # random_factors' reduced-QR clamp
        if int(F.shape[1]) > K:
            F = F[:, :K]
        elif int(F.shape[1]) < K:
            extra = jax.random.normal(
                jax.random.fold_in(key, 4100 + n),
                (int(L), K - int(F.shape[1])), jnp.float32)
            F, _ = jnp.linalg.qr(jnp.concatenate([F, extra], axis=1))
        out.append(F)
    return out


def _backend_label(specs: Sequence[_ModeSpec]) -> str:
    """One calibration label per run: the uniform backend or 'mixed'."""
    names = {sp.backend for sp in specs}
    return names.pop() if len(names) == 1 else "mixed"


def _run_comm_bytes(pl: PartitionPlan, specs: Sequence[_ModeSpec]) -> float:
    """Modeled comm bytes for the backends that actually run.

    A plan may legally run under a different backend family than it was
    costed for (auto-costed plan under an explicit path, and vice versa);
    calibration samples must pair measured seconds with the bytes of the
    *executed* backends, not ``pl.cost.comm_bytes``, or fitted per-backend
    bandwidths would be biased by the mismatch.
    """
    from repro.engine.comm import backend_comm_bytes

    total = pl.metrics.fm_volume * 4.0
    for n, sp in enumerate(specs):
        total += backend_comm_bytes(sp.backend, pl.comm(n))
    return total


# ------------------------------------------------------- shared executors
_SHARED: dict[int, HooiExecutor] = {}  # default-mesh executors, keyed by P
# caller-provided meshes: content-keyed (jax Mesh equality/hash compare
# devices + axis names, so fresh-but-equal meshes share one executor) and
# LRU-bounded — an executor pins its mesh and compiled steps, and the old
# per-call dist_hooi never retained any of that
_SHARED_BY_MESH: dict[object, HooiExecutor] = {}
MAX_SHARED_MESH_EXECUTORS = 8
_SHARED_LOCK = threading.Lock()


def shared_executor(P_ranks: int, mesh=None) -> HooiExecutor:
    """Process-wide executor for (P, mesh) — what ``dist_hooi`` runs on.

    Sharing the executor is what makes repeated ``dist_hooi`` calls (and
    interleaved calls on different cached tensors — multi-tensor batching)
    skip jit and host->device transfer without any caller-side plumbing.
    """
    P_ranks = int(P_ranks)
    with _SHARED_LOCK:
        if mesh is None:
            ex = _SHARED.get(P_ranks)
            if ex is None:
                ex = HooiExecutor(P_ranks)
                _SHARED[P_ranks] = ex
            return ex
        ex = _SHARED_BY_MESH.get(mesh)
        if ex is not None and ex.P == P_ranks:
            # LRU touch: hot meshes survive the bound
            _SHARED_BY_MESH[mesh] = _SHARED_BY_MESH.pop(mesh)
            return ex
        ex = HooiExecutor(P_ranks, mesh=mesh)
        _SHARED_BY_MESH[mesh] = ex
        while len(_SHARED_BY_MESH) > MAX_SHARED_MESH_EXECUTORS:
            _SHARED_BY_MESH.pop(next(iter(_SHARED_BY_MESH)))
        return ex
