"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full production substrate — pjit-sharded params, AdamW + cosine
schedule, deterministic data pipeline, atomic checkpoints with auto-resume,
straggler watchdog. On CPU the default profile is a 30M-class model and 300
steps (~minutes); pass --full for the 110M-class profile.

  PYTHONPATH=src python examples/train_lm.py [--full] [--steps 300]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import ArchConfig, register
from repro.launch.train import train_main


def _mini_lm(d, L, ff, vocab, name) -> ArchConfig:
    return ArchConfig(
        name=name, family="dense", n_layers=L, d_model=d, n_heads=8,
        n_kv_heads=4, d_ff=ff, vocab=vocab, layout=(("dense", L),),
        tie_embeddings=True, rope_theta=10_000.0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="110M-class model (slower on CPU)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.full:
        cfg = _mini_lm(640, 12, 2560, 32_000, "demo-lm-110m")
    else:
        cfg = _mini_lm(384, 8, 1536, 8_192, "demo-lm-30m")
    register(cfg.name, lambda: cfg, lambda: cfg)
    print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    res = train_main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--lr", "1e-3", "--log-every", "20",
    ])
    assert res["last_loss"] < res["first_loss"], "loss did not improve"
    print(f"[example] loss improved {res['first_loss']:.3f} -> "
          f"{res['last_loss']:.3f} over {res['steps']} steps")


if __name__ == "__main__":
    main()
