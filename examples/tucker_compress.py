"""Tucker-compress an embedding-style weight table with the paper's machinery.

Synthesizes a low-rank-plus-noise embedding table (the spectrum trained
token embeddings actually have), reshapes it to a 3-way tensor, sparsifies
by magnitude (top-k%), and runs the sparse Tucker pipeline — real-time
scheme selection, the distributed executor with its reuse caches, measured
calibration, and finally the streaming scheduler serving a stream of
updated tables with host partitioning overlapped against device sweeps.

  PYTHONPATH=src python examples/tucker_compress.py
"""

import os
import sys

sys.path.insert(0, "src")
# 8 simulated host devices so the HooiExecutor section can run a real
# distributed decomposition (must be set before jax initializes; append so
# a user-provided XLA_FLAGS keeps its other options)
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import jax
import numpy as np

from repro.core.calibrate import fit_cost_model, set_cost_model
from repro.core.coo import SparseTensor
from repro.core.hooi import hooi
from repro.core.plan import plan
from repro.distributed.executor import HooiExecutor
from repro.engine.scheduler import StreamScheduler
from repro.streaming import StreamingTensor


def make_table(V: int = 4096, d1: int = 16, d2: int = 16,
               seed: int = 0, noise: float = 0.02) -> np.ndarray:
    """A (V, d1*d2) embedding table with genuine Tucker structure.

    Trained embeddings factor into token clusters x feature subspaces; we
    emulate that spectrum directly: a rank-(16,4,4) Tucker tensor over the
    reshaped table plus a small dense residual.
    """
    rng = np.random.default_rng(seed)
    G = rng.standard_normal((16, 4, 4))
    A = rng.standard_normal((V, 16)) / 4
    B = rng.standard_normal((d1, 4)) / 2
    C = rng.standard_normal((d2, 4)) / 2
    T = np.einsum("abc,ia,jb,kc->ijk", G, A, B, C)
    T += rng.standard_normal(T.shape) * noise
    return T.astype(np.float32).reshape(V, d1 * d2)


def sparsify(W: np.ndarray, keep: float = 0.20) -> SparseTensor:
    """Reshape (V, d) -> (V, d1, d2) and keep the top-|keep| magnitudes."""
    V, d = W.shape
    d1 = int(np.sqrt(d))
    while d % d1:
        d1 -= 1
    T3 = W.reshape(V, d1, d // d1)
    thresh = np.quantile(np.abs(T3), 1.0 - keep)
    return SparseTensor.fromdense(T3 * (np.abs(T3) > thresh))


def main() -> None:
    W = make_table()
    V, d = W.shape
    print(f"[compress] embedding table {V}x{d} "
          f"({W.size * 4 / 1e6:.2f} MB fp32)")
    t = sparsify(W)
    print(f"[compress] sparsified: {t}")

    core_dims = (32, 4, 4)
    dec, fits = hooi(t, core_dims, n_invocations=4, seed=0)
    dense_bytes = t.nnz * (8 + 3 * 8)
    tucker_bytes = (int(np.prod(core_dims))
                    + sum(t.shape[n] * core_dims[n] for n in range(3))) * 4
    print(f"[compress] fit={fits[-1]:.4f}  "
          f"sparse-COO {dense_bytes/1e6:.2f} MB -> Tucker "
          f"{tucker_bytes/1e6:.2f} MB ({dense_bytes/tucker_bytes:.1f}x)")

    # distribution quality for the compression job itself at P=16 — the
    # real-time selector picks the scheme; candidate plans land in the plan
    # cache, so the per-scheme report below costs no extra partitioning.
    P = 16
    auto = plan(t, "auto", P, core_dims=core_dims)
    print(f"[compress] auto selector picked {auto.name!r} "
          f"(modeled s/invocation: "
          + ", ".join(f"{c}={v:.2e}" for c, v in auto.candidates.items())
          + f"; built in {auto.build_s*1e3:.0f} ms)")
    for name in ("lite", "coarse"):
        sm = plan(t, name, P, core_dims=core_dims).metrics
        print(f"[compress] scheme={name:7s} "
              f"E_imb={max(m.ttm_imbalance for m in sm.per_mode):.2f} "
              f"R_red={max(m.svd_redundancy for m in sm.per_mode):.2f}")
    assert fits[-1] > 0.15, "Tucker failed to capture structure"

    # run the compression distributed on the engine: the second sweep batch
    # (e.g. recompressing after a fine-tune step) reuses the compiled mode
    # steps and the device-resident partition arrays — zero new jit, zero
    # new host->device transfer. Adapt to however many devices jax actually
    # has (a user-provided XLA_FLAGS may force a different count).
    P_exec = min(8, len(jax.devices()))
    ex = HooiExecutor(P_exec)
    # path="auto": the plan also scores the comm backends (psum vs
    # boundary) per mode and the engine runs the modeled-cheapest one
    pl8 = plan(t, "auto", P_exec, core_dims=core_dims, path="auto")
    print(f"[compress] comm backends per mode: "
          f"{','.join(pl8.cost.mode_backends)} "
          f"(modeled comm s: "
          + ", ".join(f"{b}={v:.2e}" for b, v in pl8.cost.backend_s.items())
          + ")")
    _, st1 = ex.run(t, core_dims, pl8, n_invocations=2, seed=0, path="auto")
    _, st2 = ex.run(t, core_dims, pl8, n_invocations=2, seed=1, path="auto")
    print(f"[compress] executor run 1: fit={st1.fits[-1]:.4f} "
          f"compiled {st1.step_compilations} mode steps, "
          f"uploaded {st1.uploads} arrays")
    print(f"[compress] executor run 2: fit={st2.fits[-1]:.4f} "
          f"new compilations={st2.step_compilations}, "
          f"new uploads={st2.uploads} (cached plan)")
    assert st2.step_compilations == 0 and st2.uploads == 0

    # probe the per-phase split (TTM Z build vs Lanczos/SVD), then calibrate
    # the analytic selector from the measured sweeps and re-score: with
    # separable phase columns the fit returns distinct TTM/SVD rates, and
    # auto trades E_max against R_max under the rates this machine achieves
    prof = ex.profile_phases(t, core_dims, pl8, repeats=2)
    print(f"[compress] phase profile: ttm={prof['ttm_s']*1e3:.1f} ms "
          f"svd={prof['svd_s']*1e3:.1f} ms per sweep "
          f"(kernel={any(prof['z_kernel'].values())})")
    samples = [s for s in ex.calibration_samples() if s["warm"]]
    cm = set_cost_model(fit_cost_model(samples))
    recal = plan(t, "auto", 8, core_dims=core_dims)
    rt, rs = cm.phase_rates()
    print(f"[compress] calibrated {cm.source}: "
          f"flop_rate={cm.flop_rate:.2e} flop/s "
          f"(ttm={rt:.2e}, svd={rs:.2e}) -> "
          f"auto picks {recal.name!r} "
          f"(modeled {recal.cost.total_s:.2e} s/invocation, "
          f"ttm {recal.cost.ttm_s:.2e} + svd {recal.cost.svd_s:.2e})")
    set_cost_model(None)

    # ---- serve a STREAM of recompressions through the scheduler ---------
    # the fine-tune loop keeps nudging weights: each batch is a set of
    # value updates at existing coordinates. The scheduler overlaps the
    # host-side refresh (invalidation check + policy extension + staging)
    # of update k+1 with the device sweeps of update k, and only reruns
    # the auto selector when the §4 imbalance actually drifts.
    print("[stream] serving 3 table updates through StreamScheduler")
    rng = np.random.default_rng(1)
    stream = StreamingTensor.from_tensor(t, name="embeddings")
    with StreamScheduler(ex, core_dims, n_invocations=1,
                         path="liteopt") as sched:
        futs = [sched.submit(stream, seed=0)]
        for k in range(1, 3):
            idx = rng.integers(0, t.nnz, 200)  # touch existing coordinates
            stream.append(t.coords[idx], rng.standard_normal(200) * 0.01)
            futs.append(sched.submit(stream, seed=k))
        for r in (f.result() for f in futs):
            print(f"[stream] v{r.stream_version}: decision={r.decision:11s} "
                  f"fit={r.fits[-1]:.4f} prep={r.prepare_s*1e3:.0f}ms "
                  f"run={r.run_s*1e3:.0f}ms "
                  f"new_jit={r.stats.step_compilations} "
                  f"hot_path_uploads={r.stats.uploads}")
        st = sched.stats()
    print(f"[stream] pipeline: wall={st['wall_s']:.2f}s vs "
          f"host {st['host_s']:.2f}s + device {st['device_s']:.2f}s "
          f"(overlap hid {st['overlap_s']:.2f}s); decisions={st['decisions']}")


if __name__ == "__main__":
    main()
