"""Objective-pluggable sweeps: masked completion and nonnegative Tucker.

Act 1 — a fraction of a tensor's stored entries is corrupted (untrusted
measurements). The standard Tucker objective trains on everything and
chases the garbage; the completion objective drops exactly those entries
(masked fit) and recovers the underlying model better at the held-out
coordinates. Act 2 — the same data, FROSTT ``.tns`` round-trip: written to
disk, streamed back batch-by-batch into a ``StreamingTensor``, and decomposed
under the completion objective. Act 3 — nonnegative Tucker by ADMM on
block-structured nonneg data. See docs/objectives.md for the math.

  PYTHONPATH=src python examples/complete_masked.py
"""

import os
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.core.coo import SparseTensor, write_tns
from repro.core.hooi import hooi
from repro.data.frostt import iter_tns_batches, stream_tns
from repro.engine.objective import holdout_mask, predict_at_coords


def lowrank_sample(rng, shape, rank, nnz):
    """An exact rank-``rank`` model sampled (densely) at random coords."""
    g = rng.standard_normal(rank)
    us = [np.linalg.qr(rng.standard_normal((L, r)))[0]
          for L, r in zip(shape, rank)]
    coords = np.unique(np.stack([rng.integers(0, L, 2 * nnz) for L in shape],
                                axis=1), axis=0)[:nnz]
    vals = predict_at_coords(g, us, coords)
    return coords, vals / max(np.abs(vals).max(), 1e-12)


def main() -> None:
    rng = np.random.default_rng(0)
    shape, core = (24, 20, 18), (4, 4, 4)
    coords, true_vals = lowrank_sample(rng, shape, core, 6000)

    # corrupt the entries the completion objective will hold out
    # (fraction 0.2, seed 0 are the CompletionObjective defaults)
    held = holdout_mask(len(coords), 0.2, 0)
    vals = true_vals.copy()
    vals[held] = rng.standard_normal(int(held.sum())) * 5.0 * true_vals.std()
    t = SparseTensor(coords=coords, values=vals, shape=shape)
    print(f"== {t.nnz} observed entries, {int(held.sum())} corrupted ==")

    print("\n== Act 1: unmasked Tucker vs masked completion ==")
    for obj in ("tucker", "completion"):
        dec, fits = hooi(t, core, n_invocations=3, seed=0, objective=obj)
        pred = predict_at_coords(dec.core, dec.factors, coords[held])
        rmse = float(np.sqrt(np.mean((pred - true_vals[held]) ** 2)))
        print(f"   {obj:12s} fit={fits[-1]:.4f}  "
              f"held-out RMSE vs truth={rmse:.4f}")
    print("   -> completion ignores the corrupted entries; the baseline "
          "chases them.")

    print("\n== Act 2: FROSTT .tns round-trip through StreamingTensor ==")
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "fixture.tns")
        write_tns(path, t)
        n_batches = sum(1 for _ in iter_tns_batches(path, batch_nnz=2000))
        stream = stream_tns(path, batch_nnz=2000)
        snap = stream.snapshot()
        print(f"   {path.split('/')[-1]}: {n_batches} batches -> "
              f"version {stream.version}, nnz={snap.nnz}")
        dec, fits = hooi(snap, core, n_invocations=2, seed=0,
                         objective="completion")
        print(f"   completion on the streamed copy: fit={fits[-1]:.4f}")

    print("\n== Act 3: nonnegative Tucker (ADMM) ==")
    us_nn = []
    for L in shape:
        f = np.zeros((L, 4))
        for j in range(4):
            lo, hi = j * L // 4, (j + 1) * L // 4
            f[lo:hi, j] = np.abs(rng.standard_normal(hi - lo)) + 0.1
        us_nn.append(f)
    vals_nn = predict_at_coords(np.abs(rng.standard_normal(core)), us_nn,
                                coords)
    t_nn = SparseTensor(coords=coords,
                        values=vals_nn / max(vals_nn.max(), 1e-12),
                        shape=shape)
    dec, fits = hooi(t_nn, core, n_invocations=3, seed=0, objective="nn")
    mn = min(float(np.asarray(f).min()) for f in dec.factors)
    print(f"   nn fit trajectory: {[round(f, 4) for f in fits]}")
    print(f"   min factor entry: {mn} (exactly nonnegative)")


if __name__ == "__main__":
    main()
