"""Quickstart: sparse Tucker decomposition with the Lite scheme.

Builds a skewed synthetic sparse tensor (the paper's regime: a few huge
slices), runs HOOI to a rank-(8,8,8) Tucker decomposition, and prints the
§4 metrics for Lite vs the prior schemes — reproducing the paper's headline
comparison at laptop scale.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.distribution import build_scheme
from repro.core.hooi import hooi
from repro.core.metrics import scheme_metrics
from repro.data.tensors import synth_tensor


def main() -> None:
    print("== building synthetic tensor (enron-like skew) ==")
    t = synth_tensor((300, 400, 350), 60_000, alphas=(1.3, 1.1, 1.1),
                     hub_fraction=0.15, hub_modes=(0,), seed=0)
    print(f"   {t}")
    sizes = np.sort(t.slice_sizes(0))[::-1]
    print(f"   largest mode-0 slices: {sizes[:5].tolist()} "
          f"(avg {t.nnz // t.shape[0]})")

    print("\n== HOOI (5 invocations, K=8, random bootstrap) ==")
    dec, fits = hooi(t, (8, 8, 8), n_invocations=5, seed=0)
    for i, f in enumerate(fits):
        print(f"   invocation {i}: fit = {f:.4f}")
    print(f"   core shape: {dec.core.shape}")

    print("\n== distribution metrics at P=32 (paper §4, Fig 12) ==")
    P = 32
    hdr = f"{'scheme':12s} {'E_imbalance':>12s} {'R_redundancy':>13s} {'R_imbalance':>12s}"
    print("   " + hdr)
    for name in ("lite", "coarse", "medium", "hypergraph"):
        s = build_scheme(t, name, P)
        sm = scheme_metrics(t, s, (8, 8, 8))
        imb = max(m.ttm_imbalance for m in sm.per_mode)
        red = max(m.svd_redundancy for m in sm.per_mode)
        simb = max(m.svd_imbalance for m in sm.per_mode)
        print(f"   {name:12s} {imb:12.2f} {red:13.2f} {simb:12.2f}")
    print("\n   -> Lite is simultaneously ~1.0 on all three "
          "(Theorem 6.1); CoarseG blows up E, uni-policy schemes blow up R.")


if __name__ == "__main__":
    main()
