"""Batched serving example: continuous batching with slot recycling.

  PYTHONPATH=src python examples/serve_lm.py [--arch granite-3-2b]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()
    res = serve_main([
        "--arch", args.arch, "--smoke", "--requests", "8", "--batch", "4",
        "--gen-len", "12", "--prompt-len", "6", "--s-max", "48",
    ])
    assert res["completed"] == 8
    print(f"[example] served {res['completed']} requests at "
          f"{res['tokens_per_s']:.1f} tok/s (smoke config, CPU)")


if __name__ == "__main__":
    main()
