"""Decomposition-as-a-service: pooled executors, routing, SLOs.

Spins up an ExecutorPool of 2 executors (P=2 each) on disjoint slices of 8
simulated host devices, fronts it with a StreamRouter, and serves a mix of
traffic classes:

  * interactive streams with tight SLO deadlines,
  * batch tensors that the router may refuse under load (PoolSaturated —
    backpressure surfaces to the caller, nothing queues unboundedly),
  * a growing stream that is rerouted between lanes mid-session, carrying
    its partition plan via PartitionPlan.save()/load() so the new lane
    replays it warm (the refresh ladder reports "reuse", not a re-plan).

Ends by printing the PoolStats aggregate: per-lane completions, SLO
hit/miss counts, admission rejections and the routing decisions taken.

  PYTHONPATH=src python examples/serve_pool.py
"""

import os
import sys

sys.path.insert(0, "src")
# must be set before jax initializes; append so a user-provided XLA_FLAGS
# keeps its other options
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import numpy as np

from repro.data.tensors import synth_tensor
from repro.engine import ExecutorPool, PoolSaturated, StreamRouter
from repro.streaming import StreamingTensor

CORE = (6, 6, 6)


def make_stream(seed: int, name: str) -> StreamingTensor:
    t = synth_tensor((120, 100, 90), 8_000, alphas=(1.2, 1.05, 1.05),
                     hub_fraction=0.1, hub_modes=(0,), seed=seed)
    return StreamingTensor.from_tensor(t, name=name)


def main() -> None:
    rng = np.random.default_rng(0)
    with ExecutorPool(2, 2, CORE, workers=2, n_invocations=1,
                      pad_geometric=True) as pool:
        router = StreamRouter(pool, max_pending=8)

        print("== mixed traffic: 4 interactive streams + batch one-shots ==")
        streams = [make_stream(s, f"client-{s}") for s in range(4)]
        for s in streams:
            router.submit(s, priority="interactive", deadline_s=120.0)
        rejected = 0
        for s in range(8):  # batch tries to pile on behind them
            try:
                router.submit(synth_tensor((80, 70, 60), 3_000, seed=50 + s),
                              priority="batch", deadline_s=120.0)
            except PoolSaturated as e:
                rejected += 1
                print(f"  batch submit refused: {e}")
        for r in router.drain():
            print(f"  {r.name:>10s}  lane={r.stats.lane}  "
                  f"decision={r.decision:<6s}  "
                  f"queue_wait={r.queue_wait_s:.2f}s  slo_met={r.slo_met}")

        print("\n== streams are sticky: resubmits replay warm ==")
        for s in streams:
            router.submit(s, priority="interactive", deadline_s=120.0)
        for r in router.drain():
            print(f"  {r.name:>10s}  lane={r.stats.lane}  "
                  f"decision={r.decision:<6s}  "
                  f"new_jit={r.stats.step_compilations}  "
                  f"uploads={r.stats.uploads}")

        print("\n== warm-start reroute: move client-0 to the other lane ==")
        s0 = streams[0]
        new_lane = router.reroute(s0)  # plan carried via save()/load()
        r = router.submit(s0, priority="interactive").result()
        print(f"  client-0 now on lane {new_lane}: decision={r.decision}  "
              f"new_jit={r.stats.step_compilations}  "
              f"uploads={r.stats.uploads}")

        batch = np.stack([rng.integers(0, L, 200)
                          for L in s0.shape], axis=1)
        s0.append(batch, rng.standard_normal(200))  # it keeps growing
        r = router.submit(s0, priority="interactive").result()
        drift = (r.stats.stream_drift or {}).get("worst", float("nan"))
        print(f"  after an appended batch: decision={r.decision}  "
              f"drift_worst={drift:.3f} (ladder continues on the new lane)")

        st = router.stats()
        print("\n== PoolStats ==")
        print(f"  lanes={st.n_lanes}  submitted={st.submitted}  "
              f"completed={st.completed}  failed={st.failed}")
        print(f"  slo: {st.slo_hit} hit / {st.slo_miss} miss   "
              f"rejected={st.rejected} {st.rejected_by_priority}   "
              f"rerouted={st.rerouted}")
        print(f"  decisions={st.decisions}")
        for ls in st.lane_stats:
            print(f"  lane: completed={ls['completed']}  "
                  f"host_s={ls['host_s']:.2f}  device_s={ls['device_s']:.2f}  "
                  f"queue_wait_s={ls['queue_wait_s']:.2f}")
        router.close()


if __name__ == "__main__":
    main()
