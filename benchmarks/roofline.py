"""Roofline report: reads experiments/dryrun artifacts, prints the §Roofline
table (one row per arch x shape x mesh) and emits markdown for
EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
  PYTHONPATH=src python -m benchmarks.roofline --markdown > table.md
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def load_cells(root: str) -> list[dict]:
    cells = []
    for mesh_kind in sorted(os.listdir(root)) if os.path.isdir(root) else []:
        mdir = os.path.join(root, mesh_kind)
        if not os.path.isdir(mdir):
            continue
        for arch in sorted(os.listdir(mdir)):
            adir = os.path.join(mdir, arch)
            if not os.path.isdir(adir):
                continue
            for f in sorted(os.listdir(adir)):
                if not f.endswith(".json"):
                    continue
                with open(os.path.join(adir, f)) as fh:
                    rec = json.load(fh)
                rec.setdefault("arch", arch)
                rec.setdefault("shape", f[:-5])
                rec["mesh_kind"] = mesh_kind
                cells.append(rec)
    return cells


def fmt_row(rec: dict, md: bool = False) -> str:
    if rec.get("skipped"):
        cols = [rec["mesh_kind"], rec["arch"], rec["shape"], "SKIP",
                rec["reason"][:60], "", "", "", "", ""]
    else:
        r = rec["roofline"]
        frac = r["model_flops_per_chip"] / max(
            r["bound_step_time_s"] * 197e12, 1e-30)
        cols = [
            rec["mesh_kind"], rec["arch"], rec["shape"],
            r["dominant"].replace("_s", ""),
            f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
            f"{r['collective_s']:.3e}",
            f"{r['useful_flop_ratio']:.2f}",
            f"{frac:.3f}",
            "Y" if rec.get("fits_hbm_16g") else "N",
        ]
    sep = " | " if md else ","
    row = sep.join(str(c) for c in cols)
    return ("| " + row + " |") if md else row


HEADER = ["mesh", "arch", "shape", "dominant", "compute_s", "memory_s",
          "collective_s", "useful_ratio", "roofline_frac", "fits16G"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    if not cells:
        print("no dry-run artifacts found; run python -m repro.launch.dryrun",
              file=sys.stderr)
        sys.exit(1)
    if args.markdown:
        print("| " + " | ".join(HEADER) + " |")
        print("|" + "---|" * len(HEADER))
    else:
        print(",".join(HEADER))
    for rec in cells:
        print(fmt_row(rec, md=args.markdown))


if __name__ == "__main__":
    main()
