"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the figure's metric)
and, when run through ``main()`` / ``run_benches()``, writes one
``BENCH_<name>.json`` per bench (rows + wall time + error, if any) so CI can
upload the perf trajectory as artifacts. Output dir: ``--out-dir`` or the
``BENCH_OUT_DIR`` env var (default: current directory).

Mapping (see DESIGN.md §7):
  Fig 9   bench_dataset_suite       tensor stats of the synthetic mirror suite
  Fig10/14 bench_hooi_time          HOOI wall-time x scheme (8 simulated ranks)
  Fig 11  bench_time_breakup        TTM vs SVD vs comm time x scheme
  Fig 12  bench_metrics             E^max/R^sum/R^max (imbalance + redundancy)
  Fig 13  bench_comm_volume         SVD vs factor-matrix volumes x scheme
  Fig 15  bench_scaling             critical-path scaling P=4..64
  Fig 16  bench_distribution_time   scheme construction wall-time
  Fig 17  bench_memory              memory model per rank x scheme
  (ours)  bench_kernel_oracle       fused-oracle kernel vs two-pass reference
  (ours)  bench_auto_selection      real-time auto selector choice + overhead
  (ours)  bench_plan_cache          PartitionPlan cache: 2nd dist_hooi call
                                    skips host-side partition construction
  (ours)  bench_executor_reuse      HooiExecutor engine: 2nd run on a cached
                                    plan does zero jit compilations and zero
                                    host->device uploads
  (ours)  bench_scheduler_overlap   StreamScheduler pipeline: host
                                    partitioning overlapped with device
                                    sweeps beats the sequential sum; the
                                    streaming-append rerun stays fully cached
  (ours)  bench_pool_throughput     ExecutorPool serving tier: 2 executors
                                    on disjoint device slices vs a single
                                    executor on a queue of concurrent
                                    streams (streams/sec + SLO accounting)
  (ours)  bench_objectives          objective-pluggable sweeps: masked
                                    completion beats the unmasked baseline
                                    on held-out RMSE under corrupted
                                    entries; a FROSTT .tns fixture streams
                                    through StreamingTensor -> scheduler
  (ours)  bench_sketch_warmstart    sketch warm starts cut counted oracle
                                    Z passes >=1.5x at equal final fit;
                                    adaptive per-mode rank grows AND
                                    shrinks mid-stream with the cost model
                                    re-scored each step
  (ours)  bench_mixed_backends      path="auto" under a per-backend-skewed
                                    CostModel picks a heterogeneous
                                    per-mode comm-backend map

Multi-device benches run in a subprocess with 8 placeholder host devices so
this process keeps the 1-device view (dry-run isolation rule).

Discover bench names with ``--list``; run a subset by naming benches on the
command line (``python benchmarks/run.py plan_cache scheduler_overlap``).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time

import numpy as np

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
sys.path.insert(0, _SRC)

SCHEMES = ("lite", "coarse", "medium", "hypergraph")
DIST_SCHEMES = SCHEMES + ("auto",)  # runtime sweeps
CORE = (10, 10, 10)  # paper default K=10


def _suite(scale=0.25):
    from repro.data.tensors import paper_suite

    return paper_suite(scale=scale)


_ROWS: list = []  # rows of the currently-running bench (JSON artifact)


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": us, "derived": derived})


# ----------------------------------------------------------------- Fig 9
def bench_dataset_suite() -> None:
    t0 = time.perf_counter()
    suite = _suite()
    us = (time.perf_counter() - t0) * 1e6 / max(len(suite), 1)
    for name, t in suite.items():
        _row(f"fig9/{name}", us,
             f"shape={'x'.join(map(str, t.shape))};nnz={t.nnz};"
             f"sparsity={t.sparsity:.2e}")


# ------------------------------------------------------------ Fig 10/14/11
_DIST_BENCH_BODY = """
    import json, time
    import numpy as np
    from repro.data.tensors import paper_suite
    from repro.core.plan import plan
    from repro.distributed.dist_hooi import dist_hooi
    suite = paper_suite(scale=0.12)
    out = {}
    for tname in ["delicious-s", "enron-s", "nell2-s"]:
        t = suite[tname]
        core = (10,) * t.ndim
        out[tname] = {}
        for scheme in %r:
            try:
                t0 = time.perf_counter()
                dec, stats = dist_hooi(t, core, 8, scheme=scheme,
                                       n_invocations=1, path="liteopt",
                                       seed=0)
                dt = time.perf_counter() - t0
                # second run = steady-state (compiled) timing; the plan
                # cache makes its host-side partition time ~0
                t0 = time.perf_counter()
                dec, stats = dist_hooi(t, core, 8, scheme=scheme,
                                       n_invocations=1, path="liteopt",
                                       seed=1)
                warm = time.perf_counter() - t0
                # NOTE: all 8 simulated ranks share ONE physical core, so
                # wall time cannot show load imbalance; the critical-path
                # FLOPs ratio is the hardware-faithful signal (paper Fig 10)
                sm = plan(t, scheme, 8, core_dims=core).metrics
                out[tname][scheme] = {"cold_s": dt, "warm_s": warm,
                                      "fit": stats.fits[-1],
                                      "ran": stats.scheme,
                                      "cache_hit": stats.plan_cache_hit,
                                      "objective": stats.objective,
                                      "backends": "/".join(
                                          stats.comm_backends[n] for n in
                                          sorted(stats.comm_backends)),
                                      "crit_flops": sm.critical_path_flops}
            except Exception as e:
                out[tname][scheme] = {"error": str(e)[:100]}
    print("JSON::" + json.dumps(out))
"""


def _run_subprocess_bench(body: str, devices: int = 8) -> dict:
    import json

    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=3600, env=env)
    if res.returncode != 0:
        raise RuntimeError(f"subprocess bench failed:\n{res.stderr[-2000:]}")
    for line in res.stdout.splitlines():
        if line.startswith("JSON::"):
            return json.loads(line[6:])
    raise RuntimeError(f"no JSON in output:\n{res.stdout[-2000:]}")


def bench_hooi_time() -> None:
    out = _run_subprocess_bench(_DIST_BENCH_BODY % (DIST_SCHEMES,))
    for tname, per in out.items():
        base = per.get("lite", {}).get("warm_s")
        base_cf = per.get("lite", {}).get("crit_flops")
        for scheme, rec in per.items():
            if "error" in rec:
                _row(f"fig10/{tname}/{scheme}", -1.0, f"error={rec['error']}")
                continue
            rel = rec["warm_s"] / base if base else float("nan")
            crel = rec["crit_flops"] / base_cf if base_cf else float("nan")
            _row(f"fig10/{tname}/{scheme}", rec["warm_s"] * 1e6,
                 f"wall_rel_to_lite={rel:.2f};critpath_rel_to_lite={crel:.2f};"
                 f"fit={rec['fit']:.4f};ran={rec['ran']};"
                 f"warm_cache_hit={rec['cache_hit']};"
                 f"objective={rec['objective']};backends={rec['backends']}")


def bench_time_breakup() -> None:
    """Single-rank HOOI instrumented into TTM vs SVD phases (Fig 11's
    computation-dominance claim), plus the analytic comm model."""
    from repro.core.hooi import hooi_invocation, random_factors
    from repro.core.distribution import build_scheme
    from repro.distributed.dist_hooi import comm_model
    from repro.distributed.partition import make_mode_partition
    import jax

    suite = _suite(scale=0.12)
    for tname in ("delicious-s", "nell2-s"):
        t = suite[tname]
        core = (10,) * t.ndim
        factors = random_factors(t.shape, core, jax.random.PRNGKey(0))
        timings: dict = {}
        hooi_invocation(t, factors, jax.random.PRNGKey(1), timings=timings)
        timings2: dict = {}
        hooi_invocation(t, factors, jax.random.PRNGKey(1), timings=timings2)
        total = timings2["ttm"] + timings2["svd"]
        scheme = build_scheme(t, "lite", 8)
        khat = int(np.prod(core[1:]))
        comm = comm_model(make_mode_partition(t, scheme, 0), khat, 2 * core[0])
        _row(f"fig11/{tname}", total * 1e6,
             f"ttm_frac={timings2['ttm']/total:.2f};"
             f"svd_frac={timings2['svd']/total:.2f};"
             f"liteopt_comm_bytes={comm['liteopt_bytes']:.0f}")


# ----------------------------------------------------------------- Fig 12
def bench_metrics() -> None:
    from repro.core.plan import plan

    suite = _suite()
    P = 64
    for tname, t in suite.items():
        core = (10,) * t.ndim
        for scheme_name in SCHEMES:
            if scheme_name == "hypergraph" and t.nnz > 60_000:
                _row(f"fig12/{tname}/{scheme_name}", -1.0,
                     "skipped=too_large_for_hyperg (paper: same for Zoltan)")
                continue
            t0 = time.perf_counter()
            sm = plan(t, scheme_name, P, core_dims=core).metrics
            us = (time.perf_counter() - t0) * 1e6
            imb = max(m.ttm_imbalance for m in sm.per_mode)
            red = max(m.svd_redundancy for m in sm.per_mode)
            svd_imb = max(m.svd_imbalance for m in sm.per_mode)
            _row(f"fig12/{tname}/{scheme_name}", us,
                 f"ttm_imbalance={imb:.2f};svd_redundancy={red:.2f};"
                 f"svd_imbalance={svd_imb:.2f}")


# ----------------------------------------------------------------- Fig 13
def bench_comm_volume() -> None:
    from repro.core.plan import plan

    suite = _suite()
    P = 64
    for tname in ("delicious-s", "enron-s", "flickr-s"):
        t = suite[tname]
        core = (10,) * t.ndim
        for scheme_name in SCHEMES:
            if scheme_name == "hypergraph" and t.nnz > 60_000:
                continue
            t0 = time.perf_counter()
            sm = plan(t, scheme_name, P, core_dims=core).metrics
            us = (time.perf_counter() - t0) * 1e6
            _row(f"fig13/{tname}/{scheme_name}", us,
                 f"svd_vol={sm.svd_volume};fm_vol={sm.fm_volume};"
                 f"total={sm.svd_volume + sm.fm_volume}")


# ----------------------------------------------------------------- Fig 15
def bench_scaling() -> None:
    """Critical-path FLOPs scaling P=4..64 (model-based strong scaling; the
    paper's Fig 15 wall-time speedups follow the same curve since HOOI is
    computation-dominated)."""
    from repro.core.plan import plan

    suite = _suite()
    for tname in ("delicious-s", "enron-s", "amazon-s"):
        t = suite[tname]
        core = (10,) * t.ndim
        for scheme_name in ("lite", "coarse", "medium"):
            flops = {}
            t0 = time.perf_counter()
            for P in (4, 8, 16, 32, 64):
                sm = plan(t, scheme_name, P, core_dims=core).metrics
                flops[P] = sm.critical_path_flops
            us = (time.perf_counter() - t0) * 1e6 / 5
            speedup = flops[4] / flops[64]
            _row(f"fig15/{tname}/{scheme_name}", us,
                 f"speedup_4_to_64={speedup:.1f};ideal=16.0")


# ----------------------------------------------------------------- Fig 16
def bench_distribution_time() -> None:
    """Scheme (policy) construction wall time, as the paper's Fig 16 charges
    it — partition/metric building is excluded so the cross-scheme ratios
    stay comparable to the paper. "auto" pays for all three candidates plus
    the cost-model scoring (uncached on purpose)."""
    from repro.core.distribution import build_scheme

    suite = _suite()
    P = 64
    for tname, t in suite.items():
        for scheme_name in SCHEMES + ("auto",):
            if scheme_name == "hypergraph" and t.nnz > 60_000:
                _row(f"fig16/{tname}/{scheme_name}", -1.0, "skipped=big")
                continue
            kw = {"use_cache": False} if scheme_name == "auto" else {}
            t0 = time.perf_counter()
            s = build_scheme(t, scheme_name, P, **kw)
            us = (time.perf_counter() - t0) * 1e6
            _row(f"fig16/{tname}/{scheme_name}", us,
                 f"nnz={t.nnz};ran={s.name}")


# ----------------------------------------------------------------- Fig 17
def bench_memory() -> None:
    from repro.core.plan import plan

    suite = _suite()
    P = 64
    for tname in ("delicious-s", "nell2-s", "amazon-s"):
        t = suite[tname]
        core = (10,) * t.ndim
        for scheme_name in ("lite", "coarse", "medium"):
            t0 = time.perf_counter()
            sm = plan(t, scheme_name, P, core_dims=core).metrics
            mem = sm.memory_bytes_per_rank()
            us = (time.perf_counter() - t0) * 1e6
            _row(f"fig17/{tname}/{scheme_name}", us,
                 f"tensor_MB={mem['tensor']/1e6:.2f};"
                 f"penult_MB={mem['penultimate']/1e6:.2f};"
                 f"total_MB={mem['total']/1e6:.2f}")


# ---------------------------------------------------------------- kernels
def bench_kernel_oracle() -> None:
    """Fused oracle pair vs two-pass reference: HBM bytes per Lanczos query
    (the kernel's raison d'être — reported analytically; wall time is the
    jnp reference since interpret-mode timing is meaningless)."""
    import jax.numpy as jnp
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    for R, K in ((4096, 100), (16384, 100), (4096, 1000)):
        Z = jnp.asarray(rng.standard_normal((R, K)), jnp.float32)
        x = jnp.asarray(rng.standard_normal(K), jnp.float32)
        y = jnp.asarray(rng.standard_normal(R), jnp.float32)
        ref.oracle_pair_ref(Z, x, y)  # warm
        t0 = time.perf_counter()
        n = 20
        for _ in range(n):
            a, b = ref.oracle_pair_ref(Z, x, y)
        a.block_until_ready()
        us = (time.perf_counter() - t0) * 1e6 / n
        two_pass = 2 * R * K * 4
        fused = R * K * 4
        _row(f"kernel_oracle/R{R}_K{K}", us,
             f"hbm_two_pass_B={two_pass};hbm_fused_B={fused};saving=2.0x")


def bench_kernel_ttm() -> None:
    """TTM hot loop: Pallas kron_segsum vs the jnp segment_sum reference.

    Reference wall time is the meaningful number off-TPU (the kernel runs in
    interpret mode here, orders of magnitude slower than compiled); what the
    kernel buys is reported analytically — MXU MACs of the one-hot-matmul
    reformulation vs the scatter-add's MACs (~1.5x minimal work, but on the
    systolic array instead of serialized scatters) — plus the max abs
    difference as a correctness check.
    """
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.kron_segsum import ROW_BLOCK, kron_segsum, \
        tile_geometry

    rng = np.random.default_rng(1)
    block_e = 256
    for E, Ka, Kb, R in ((4096, 10, 10, 512), (16384, 10, 10, 2048),
                         (8192, 4, 100, 1024)):
        rows = np.sort(rng.integers(0, R, E)).astype(np.int32)
        a = rng.standard_normal((E, Ka)).astype(np.float32)
        b = rng.standard_normal((E, Kb)).astype(np.float32)
        jrows, ja, jb = jnp.asarray(rows), jnp.asarray(a), jnp.asarray(b)

        want = ref.kron_segsum_ref(jrows, ja, jb, R)  # warm
        t0 = time.perf_counter()
        n = 10
        for _ in range(n):
            want = ref.kron_segsum_ref(jrows, ja, jb, R)
        want.block_until_ready()
        ref_us = (time.perf_counter() - t0) * 1e6 / n

        t0 = time.perf_counter()
        got = kron_segsum(jrows, ja, jb, R, interpret=True)
        got.block_until_ready()
        interp_us = (time.perf_counter() - t0) * 1e6
        max_diff = float(np.abs(np.asarray(got) - np.asarray(want)).max())

        g = tile_geometry(R, Ka, Kb, block_e)
        n_eb = -(-E // block_e)
        n_kb = g.Kb_pad // g.kb_blk
        mxu_macs = n_kb * n_eb * g.span * ROW_BLOCK * block_e * Ka * g.kb_blk
        min_macs = E * Ka * Kb
        # systolic overhead decomposes into the span factor (row windows per
        # element block) and lane padding (Kb -> kb_blk multiples of 128)
        span_x = g.span * ROW_BLOCK / block_e
        lane_x = n_kb * g.kb_blk / Kb
        _row(f"kernel_ttm/E{E}_Ka{Ka}_Kb{Kb}_R{R}", ref_us,
             f"ref_us={ref_us:.1f};kernel_interpret_us={interp_us:.1f};"
             f"max_abs_diff={max_diff:.2e};"
             f"mxu_macs_over_minimal={mxu_macs / min_macs:.2f};"
             f"span_overhead={span_x:.2f}x;lane_pad={lane_x:.2f}x;"
             f"vmem_bytes={g.vmem_bytes}")


def bench_kernel_roofline() -> None:
    """Roofline: counted HBM passes over Z per sweep·mode — PR-6 reference
    path vs the fused Z-build→oracle pipeline vs fused + block Lanczos —
    with end-to-end fit parity between the variants (the passes drop is
    structural, not a quality trade). Acceptance: fused+block cuts the
    counted passes ≥2x vs the reference path."""
    from repro.core.hooi import hooi
    from repro.core.lanczos import effective_block_size, lanczos_niter
    from repro.data.tensors import synth_tensor
    from repro.engine import count_z_passes

    t = synth_tensor((120, 100, 90), 20_000, alphas=(1.1, 1.0, 1.0),
                     hub_fraction=0.1, hub_modes=(0,), seed=5)
    core = CORE  # paper default K=10
    variants = (
        ("reference", dict()),
        ("fused", dict(fused_zbuild=True)),
        ("fused_block8", dict(fused_zbuild=True, lanczos_block=8)),
    )
    passes = {}
    fits = {}
    for name, kw in variants:
        blk = int(kw.get("lanczos_block", 1))
        fz = bool(kw.get("fused_zbuild", False))
        per_mode = []
        for n in range(t.ndim):
            khat = int(np.prod([core[j] for j in range(t.ndim) if j != n]))
            s_eff = effective_block_size(core[n], t.shape[n], khat, blk)
            niter = lanczos_niter(core[n], t.shape[n], khat,
                                  s_eff if (fz or s_eff > 1) else 1)
            per_mode.append(count_z_passes(niter, fz))
        passes[name] = per_mode
        t0 = time.perf_counter()
        _, fit_traj = hooi(t, core, n_invocations=2, seed=0, **kw)
        us = (time.perf_counter() - t0) * 1e6
        fits[name] = fit_traj[-1]
        _row(f"kernel_roofline/{name}", us,
             f"z_passes_per_mode={'/'.join(map(str, per_mode))};"
             f"z_passes_sweep_total={sum(per_mode)};"
             f"final_fit={fit_traj[-1]:.4f}")
    ratio = sum(passes["reference"]) / max(sum(passes["fused_block8"]), 1)
    parity = max(abs(fits[n] - fits["reference"]) for n in fits)
    _row("kernel_roofline/acceptance", -1.0,
         f"passes_drop={ratio:.2f}x;ok={ratio >= 2.0};"
         f"max_fit_delta_vs_reference={parity:.4f};"
         f"parity_ok={parity < 5e-3}")


# ------------------------------------------------------- auto + plan cache
def bench_auto_selection() -> None:
    """Real-time selector: which candidate wins per tensor, and what the
    selection costs relative to building the winner alone."""
    from repro.core.plan import plan

    suite = _suite()
    P = 16
    for tname, t in suite.items():
        core = (10,) * t.ndim
        t0 = time.perf_counter()
        pl = plan(t, "auto", P, core_dims=core, use_cache=False)
        us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        plan(t, pl.name, P, core_dims=core, use_cache=False)
        winner_us = (time.perf_counter() - t0) * 1e6
        cands = ";".join(f"{c}={v:.2e}" for c, v in
                         sorted(pl.candidates.items(), key=lambda kv: kv[1]))
        _row(f"auto/{tname}", us,
             f"picked={pl.name};overhead_vs_winner={us/max(winner_us,1):.2f}x;"
             + cands)


_PLAN_CACHE_BODY = """
    import json, time
    from repro.data.tensors import paper_suite
    from repro.distributed.dist_hooi import dist_hooi
    t = paper_suite(scale=0.12)["delicious-s"]
    core = (10,) * t.ndim
    out = {}
    for run in ("first", "second"):
        t0 = time.perf_counter()
        dec, stats = dist_hooi(t, core, 8, scheme="auto", n_invocations=1,
                               seed=0 if run == "first" else 1)
        out[run] = {"total_s": time.perf_counter() - t0,
                    "partition_build_s": stats.partition_build_s,
                    "cache_hit": stats.plan_cache_hit,
                    "scheme": stats.scheme,
                    "objective": stats.objective,
                    "backends": "/".join(stats.comm_backends[n] for n in
                                         sorted(stats.comm_backends))}
    print("JSON::" + json.dumps(out))
"""


def bench_plan_cache() -> None:
    """Acceptance: the second dist_hooi call on the same tensor must skip
    partition construction (host-side partition time ~ 0)."""
    out = _run_subprocess_bench(_PLAN_CACHE_BODY)
    first, second = out["first"], out["second"]
    for run, rec in (("first", first), ("second", second)):
        _row(f"plan_cache/{run}", rec["partition_build_s"] * 1e6,
             f"cache_hit={rec['cache_hit']};scheme={rec['scheme']};"
             f"total_s={rec['total_s']:.2f};objective={rec['objective']};"
             f"backends={rec['backends']}")
    speedup = first["partition_build_s"] / max(second["partition_build_s"],
                                               1e-9)
    _row("plan_cache/partition_speedup", second["partition_build_s"] * 1e6,
         f"first_vs_second={speedup:.0f}x;second_hit={second['cache_hit']}")


_SCHED_OVERLAP_BODY = """
    import json, time
    import numpy as np
    from repro.core.plan import plan_cache_clear
    from repro.data.tensors import synth_tensor
    from repro.distributed.executor import HooiExecutor
    from repro.engine.scheduler import StreamScheduler
    from repro.streaming import StreamingTensor

    core = (8, 8, 8)
    tensors = [synth_tensor((260, 220, 200), 60_000,
                            alphas=(1.2, 1.05, 1.05), hub_fraction=0.1,
                            hub_modes=(0,), seed=s) for s in range(4)]
    out = {}

    # one-time warmup so neither phase is charged XLA platform startup
    warm = synth_tensor((24, 20, 18), 500, seed=99)
    HooiExecutor(8).run(warm, (2, 2, 2), "lite", n_invocations=1)

    # --- sequential reference: plan -> stage -> sweep, one tensor at a time
    plan_cache_clear()
    ex_seq = HooiExecutor(8)
    t0 = time.perf_counter()
    host_s = dev_s = 0.0
    for i, t in enumerate(tensors):
        h0 = time.perf_counter()
        pl, _ = ex_seq.prepare(t, core, "auto", pad_geometric=True)
        h1 = time.perf_counter()
        ex_seq.run(t, core, pl, n_invocations=1, seed=i)
        dev_s += time.perf_counter() - h1
        host_s += h1 - h0
    seq_wall = time.perf_counter() - t0
    out["sequential"] = {"wall_s": seq_wall, "host_s": host_s,
                         "device_s": dev_s}

    # --- pipelined: same tensors, fresh caches + executor, scheduler overlap
    plan_cache_clear()
    ex_pipe = HooiExecutor(8)
    sched = StreamScheduler(ex_pipe, core, scheme="auto", n_invocations=1,
                            workers=2)
    t0 = time.perf_counter()
    futs = [sched.submit(t, name="t%d" % i, seed=i)
            for i, t in enumerate(tensors)]
    res = sched.drain()
    pipe_wall = time.perf_counter() - t0
    st = sched.stats()
    sched.close()
    out["pipelined"] = {"wall_s": pipe_wall, "host_s": st["host_s"],
                        "device_s": st["device_s"],
                        "overlap_s": st["overlap_s"],
                        "decisions": st["decisions"]}
    out["overlap_ok"] = pipe_wall < seq_wall

    # --- streaming ladder on the warm executor: append -> rerun contract
    stream = StreamingTensor.from_tensor(tensors[0], name="stream")
    sched = StreamScheduler(ex_pipe, core, scheme="auto", n_invocations=1,
                            workers=2)
    rng = np.random.default_rng(0)
    r1 = sched.submit(stream, seed=0).result()
    idx = rng.integers(0, tensors[0].nnz, 500)  # value updates: same coords
    stream.append(tensors[0].coords[idx], rng.standard_normal(500))
    r2 = sched.submit(stream, seed=1).result()
    r3 = sched.submit(stream, seed=2).result()  # rerun, unchanged stream
    sched.close()
    for name, r in (("stream_first", r1), ("stream_append", r2),
                    ("stream_rerun", r3)):
        out[name] = {"decision": r.decision,
                     "compilations": r.stats.step_compilations,
                     "uploads": r.stats.uploads,
                     "fit": r.fits[-1],
                     "objective": r.stats.objective,
                     "backends": "/".join(r.stats.comm_backends[n] for n in
                                          sorted(r.stats.comm_backends)),
                     # did THIS submit run the auto selector? (a reused
                     # auto plan still carries its adoption candidates)
                     "reselected": r.decision in ("plan", "reselect")}
    out["rerun_ok"] = (r3.decision == "reuse"
                       and r3.stats.step_compilations == 0
                       and r3.stats.uploads == 0)
    print("JSON::" + json.dumps(out))
"""


def bench_scheduler_overlap() -> None:
    """Acceptance: the scheduler pipeline (host partitioning overlapped
    with device sweeps) beats the sequential plan+sweep sum on a queue of
    tensors, and the streaming-append rerun on an unchanged distribution
    reports 0 new compilations and 0 new uploads."""
    out = _run_subprocess_bench(_SCHED_OVERLAP_BODY)
    seq, pipe = out["sequential"], out["pipelined"]
    _row("scheduler_overlap/sequential", seq["wall_s"] * 1e6,
         f"host_s={seq['host_s']:.2f};device_s={seq['device_s']:.2f}")
    _row("scheduler_overlap/pipelined", pipe["wall_s"] * 1e6,
         f"host_s={pipe['host_s']:.2f};device_s={pipe['device_s']:.2f};"
         f"overlap_hidden_s={pipe['overlap_s']:.2f};"
         f"decisions={pipe['decisions']}")
    _row("scheduler_overlap/speedup", pipe["wall_s"] * 1e6,
         f"ok={out['overlap_ok']};"
         f"sequential_vs_pipelined="
         f"{seq['wall_s'] / max(pipe['wall_s'], 1e-9):.2f}x")
    for name in ("stream_first", "stream_append", "stream_rerun"):
        rec = out[name]
        _row(f"scheduler_overlap/{name}", -1.0,
             f"decision={rec['decision']};"
             f"compilations={rec['compilations']};"
             f"uploads={rec['uploads']};reselected={rec['reselected']};"
             f"objective={rec['objective']};backends={rec['backends']};"
             f"fit={rec['fit']:.4f}")
    _row("scheduler_overlap/rerun_fully_cached", -1.0,
         f"ok={out['rerun_ok']}")


_EXEC_REUSE_BODY = """
    import json, time
    from repro.core.calibrate import fit_cost_model
    from repro.core.plan import plan
    from repro.data.tensors import paper_suite
    from repro.distributed.executor import HooiExecutor
    t = paper_suite(scale=0.12)["delicious-s"]
    core = (10,) * t.ndim
    ex = HooiExecutor(8)
    pl = plan(t, "auto", 8, core_dims=core)
    out = {}
    for run in ("first", "second"):
        t0 = time.perf_counter()
        dec, st = ex.run(t, core, pl, n_invocations=1,
                         seed=0 if run == "first" else 1)
        out[run] = {"total_s": time.perf_counter() - t0,
                    "step_compilations": st.step_compilations,
                    "step_cache_hits": st.step_cache_hits,
                    "uploads": st.uploads,
                    "upload_cache_hit": st.upload_cache_hit,
                    "objective": st.objective,
                    "backends": "/".join(st.comm_backends[n] for n in
                                         sorted(st.comm_backends)),
                    "fit": st.fits[-1]}
    cm = fit_cost_model(ex.calibration_samples())
    out["calibration"] = {"flop_rate": cm.flop_rate,
                          "net_bandwidth": cm.net_bandwidth,
                          "source": cm.source}
    out["executor"] = ex.stats()
    print("JSON::" + json.dumps(out))
"""


def bench_executor_reuse() -> None:
    """Acceptance: the second HooiExecutor.run() on a cached plan performs
    no new jit compilations and no new host->device uploads; the measured
    sweeps also yield a fitted CostModel for the selector."""
    out = _run_subprocess_bench(_EXEC_REUSE_BODY)
    for run in ("first", "second"):
        rec = out[run]
        _row(f"executor_reuse/{run}", rec["total_s"] * 1e6,
             f"compilations={rec['step_compilations']};"
             f"uploads={rec['uploads']};"
             f"upload_cache_hit={rec['upload_cache_hit']};"
             f"objective={rec['objective']};backends={rec['backends']};"
             f"fit={rec['fit']:.4f}")
    second = out["second"]
    ok = second["step_compilations"] == 0 and second["uploads"] == 0
    speedup = out["first"]["total_s"] / max(second["total_s"], 1e-9)
    _row("executor_reuse/second_fully_cached", second["total_s"] * 1e6,
         f"ok={ok};first_vs_second={speedup:.1f}x;"
         f"calibrated_flop_rate={out['calibration']['flop_rate']:.2e};"
         f"source={out['calibration']['source']}")


_POOL_THROUGHPUT_BODY = """
    import json, time
    import numpy as np
    from repro.core.plan import plan_cache_clear
    from repro.data.tensors import synth_tensor
    from repro.distributed.executor import HooiExecutor
    from repro.engine import ExecutorPool, StreamRouter
    from repro.engine.scheduler import StreamScheduler
    from repro.streaming import StreamingTensor

    core = (8, 8, 8)
    n_streams = 8
    tensors = [synth_tensor((220, 200, 180), 40_000,
                            alphas=(1.2, 1.05, 1.05), hub_fraction=0.1,
                            hub_modes=(0,), seed=s) for s in range(n_streams)]
    out = {"n_streams": n_streams}

    # one-time warmup: platform startup charged to neither contender
    warm = synth_tensor((24, 20, 18), 500, seed=99)
    HooiExecutor(2).run(warm, (2, 2, 2), "lite", n_invocations=1)

    import jax
    devs = jax.devices()

    # --- single executor (P=2), one scheduler pipeline
    plan_cache_clear()
    ex = HooiExecutor(2)
    t0 = time.perf_counter()
    with StreamScheduler(ex, core, n_invocations=1, workers=2,
                         pad_geometric=True) as sched:
        for i, t in enumerate(tensors):
            sched.submit(t, seed=i, deadline_s=600.0)
        res_single = sched.drain()
    single_wall = time.perf_counter() - t0
    out["single"] = {
        "wall_s": single_wall,
        "streams_per_s": n_streams / single_wall,
        "slo_hit": sum(1 for r in res_single if r.slo_met),
        "objective": sorted({r.stats.objective for r in res_single}),
        "backends": sorted({b for r in res_single
                            for b in r.stats.comm_backends.values()}),
    }

    # --- pool of 2 executors (P=2 each) on disjoint device slices
    plan_cache_clear()
    t0 = time.perf_counter()
    with ExecutorPool(2, 2, core, devices=devs[:4], workers=2,
                      n_invocations=1, pad_geometric=True) as pool:
        router = StreamRouter(pool, max_pending=2 * n_streams)
        for i, t in enumerate(tensors):
            router.submit(t, seed=i, deadline_s=600.0)
        res_pool = router.drain()
        pool_wall = time.perf_counter() - t0
        st = router.stats()
        out["pool"] = {
            "wall_s": pool_wall,
            "streams_per_s": n_streams / pool_wall,
            "slo_hit": st.slo_hit,
            "slo_miss": st.slo_miss,
            "lanes_used": sorted({r.stats.lane for r in res_pool}),
            "queue_wait_s": st.queue_wait_s,
            "rejected": st.rejected,
            "objective": sorted({r.stats.objective for r in res_pool}),
            "backends": sorted({b for r in res_pool
                                for b in r.stats.comm_backends.values()}),
        }
    out["speedup"] = single_wall / max(pool_wall, 1e-9)
    print("JSON::" + json.dumps(out))
"""


def bench_pool_throughput() -> None:
    """Acceptance: a 2-executor pool on disjoint device slices serves a
    queue of concurrent streams at higher throughput (streams/sec) than a
    single executor pipeline, with every stream's SLO accounted."""
    out = _run_subprocess_bench(_POOL_THROUGHPUT_BODY)
    single, pool = out["single"], out["pool"]
    n = out["n_streams"]
    _row("pool_throughput/single_executor", single["wall_s"] * 1e6,
         f"streams_per_s={single['streams_per_s']:.3f};"
         f"slo_hit={single['slo_hit']}/{n};"
         f"objective={','.join(single['objective'])};"
         f"backends={','.join(single['backends'])}")
    _row("pool_throughput/pool_of_2", pool["wall_s"] * 1e6,
         f"streams_per_s={pool['streams_per_s']:.3f};"
         f"slo_hit={pool['slo_hit']}/{n};"
         f"lanes_used={pool['lanes_used']};"
         f"queue_wait_s={pool['queue_wait_s']:.2f};"
         f"rejected={pool['rejected']};"
         f"objective={','.join(pool['objective'])};"
         f"backends={','.join(pool['backends'])}")
    _row("pool_throughput/speedup", pool["wall_s"] * 1e6,
         f"single_vs_pool={out['speedup']:.2f}x;"
         f"ok={out['speedup'] > 1.0}")


_OBJECTIVES_BODY = """
    import json, os, tempfile, time
    import numpy as np
    from repro.core.coo import SparseTensor, write_tns
    from repro.data.frostt import iter_tns_batches, load_tns
    from repro.distributed.dist_hooi import dist_hooi
    from repro.distributed.executor import HooiExecutor
    from repro.engine.objective import holdout_mask, predict_at_coords
    from repro.engine.scheduler import StreamScheduler
    from repro.streaming import StreamingTensor

    out = {}
    rng = np.random.default_rng(0)

    # ground truth: an exact rank-(4,4,4) model sampled at random coords;
    # the held-out fraction of stored entries is then CORRUPTED with large
    # garbage values (untrusted measurements). Zero-corruption would be a
    # wash by construction — under the implicit-zero Frobenius objective,
    # masking an entry and storing it as zero are the same statement (see
    # docs/objectives.md) — so the corruption must be nonzero for the split
    # to matter. The unmasked baseline trains on everything and chases the
    # garbage; completion drops exactly those entries. Both are scored at
    # the held-out coords against the TRUE values.
    # a small shape sampled densely (~70% of cells observed) keeps the
    # sparse tensor close to its dense low-rank generator, so the sweeps
    # can actually recover the model and the held-out scores separate
    shape, core = (24, 20, 18), (4, 4, 4)
    g = rng.standard_normal(core)
    us = [np.linalg.qr(rng.standard_normal((L, r)))[0]
          for L, r in zip(shape, core)]
    nnz = 6000
    coords = np.unique(np.stack([rng.integers(0, L, 2 * nnz) for L in shape],
                                axis=1), axis=0)[:nnz]
    true_vals = predict_at_coords(g, us, coords)
    true_vals = true_vals / max(np.abs(true_vals).max(), 1e-12)

    frac, hseed = 0.2, 0  # CompletionObjective defaults
    held = holdout_mask(len(coords), frac, hseed)
    vals = true_vals.copy()
    vals[held] = rng.standard_normal(int(held.sum())) \
        * 5.0 * float(true_vals.std())
    t = SparseTensor(coords=coords, values=vals, shape=shape)

    recs = {}
    for name, obj in (("tucker_baseline", "tucker"),
                      ("completion", "completion")):
        t0 = time.perf_counter()
        dec, stats = dist_hooi(t, core, 8, scheme="medium", n_invocations=2,
                               seed=0, objective=obj)
        dt = time.perf_counter() - t0
        pred = predict_at_coords(dec.core, dec.factors, coords[held])
        rmse = float(np.sqrt(np.mean((pred - true_vals[held]) ** 2)))
        om = stats.objective_metrics or {}
        recs[name] = {"took_s": dt, "fit": stats.fits[-1],
                      "objective": stats.objective,
                      "backends": "/".join(stats.comm_backends[n] for n in
                                           sorted(stats.comm_backends)),
                      "heldout_rmse_vs_truth": rmse,
                      "masked_holdout_rmse_traj": om.get("holdout_rmse")}
    out["recovery"] = recs
    out["completion_beats_baseline"] = (
        recs["completion"]["heldout_rmse_vs_truth"]
        < recs["tucker_baseline"]["heldout_rmse_vs_truth"])

    # nonnegative ADMM Tucker on the same coords, from a nonneg generator
    # with block-supported (near-orthogonal) factor columns — the parts-
    # based structure NN Tucker is meant to recover
    us_nn = []
    for L in shape:
        f = np.zeros((L, 4))
        for j in range(4):
            lo, hi = j * L // 4, (j + 1) * L // 4
            f[lo:hi, j] = np.abs(rng.standard_normal(hi - lo)) + 0.1
        us_nn.append(f)
    g_nn = np.abs(rng.standard_normal(core))
    vals_nn = predict_at_coords(g_nn, us_nn, coords)
    vals_nn = vals_nn / max(vals_nn.max(), 1e-12)
    t_nn = SparseTensor(coords=coords, values=vals_nn, shape=shape)
    dec, stats = dist_hooi(t_nn, core, 8, scheme="medium", n_invocations=2,
                           seed=0, objective="nn")
    out["nn"] = {"fit": stats.fits[-1], "objective": stats.objective,
                 "backends": "/".join(stats.comm_backends[n] for n in
                                      sorted(stats.comm_backends)),
                 "min_factor": float(min(np.asarray(f).min()
                                         for f in dec.factors))}

    # FROSTT-format fixture -> StreamingTensor -> StreamScheduler, masked
    # completion over the growing stream (the scheduler's refresh ladder
    # runs on the objective's view)
    tmp = tempfile.mkdtemp()
    path = os.path.join(tmp, "fixture.tns")
    write_tns(path, t)
    full = load_tns(path)
    batches = list(iter_tns_batches(path, batch_nnz=2000))
    stream = StreamingTensor(full.shape, name="frostt-fixture")
    ex = HooiExecutor(8)
    with StreamScheduler(ex, core, scheme="auto", n_invocations=1,
                         objective="completion", workers=2) as sched:
        stream.append(*batches[0])
        r1 = sched.submit(stream, seed=0).result()
        for c, v in batches[1:]:
            stream.append(c, v)
        r2 = sched.submit(stream, seed=1).result()
    om = r2.stats.objective_metrics or {}
    out["frostt_stream"] = {
        "batches": len(batches), "nnz": int(full.nnz),
        "first_decision": r1.decision, "first_fit": r1.fits[-1],
        "final_decision": r2.decision, "final_fit": r2.fits[-1],
        "objective": r2.stats.objective,
        "backends": "/".join(r2.stats.comm_backends[n] for n in
                             sorted(r2.stats.comm_backends)),
        "holdout_rmse": (om.get("holdout_rmse") or [None])[-1],
    }
    print("JSON::" + json.dumps(out))
"""


def bench_objectives() -> None:
    """Acceptance: masked completion beats the unmasked Tucker baseline on
    held-out RMSE when a fraction of stored entries is corrupted; NN-ADMM
    emits exactly nonnegative factors; and a FROSTT-format .tns fixture
    streams end-to-end through StreamingTensor -> StreamScheduler under
    the completion objective."""
    out = _run_subprocess_bench(_OBJECTIVES_BODY)
    for name, rec in out["recovery"].items():
        traj = rec["masked_holdout_rmse_traj"]
        traj_s = ("none" if not traj
                  else "/".join(f"{x:.3f}" for x in traj))
        _row(f"objectives/{name}", rec["took_s"] * 1e6,
             f"heldout_rmse_vs_truth={rec['heldout_rmse_vs_truth']:.4f};"
             f"fit={rec['fit']:.4f};objective={rec['objective']};"
             f"backends={rec['backends']};masked_rmse_traj={traj_s}")
    base = out["recovery"]["tucker_baseline"]["heldout_rmse_vs_truth"]
    comp = out["recovery"]["completion"]["heldout_rmse_vs_truth"]
    _row("objectives/recovery_acceptance", -1.0,
         f"ok={out['completion_beats_baseline']};"
         f"baseline_over_completion_rmse={base / max(comp, 1e-12):.2f}x")
    nn = out["nn"]
    _row("objectives/nn_admm", -1.0,
         f"fit={nn['fit']:.4f};min_factor={nn['min_factor']:.3e};"
         f"nonneg_ok={nn['min_factor'] >= 0.0};objective={nn['objective']};"
         f"backends={nn['backends']}")
    fs = out["frostt_stream"]
    rmse_s = ("none" if fs["holdout_rmse"] is None
              else f"{fs['holdout_rmse']:.4f}")
    _row("objectives/frostt_stream", -1.0,
         f"batches={fs['batches']};nnz={fs['nnz']};"
         f"first_decision={fs['first_decision']};"
         f"final_decision={fs['final_decision']};"
         f"final_fit={fs['final_fit']:.4f};holdout_rmse={rmse_s};"
         f"objective={fs['objective']};backends={fs['backends']}")


_SKETCH_WARMSTART_BODY = """
    import json, time
    import numpy as np
    from repro.core.hooi import hooi
    from repro.core.lanczos import lanczos_niter
    from repro.core.sketch import (DEFAULT_POWER_ITERS, sketch_block_size,
                                   sketch_niter)
    from repro.data.tensors import synth_tensor
    from repro.distributed.executor import HooiExecutor
    from repro.engine import count_z_passes
    from repro.engine.scheduler import StreamScheduler
    from repro.streaming import StreamingTensor

    out = {}

    # --- Part A: counted oracle Z passes, full-GK vs sketch warm start.
    # Paper-default K=10 is where the halved refinement budget pays: the
    # full driver runs ceil(2K/s) block iterations, the sketched one
    # ceil(K/s) plus one seed product and one power iteration.
    t = synth_tensor((120, 100, 90), 20_000, alphas=(1.1, 1.0, 1.0),
                     hub_fraction=0.1, hub_modes=(0,), seed=5)
    core = (10, 10, 10)
    oracle = {}
    for name, ws in (("full_gk", "none"), ("sketch", "sketch")):
        per_mode = []
        for n in range(t.ndim):
            khat = int(np.prod([core[j] for j in range(t.ndim) if j != n]))
            if ws == "sketch":
                s_sk = sketch_block_size(core[n], t.shape[n], khat, 1)
                niter = sketch_niter(core[n], t.shape[n], khat, s_sk)
                per_mode.append(count_z_passes(
                    niter, False, warm_start="sketch",
                    power_iters=DEFAULT_POWER_ITERS))
            else:
                niter = lanczos_niter(core[n], t.shape[n], khat, 1)
                per_mode.append(count_z_passes(niter, False))
        t0 = time.perf_counter()
        _, traj = hooi(t, core, n_invocations=6, seed=0, warm_start=ws)
        oracle[name] = {"wall_s": time.perf_counter() - t0,
                       "z_passes_per_mode": per_mode,
                       "z_passes_total": sum(per_mode),
                       "final_fit": traj[-1]}
    out["oracle"] = oracle
    # warm_start="none" must reproduce the historical trajectory bitwise
    _, t_def = hooi(t, core, n_invocations=2, seed=0)
    _, t_none = hooi(t, core, n_invocations=2, seed=0, warm_start="none")
    out["none_bitwise"] = bool(t_def == t_none)

    # --- Part B: adaptive per-mode rank over a drifting stream. Phase 1
    # appends samples of a coherent rank-8 model (tail energy pushes ranks
    # up); phase 2 appends a much stronger rank-2 model (spectra collapse,
    # ranks come back down). Dense-ish non-replacement sampling keeps the
    # sparse view close to its low-rank generator so the sketch spectra
    # are informative.
    rng = np.random.default_rng(7)
    shape = (32, 28, 24)
    NN = shape[0] * shape[1] * shape[2]

    def model(R, scale):
        fac = [np.linalg.qr(rng.normal(size=(s, R)))[0] for s in shape]
        g = rng.normal(size=(R,) * 3) * scale
        return np.einsum("abc,ia,jb,kc->ijk", g, *fac)

    def sample(dense, n):
        flat = rng.choice(NN, n, replace=False)
        coords = np.stack(np.unravel_index(flat, shape), 1)
        return coords, dense[tuple(coords.T)]

    d8 = model(8, 1.0)
    d2 = model(2, 300.0)
    ex = HooiExecutor(4)
    stream = StreamingTensor(shape, name="adaptive-rank")
    steps = []
    with StreamScheduler(ex, (4, 4, 4), n_invocations=3,
                         warm_start="sketch", adaptive_rank=True,
                         rank_policy=dict(k_max=8, k_min=2, grow_thresh=0.45,
                                          shrink_thresh=0.3)) as sched:
        for phase, (dense, n, reps) in enumerate(
                ((d8, 2000, 3), (d2, 5000, 4))):
            for _ in range(reps):
                stream.append(*sample(dense, n))
                r = sched.submit(stream).result()
                rec = r.stats.rank_trajectory[-1]
                steps.append({"phase": phase,
                              "core_dims": list(rec["core_dims"]),
                              "modeled_total_s": rec["modeled_total_s"],
                              "decision": r.decision,
                              "fit": r.fits[-1]})
    dims = [s["core_dims"] for s in steps]
    grew = shrank = False
    for a, b in zip(dims, dims[1:]):
        grew = grew or any(y > x for x, y in zip(a, b))
        shrank = shrank or any(y < x for x, y in zip(a, b))
    out["adaptive"] = {"steps": steps, "grew": grew, "shrank": shrank}
    print("JSON::" + json.dumps(out))
"""


def bench_sketch_warmstart() -> None:
    """Acceptance: the sketched range-finder warm start cuts counted
    oracle Z passes >=1.5x vs the full Golub-Kahan budget at equal final
    fit (within 1e-3); the adaptive-rank scheduler demonstrably grows AND
    shrinks a mode's rank mid-stream with the plan cost re-scored at each
    rank change."""
    out = _run_subprocess_bench(_SKETCH_WARMSTART_BODY)
    oracle = out["oracle"]
    for name, rec in oracle.items():
        _row(f"sketch_warmstart/{name}", rec["wall_s"] * 1e6,
             f"z_passes_per_mode={'/'.join(map(str, rec['z_passes_per_mode']))};"
             f"z_passes_sweep_total={rec['z_passes_total']};"
             f"final_fit={rec['final_fit']:.4f}")
    ratio = oracle["full_gk"]["z_passes_total"] \
        / max(oracle["sketch"]["z_passes_total"], 1)
    delta = abs(oracle["full_gk"]["final_fit"] - oracle["sketch"]["final_fit"])
    _row("sketch_warmstart/oracle_acceptance", -1.0,
         f"passes_drop={ratio:.2f}x;ok={ratio >= 1.5};"
         f"fit_delta={delta:.2e};fit_ok={delta < 1e-3};"
         f"none_bitwise={out['none_bitwise']}")
    ad = out["adaptive"]
    for i, s in enumerate(ad["steps"]):
        _row(f"sketch_warmstart/adaptive_step{i}", -1.0,
             f"phase={s['phase']};core_dims={'x'.join(map(str, s['core_dims']))};"
             f"modeled_total_s={s['modeled_total_s']:.3e};"
             f"decision={s['decision']};fit={s['fit']:.4f}")
    _row("sketch_warmstart/adaptive_acceptance", -1.0,
         f"grew={ad['grew']};shrank={ad['shrank']};"
         f"ok={ad['grew'] and ad['shrank']}")


_MIXED_BACKENDS_BODY = """
    import json, time
    import numpy as np
    from repro.core.calibrate import CostModel, set_cost_model
    from repro.core.plan import plan, plan_cache_clear
    from repro.data.tensors import synth_tensor
    from repro.distributed.dist_hooi import dist_hooi

    out = {}
    t = synth_tensor((160, 140, 120), 30_000, alphas=(1.4, 1.0, 1.0),
                     hub_fraction=0.15, hub_modes=(0,), seed=7)
    core = (8, 8, 8)
    try:
        # per-mode baseline/liteopt byte ratios decide the psum/boundary
        # crossover; a bandwidth ratio strictly between the extremes makes
        # the auto selector split the modes across backends
        pl = plan(t, "medium", 8, core_dims=core, path="auto",
                  use_cache=False)
        ratios = {n: pl.comm(n)["baseline_bytes"]
                  / max(pl.comm(n)["liteopt_bytes"], 1.0)
                  for n in range(t.ndim)}
        out["byte_ratios"] = {str(n): r for n, r in ratios.items()}
        rs = sorted(ratios.values())
        mid = float(np.sqrt(rs[0] * rs[-1]))
        configs = (
            ("default", None),
            ("psum_favored", CostModel(psum_bandwidth=1e12,
                                       boundary_bandwidth=1e9,
                                       source="bench:psum_favored")),
            ("split", CostModel(psum_bandwidth=1e10 * mid,
                                boundary_bandwidth=1e10,
                                source="bench:split")),
        )
        for name, cm in configs:
            set_cost_model(cm)
            plan_cache_clear()
            t0 = time.perf_counter()
            dec, stats = dist_hooi(t, core, 8, scheme="medium",
                                   n_invocations=1, path="auto", seed=0)
            bk = {str(n): stats.comm_backends[n]
                  for n in sorted(stats.comm_backends)}
            out[name] = {"wall_s": time.perf_counter() - t0,
                         "backends": bk, "fit": stats.fits[-1],
                         "mixed": len(set(bk.values())) > 1}
    finally:
        set_cost_model(None)
    print("JSON::" + json.dumps(out))
"""


def bench_mixed_backends() -> None:
    """Acceptance: ``path="auto"`` under a CostModel with skewed
    per-backend bandwidths picks a *heterogeneous* per-mode comm-backend
    map (some modes psum, some boundary) and records the chosen map."""
    out = _run_subprocess_bench(_MIXED_BACKENDS_BODY)
    ratios = ";".join(f"mode{n}={r:.3f}"
                      for n, r in sorted(out["byte_ratios"].items()))
    _row("mixed_backends/byte_ratios", -1.0, ratios)
    for name in ("default", "psum_favored", "split"):
        rec = out[name]
        bk = "/".join(rec["backends"][k] for k in sorted(rec["backends"]))
        _row(f"mixed_backends/{name}", rec["wall_s"] * 1e6,
             f"backends={bk};mixed={rec['mixed']};fit={rec['fit']:.4f}")
    _row("mixed_backends/acceptance", -1.0,
         f"split_mixed_ok={out['split']['mixed']};"
         f"uniform_default_ok={not out['default']['mixed']}")


_STOCH_REFRESH_BODY = """
    import json, time
    import numpy as np
    from repro.core.plan import plan as make_plan, plan_cache_clear
    from repro.data.tensors import synth_tensor
    from repro.distributed.executor import HooiExecutor
    from repro.engine.scheduler import StreamScheduler
    from repro.streaming import StreamingTensor

    core = (8, 8, 8)
    shape = (220, 200, 180)
    base = synth_tensor(shape, 40_000, seed=0)
    rng = np.random.default_rng(123)
    batches = []
    for b in range(6):
        c = np.stack([rng.integers(0, L, 3000) for L in shape], axis=1)
        batches.append((c, rng.standard_normal(3000)))

    # one-time warmup: platform startup charged to neither arm
    HooiExecutor(2).run(synth_tensor((24, 20, 18), 500, seed=99),
                        (2, 2, 2), "lite", n_invocations=1)

    def run_arm(sample):
        plan_cache_clear()
        ex = HooiExecutor(8)
        stream = StreamingTensor.from_tensor(base, name="bench")
        kw = {}
        if sample:
            kw = dict(sample_fraction=0.25, sample_seed=7, replay_nnz=1024,
                      stochastic_tol=0.25, correction_every=0)
        recs = []
        with StreamScheduler(ex, core, n_invocations=2, workers=2,
                             **kw) as sched:
            first = sched.submit(stream, seed=0).result()
            for i, (c, v) in enumerate(batches):
                stream.append(c, v)
                r = sched.submit(stream, seed=1 + i).result()
                recs.append({"decision": r.decision, "run_s": r.run_s,
                             "compilations": r.stats.step_compilations,
                             "uploads": r.stats.uploads,
                             "fit": float(r.stats.fits[-1]),
                             "sample_nnz": r.stats.sample_nnz})
        return {"first_fit": float(first.stats.fits[-1]), "appends": recs,
                "final_fit": recs[-1]["fit"]}

    out = {"baseline": run_arm(False), "stochastic": run_arm(True)}

    # rerun contract on the refine path itself: the same refine twice on
    # one executor — second run must be fully cached and bitwise equal
    stream = StreamingTensor.from_tensor(base, name="rerun")
    snap0 = stream.snapshot()
    pl = make_plan(snap0, "lite", 8, core_dims=core, pad_geometric=True)
    ex = HooiExecutor(8)
    dec, _ = ex.run(snap0, core, pl, n_invocations=1, seed=0)
    stream.append(*batches[0])
    snap1 = stream.snapshot()
    runs = []
    for rep in range(2):
        rdec, rst = ex.run_stochastic(
            snap1, core, pl, init_factors=dec.factors,
            covered_nnz=snap0.nnz, sample_fraction=0.25, sample_seed=7,
            seed=1)
        runs.append({"compilations": rst.step_compilations,
                     "uploads": rst.uploads,
                     "fits": [float(f) for f in rst.fits]})
    out["rerun"] = {"compilations": runs[1]["compilations"],
                    "uploads": runs[1]["uploads"],
                    "fits_equal": runs[0]["fits"] == runs[1]["fits"]}
    print("JSON::" + json.dumps(out))
"""


def bench_stochastic_refresh() -> None:
    """Acceptance for the stochastic-refine rung: over a 6-batch append
    stream, sampled refines cut per-append device time >= 3x vs full
    sweeps while the final fit stays within 5e-2 of the full-sweep
    trajectory, and rerunning the same refine is fully cached (0/0)."""
    out = _run_subprocess_bench(_STOCH_REFRESH_BODY)
    base, stoch = out["baseline"], out["stochastic"]
    refines = [r for r in stoch["appends"]
               if r["decision"] == "stochastic-refine"]
    for arm, recs in (("full", base["appends"]),
                      ("sampled", stoch["appends"])):
        decisions = "/".join(r["decision"] for r in recs)
        # append 0 pays the arm's one-time step compile (the stochastic
        # minibatch step for the sampled arm); steady state is the rest
        steady = [r["run_s"] for r in recs[1:]]
        mean_s = sum(steady) / len(steady)
        per = "/".join(f"{r['run_s']:.2f}" for r in recs)
        _row(f"stochastic_refresh/{arm}_appends", mean_s * 1e6,
             f"decisions={decisions};per_append_s={per};"
             f"compilations={sum(r['compilations'] for r in recs[1:])};"
             f"final_fit={recs[-1]['fit']:.4f}")
    full_s = [r["run_s"] for r in base["appends"][1:]]
    refine_s = [r["run_s"] for r in stoch["appends"][1:]
                if r["decision"] == "stochastic-refine"]
    speedup = (sum(full_s) / len(full_s)) / max(
        sum(refine_s) / max(len(refine_s), 1), 1e-9) if refine_s else 0.0
    fit_delta = abs(stoch["final_fit"] - base["final_fit"])
    ok = (speedup >= 3.0 and fit_delta <= 5e-2
          and len(refines) == len(stoch["appends"]))
    _row("stochastic_refresh/acceptance", -1.0,
         f"ok={ok};speedup={speedup:.1f}x;fit_delta={fit_delta:.4f};"
         f"refines={len(refines)}/{len(stoch['appends'])};"
         f"sample_nnz={refines[0]['sample_nnz'] if refines else None}")
    rr = out["rerun"]
    rerun_ok = (rr["compilations"] == 0 and rr["uploads"] == 0
                and rr["fits_equal"])
    _row("stochastic_refresh/rerun_fully_cached", -1.0,
         f"ok={rerun_ok};compilations={rr['compilations']};"
         f"uploads={rr['uploads']};fits_bitwise_equal={rr['fits_equal']}")


BENCHES = [
    bench_dataset_suite,
    bench_metrics,
    bench_comm_volume,
    bench_scaling,
    bench_distribution_time,
    bench_memory,
    bench_time_breakup,
    bench_kernel_oracle,
    bench_kernel_ttm,
    bench_kernel_roofline,
    bench_auto_selection,
    bench_plan_cache,  # subprocess, 8 devices
    bench_executor_reuse,  # subprocess, 8 devices
    bench_scheduler_overlap,  # subprocess, 8 devices
    bench_pool_throughput,  # subprocess, 8 devices
    bench_objectives,  # subprocess, 8 devices
    bench_sketch_warmstart,  # subprocess, 8 devices
    bench_mixed_backends,  # subprocess, 8 devices
    bench_stochastic_refresh,  # subprocess, 8 devices
    bench_hooi_time,  # slowest (subprocess, 8 devices) — last
]


def bench_environment() -> dict:
    """Provenance stamp written into every ``BENCH_<name>.json``.

    Bench artifacts accumulate across PRs; without the git SHA, timestamp,
    jax version and device kind they are not comparable as a trajectory.
    Every field degrades to a sentinel rather than failing the bench run.
    """
    import datetime

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(_SRC),
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — git absent / not a checkout
        sha = "unknown"
    try:
        import jax

        jax_version = jax.__version__
    except Exception:  # noqa: BLE001
        jax_version = "unknown"
    try:
        dev = jax.devices()[0]  # may raise even when jax imports fine
        device_kind = getattr(dev, "device_kind", "unknown")
        platform = getattr(dev, "platform", jax.default_backend())
    except Exception:  # noqa: BLE001
        device_kind = platform = "unknown"
    return {
        "git_sha": sha,
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "jax_version": jax_version,
        "device_kind": device_kind,
        "platform": platform,
    }


def _artifact_path(out_dir: str, bench_name: str) -> str:
    """``BENCH_<slug>.json`` inside ``out_dir`` — guarded.

    The slug comes from a function name today, but bench registries have
    grown dynamic entries before; a slug with a path separator (or any
    char outside ``[A-Za-z0-9_.-]``) could silently write an artifact
    outside the artifact dir, and CI would upload nothing while reading
    all green. Both the slug and the joined path are checked."""
    import re

    # bench_scheduler_overlap -> BENCH_scheduler_overlap.json
    slug = bench_name.removeprefix("bench_")
    if not re.fullmatch(r"[A-Za-z0-9_.-]+", slug):
        raise RuntimeError(
            f"bench name {bench_name!r} yields unsafe artifact slug "
            f"{slug!r} — refusing to write outside the artifact dir")
    out_real = os.path.realpath(out_dir)
    path = os.path.realpath(os.path.join(out_dir, f"BENCH_{slug}.json"))
    if os.path.dirname(path) != out_real:
        raise RuntimeError(
            f"artifact path {path!r} escapes the artifact dir {out_real!r}")
    return path


def run_benches(benches, out_dir: str | None = None) -> list[str]:
    """Run ``benches``, writing one ``BENCH_<name>.json`` each to
    ``out_dir`` (the perf-trajectory artifacts CI uploads). A bench that
    raises still produces a JSON (rows so far + the error) and does not
    stop the rest; an *empty* bench list is refused loudly — a filtering
    bug upstream would otherwise write no artifacts and read as "all
    green". A bench (or a buggy artifact path) that drops ``BENCH_*.json``
    files *outside* ``out_dir`` is also refused loudly: stray artifacts
    in the working or benchmarks directory would never be uploaded, and
    the perf trajectory would silently lose its data points. Returns the
    written paths."""
    import glob
    import json

    benches = list(benches)
    if not benches:
        raise ValueError(
            "run_benches() got an empty bench list — refusing to silently "
            "produce no artifacts (check the bench selection/filter)")
    meta = bench_environment()
    out_dir = out_dir or os.environ.get("BENCH_OUT_DIR") or "."
    os.makedirs(out_dir, exist_ok=True)
    out_real = os.path.realpath(out_dir)
    # dirs a misdirected artifact would plausibly land in
    scan_dirs = sorted({os.path.realpath(os.getcwd()),
                        os.path.realpath(os.path.dirname(
                            os.path.abspath(__file__)))} - {out_real})
    before = {d: set(glob.glob(os.path.join(d, "BENCH_*.json")))
              for d in scan_dirs}
    written = []
    for bench in benches:
        _ROWS.clear()
        err = None
        t0 = time.perf_counter()
        try:
            bench()
        except Exception as e:  # noqa: BLE001
            err = f"{type(e).__name__}: {e}"
            _row(bench.__name__, -1.0, f"ERROR={err}")
        dt = time.perf_counter() - t0
        print(f"# {bench.__name__} took {dt:.1f}s", file=sys.stderr)
        path = _artifact_path(out_dir, bench.__name__)
        with open(path, "w") as f:
            json.dump({"bench": bench.__name__, "took_s": dt,
                       "error": err, "meta": meta, "rows": list(_ROWS)},
                      f, indent=1)
        written.append(path)
    stray = sorted(p for d in scan_dirs
                   for p in set(glob.glob(os.path.join(d, "BENCH_*.json")))
                   - before[d])
    if stray:
        raise RuntimeError(
            f"bench run dropped BENCH_*.json artifacts outside the "
            f"artifact dir {out_real!r}: {stray} — these would never be "
            f"uploaded; route them through --out-dir/BENCH_OUT_DIR")
    return written


def list_benches() -> list[tuple[str, str]]:
    """(name, one-line summary) for every registered bench — what
    ``--list`` prints, so the names are discoverable without reading
    source."""
    out = []
    for bench in BENCHES:
        doc = (bench.__doc__ or "").strip().splitlines()
        out.append((bench.__name__, doc[0] if doc else ""))
    return out


def select_benches(names: list[str]) -> list:
    """Resolve user-supplied names (with or without the ``bench_`` prefix)
    to bench functions; unknown names fail loudly with the full menu."""
    by_name = {b.__name__: b for b in BENCHES}
    picked = []
    for raw in names:
        name = raw if raw.startswith("bench_") else f"bench_{raw}"
        if name not in by_name:
            known = ", ".join(sorted(by_name))
            raise SystemExit(f"unknown bench {raw!r}; known: {known}")
        picked.append(by_name[name])
    return picked


def main(argv: list[str] | None = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--list" in argv:
        for name, summary in list_benches():
            print(f"{name:28s} {summary}")
        return
    out_dir = None
    if "--out-dir" in argv:
        i = argv.index("--out-dir")
        if i + 1 >= len(argv):
            sys.exit("--out-dir requires a directory argument")
        out_dir = argv[i + 1]
        del argv[i:i + 2]
    unknown = [a for a in argv if a.startswith("-")]
    if unknown:
        # a typo'd flag must not silently fall through to "run everything"
        sys.exit(f"unknown option(s): {' '.join(unknown)} "
                 "(supported: --list, --out-dir DIR, bench names)")
    names = list(argv)
    benches = select_benches(names) if names else BENCHES
    print("name,us_per_call,derived")
    run_benches(benches, out_dir)


if __name__ == "__main__":
    main()
