"""CostModel calibration: fitting measured sweeps and feeding the rates
back into the plan layer's auto selector (cache-version invalidation)."""

import pytest

from repro.core.calibrate import (
    CostModel,
    DEFAULT_COST_MODEL,
    cost_model_version,
    current_cost_model,
    fit_cost_model,
    set_cost_model,
)
from repro.core.plan import plan, plan_cache_clear


@pytest.fixture(autouse=True)
def _restore_model():
    yield
    set_cost_model(None)


def _samples(flop_rate, bandwidth, specs):
    return [
        {"critical_path_flops": f, "comm_bytes": b,
         "seconds": f / flop_rate + b / bandwidth}
        for f, b in specs
    ]


def test_fit_recovers_both_rates():
    # two independent (flops, bytes) directions -> full-rank joint fit
    s = _samples(2.0e10, 5.0e9, [(1e9, 1e6), (4e9, 1e6), (1e9, 8e8),
                                 (2e9, 4e8)])
    cm = fit_cost_model(s)
    assert cm.flop_rate == pytest.approx(2.0e10, rel=1e-6)
    assert cm.net_bandwidth == pytest.approx(5.0e9, rel=1e-6)
    assert cm.source == "fitted:4"


def test_fit_degenerate_pins_bandwidth_to_base():
    # one plan measured repeatedly: rank-1 design -> flops-only fit
    s = _samples(1.0e9, DEFAULT_COST_MODEL.net_bandwidth,
                 [(1e9, 1e5), (1e9, 1e5)])
    cm = fit_cost_model(s)
    assert cm.net_bandwidth == DEFAULT_COST_MODEL.net_bandwidth
    assert cm.flop_rate == pytest.approx(1.0e9, rel=1e-3)


def test_fit_degenerate_with_overpredicted_comm_stays_sane():
    """Shared-memory mesh: real comm is much faster than the base model, so
    the pinned-comm residual goes negative — the fit must attribute the
    time to flops, not invert a clamped residual into an absurd rate."""
    # one plan, comm_bytes/base_bw (=10 s) far exceeds measured 1e-3 s
    s = [{"critical_path_flops": 1e7, "comm_bytes": 1e11, "seconds": 1e-3}
         for _ in range(3)]
    cm = fit_cost_model(s)
    # all measured time attributed to flops: rate = flops / seconds
    assert cm.flop_rate == pytest.approx(1e7 / 1e-3, rel=1e-6)
    assert cm.flop_rate < 1e12  # nowhere near the absurd 1e17+ regime


def test_fit_filters_cold_samples():
    warm = _samples(1.0e10, 1.0e10, [(1e9, 1e6), (3e9, 5e8)])
    cold = [{"critical_path_flops": 1e9, "comm_bytes": 1e6,
             "seconds": 50.0, "warm": False}]  # jit time, not machine rate
    cm = fit_cost_model(warm + cold)
    assert cm.flop_rate == pytest.approx(1.0e10, rel=1e-6)
    with pytest.raises(ValueError):
        fit_cost_model(cold)  # nothing usable once cold ones are dropped


def _phase_samples(r_ttm, r_svd, bandwidth, specs):
    return [
        {"ttm_flops": tf, "svd_flops": sf, "comm_bytes": b,
         "critical_path_flops": tf + sf,
         "seconds": tf / r_ttm + sf / r_svd + b / bandwidth}
        for tf, sf, b in specs
    ]


def test_fit_recovers_phase_rates():
    """Full-rank per-phase design (a pure-TTM probe plus mixed sweeps, as
    profile_phases records) separates the TTM and SVD rates."""
    s = _phase_samples(4.0e10, 1.0e10, 5.0e9,
                       [(1e9, 0.0, 0.0),       # zbuild-only probe
                        (1e9, 2e9, 1e6),       # full sweeps, varying mix
                        (3e9, 1e9, 8e8),
                        (2e9, 4e9, 4e8)])
    cm = fit_cost_model(s)
    assert cm.source == "fitted-phases:4"
    assert cm.ttm_flop_rate == pytest.approx(4.0e10, rel=1e-5)
    assert cm.svd_flop_rate == pytest.approx(1.0e10, rel=1e-5)
    assert cm.net_bandwidth == pytest.approx(5.0e9, rel=1e-5)
    rt, rs = cm.phase_rates()
    assert (rt, rs) == (cm.ttm_flop_rate, cm.svd_flop_rate)
    # combined rate stays a sane average of the two phases
    assert 1.0e10 < cm.flop_rate < 4.0e10


def test_fit_phase_degenerate_falls_back_to_joint():
    """Proportional ttm/svd columns cannot be separated — the fit must fall
    back to the single-rate path, not return garbage rates."""
    s = _phase_samples(2.0e10, 2.0e10, DEFAULT_COST_MODEL.net_bandwidth,
                       [(1e9, 2e9, 1e5), (2e9, 4e9, 2e5), (4e9, 8e9, 4e5)])
    cm = fit_cost_model(s)
    assert cm.source.startswith("fitted:")  # not fitted-phases
    assert cm.ttm_flop_rate is None and cm.svd_flop_rate is None
    assert cm.phase_rates() == (cm.flop_rate, cm.flop_rate)


def test_fit_phase_comm_degenerate_pins_bandwidth():
    """Separable phases but constant comm: bandwidth pinned to base, phase
    rates still recovered from the residual."""
    s = _phase_samples(4.0e10, 1.0e10, DEFAULT_COST_MODEL.net_bandwidth,
                       [(1e9, 0.0, 0.0), (1e9, 2e9, 0.0), (3e9, 1e9, 0.0)])
    cm = fit_cost_model(s)
    assert cm.source == "fitted-phases:3"
    assert cm.net_bandwidth == DEFAULT_COST_MODEL.net_bandwidth
    assert cm.ttm_flop_rate == pytest.approx(4.0e10, rel=1e-5)
    assert cm.svd_flop_rate == pytest.approx(1.0e10, rel=1e-5)


def test_phase_rate_validation():
    with pytest.raises(ValueError):
        CostModel(ttm_flop_rate=-1.0)
    with pytest.raises(ValueError):
        CostModel(svd_flop_rate=0.0)


def test_cost_model_validates():
    with pytest.raises(ValueError):
        CostModel(flop_rate=0.0)
    with pytest.raises(ValueError):
        fit_cost_model([])
    with pytest.raises(TypeError):
        set_cost_model(42)


def test_predict_seconds():
    cm = CostModel(flop_rate=2.0, net_bandwidth=4.0)
    assert cm.predict_seconds(6.0, 8.0) == pytest.approx(5.0)


# ------------------------------------------------ feedback into the selector
def test_set_cost_model_rescales_plan_costs(small_tensor):
    plan_cache_clear()
    p_def = plan(small_tensor, "lite", 8)
    v0 = cost_model_version()
    set_cost_model(CostModel(flop_rate=2 * DEFAULT_COST_MODEL.flop_rate,
                             net_bandwidth=DEFAULT_COST_MODEL.net_bandwidth,
                             source="fitted:test"))
    assert cost_model_version() == v0 + 1
    assert current_cost_model().source == "fitted:test"
    # the model version is part of the cache key: no stale-cost reuse
    p_fit = plan(small_tensor, "lite", 8)
    assert p_fit is not p_def
    assert p_fit.cost.flops_s == pytest.approx(p_def.cost.flops_s / 2)
    assert p_fit.cost.comm_s == pytest.approx(p_def.cost.comm_s)
    # auto re-scores its candidates under the installed rates
    auto = plan(small_tensor, "auto", 8)
    assert auto.cost.total_s == min(auto.candidates.values())


def test_phase_rates_reach_plan_cost(small_tensor):
    """Calibrated per-phase rates must re-score PlanCost's ttm_s/svd_s split
    (and therefore auto selection) through the versioned cache key."""
    plan_cache_clear()
    p_def = plan(small_tensor, "lite", 8)
    assert p_def.cost.flops_s == pytest.approx(
        p_def.cost.ttm_s + p_def.cost.svd_s)
    set_cost_model(CostModel(
        flop_rate=DEFAULT_COST_MODEL.flop_rate,
        net_bandwidth=DEFAULT_COST_MODEL.net_bandwidth,
        ttm_flop_rate=4 * DEFAULT_COST_MODEL.flop_rate,  # kernel-speed TTM
        svd_flop_rate=DEFAULT_COST_MODEL.flop_rate,
        source="fitted-phases:test"))
    p_fit = plan(small_tensor, "lite", 8)
    assert p_fit is not p_def  # version bump: no stale-cost reuse
    assert p_fit.cost.ttm_s == pytest.approx(p_def.cost.ttm_s / 4)
    assert p_fit.cost.svd_s == pytest.approx(p_def.cost.svd_s)
    auto = plan(small_tensor, "auto", 8)
    assert auto.cost.total_s == min(auto.candidates.values())


def test_set_cost_model_none_restores_default():
    set_cost_model(CostModel(flop_rate=1.0e3, net_bandwidth=1.0e3))
    assert current_cost_model().flop_rate == 1.0e3
    assert set_cost_model(None) is DEFAULT_COST_MODEL
    assert current_cost_model() is DEFAULT_COST_MODEL
