"""The layered SweepEngine: stage seams, comm backends, P=1 structural
parity, and the cached-plan rerun contract on every backend.

In-process multi-device tests rely on conftest.py setting 8 simulated host
devices before jax initializes.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.calibrate import (
    CostModel,
    _fit_backend_bandwidths,
    set_cost_model,
)
from repro.core.plan import plan, plan_cache_clear


def _need_devices(n):
    import jax

    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} simulated devices (conftest sets XLA_FLAGS)")


@pytest.fixture(autouse=True)
def _restore_cost_model():
    yield
    set_cost_model(None)


# --------------------------------------------------- oracle seam (fused)
def test_fused_oracle_matches_svd_via_lanczos():
    """The Pallas oracle_pair kernel, wired through the oracle seam, must
    reproduce svd_via_lanczos on an explicit Z (same key, same driver)."""
    import jax
    import jax.numpy as jnp

    from repro.core.lanczos import lanczos_bidiag, svd_via_lanczos
    from repro.engine.oracle import z_products

    key = jax.random.PRNGKey(7)
    m, n, k = 40, 12, 4
    u, _ = jnp.linalg.qr(jax.random.normal(key, (m, m)))
    v, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                           (n, n)))
    s = jnp.concatenate([10.0 * 0.5 ** jnp.arange(k),
                         1e-3 * jnp.ones(n - k)])
    Z = (u[:, :n] * s) @ v
    ref = svd_via_lanczos(Z, k, key=jax.random.fold_in(key, 2))
    mv, rmv = z_products(Z, fused=True)
    fused = lanczos_bidiag(mv, rmv, m, n, k, key=jax.random.fold_in(key, 2))
    np.testing.assert_allclose(fused.singular_values, ref.singular_values,
                               rtol=1e-4)
    Pf = fused.left_vectors @ fused.left_vectors.T
    Pr = ref.left_vectors @ ref.left_vectors.T
    np.testing.assert_allclose(Pf, Pr, atol=1e-3)
    assert fused.n_queries == ref.n_queries


def test_hooi_fused_oracle_flag(small_tensor):
    """use_fused_oracle=None/False is off; True routes the oracle products
    through the kernel and must not change the trajectory."""
    from repro.core.hooi import hooi

    t = small_tensor
    _, fits_plain = hooi(t, (3, 3, 3), n_invocations=2, seed=1)
    _, fits_none = hooi(t, (3, 3, 3), n_invocations=2, seed=1,
                        use_fused_oracle=None)
    _, fits_fused = hooi(t, (3, 3, 3), n_invocations=2, seed=1,
                         use_fused_oracle=True)
    np.testing.assert_allclose(fits_none, fits_plain, atol=0)  # None == off
    np.testing.assert_allclose(fits_fused, fits_plain, atol=1e-4)


@pytest.mark.slow
def test_dist_fused_oracle_differential(lowrank_tensor):
    """The fused oracle is a distinct compiled variant of the distributed
    step and converges to the same decomposition."""
    _need_devices(4)
    from repro.distributed.executor import HooiExecutor

    t = lowrank_tensor
    ex = HooiExecutor(4)
    pl = plan(t, "lite", 4, core_dims=(2, 2, 2))
    _, sf = ex.run(t, (2, 2, 2), pl, n_invocations=2, seed=0,
                   use_fused_oracle=True)
    _, sp = ex.run(t, (2, 2, 2), pl, n_invocations=2, seed=0)
    assert sf.fused_oracle and not sp.fused_oracle
    # fused and plain variants are distinct executables, not cache hits
    assert sf.step_compilations == t.ndim
    assert sp.step_compilations == t.ndim
    # the exactly-rank-2 tensor drives Lanczos through breakdown restarts,
    # where the kernel's blocked f32 accumulation can flip the threshold
    # branch — trajectories agree to restart-level tolerance, and both
    # must nail the exact rank
    np.testing.assert_allclose(sf.fits, sp.fits, atol=1e-3)
    assert sf.fits[-1] > 0.999 and sp.fits[-1] > 0.999


# ------------------------------------------------------- comm backends
def test_resolve_backend_mapping():
    from repro.engine import resolve_backend

    assert resolve_backend("baseline", 4) == "psum"
    assert resolve_backend("liteopt", 4) == "boundary"
    assert resolve_backend("baseline", 1) == "local"
    assert resolve_backend("liteopt", 1) == "local"
    assert resolve_backend("auto", 1) == "local"
    assert resolve_backend("boundary", 8) == "boundary"
    cheap_psum = {"baseline_bytes": 1.0, "liteopt_bytes": 2.0}
    cheap_bnd = {"baseline_bytes": 2.0, "liteopt_bytes": 1.0}
    assert resolve_backend("auto", 4, cheap_psum) == "psum"
    assert resolve_backend("auto", 4, cheap_bnd) == "boundary"
    with pytest.raises(ValueError, match="unknown path"):
        resolve_backend("bogus", 4)


def test_plan_backend_cost_entries(small_tensor):
    """PlanCost scores every comm backend and records the per-mode choice
    — the auto selector compares backends, not just schemes."""
    t = small_tensor
    pb = plan(t, "lite", 8, path="baseline", use_cache=False)
    pl = plan(t, "lite", 8, path="liteopt", use_cache=False)
    pa = plan(t, "lite", 8, path="auto", use_cache=False)
    for p in (pb, pl, pa):
        assert set(p.cost.backend_s) >= {"psum", "boundary"}
        assert all(v >= 0 for v in p.cost.backend_s.values())
    assert pb.cost.mode_backends == ("psum",) * t.ndim
    assert pl.cost.mode_backends == ("boundary",) * t.ndim
    assert pa.cost.path == "auto"
    assert all(b in ("psum", "boundary") for b in pa.cost.mode_backends)
    # auto's comm model is never worse than either forced family
    assert pa.cost.comm_s <= pb.cost.comm_s + 1e-15
    assert pa.cost.comm_s <= pl.cost.comm_s + 1e-15
    # P=1: the collective-free local backend
    p1 = plan(t, "lite", 1, path="liteopt", use_cache=False)
    assert p1.cost.mode_backends == ("local",) * t.ndim
    assert "local" in p1.cost.backend_s


def test_backend_bandwidths_rescore_auto(small_tensor):
    """Calibrated per-backend bandwidths shift the auto backend choice
    through the versioned cost model."""
    t = small_tensor
    plan_cache_clear()
    base = plan(t, "lite", 8, path="auto")
    # boundary moves fewer bytes, so the default model picks it everywhere
    assert base.cost.mode_backends == ("boundary",) * t.ndim
    set_cost_model(CostModel(psum_bandwidth=1e18,  # psum now ~free
                             boundary_bandwidth=1e6))
    recal = plan(t, "lite", 8, path="auto")
    assert recal is not base  # version bump: no stale-cost reuse
    assert recal.cost.mode_backends == ("psum",) * t.ndim
    assert recal.cost.backend_s["psum"] < recal.cost.backend_s["boundary"]


def test_fit_backend_bandwidths_helper():
    """Labelled samples with known per-backend bandwidths are recovered
    exactly from the comm residual."""
    cm = CostModel(flop_rate=2e10, source="fitted:test")
    bw = {"psum": 1e9, "boundary": 5e9}

    def sample(flops, nbytes, backend):
        return {"critical_path_flops": flops, "ttm_flops": flops,
                "svd_flops": 0.0, "comm_bytes": nbytes,
                "seconds": flops / 2e10 + nbytes / bw[backend],
                "comm_backend": backend}

    use = [sample(1e9, 1e8, "psum"), sample(2e9, 3e8, "psum"),
           sample(1e9, 1e8, "boundary"), sample(3e9, 2e8, "boundary")]
    out = _fit_backend_bandwidths(use, cm)
    assert out.psum_bandwidth == pytest.approx(1e9, rel=1e-6)
    assert out.boundary_bandwidth == pytest.approx(5e9, rel=1e-6)
    assert out.source == "fitted:test+backends"
    assert out.bandwidth_for("psum") == out.psum_bandwidth
    assert out.bandwidth_for("boundary") == out.boundary_bandwidth
    assert out.bandwidth_for("local") == out.net_bandwidth
    assert out.comm_seconds(2e9, "psum") == pytest.approx(2.0)
    # unlabelled / mixed samples leave the model untouched
    mixed = dict(sample(1e9, 1e8, "psum"), comm_backend="mixed")
    assert _fit_backend_bandwidths([mixed], cm) is cm


@pytest.mark.slow
def test_executor_samples_carry_backend_label(lowrank_tensor):
    _need_devices(4)
    from repro.distributed.executor import HooiExecutor

    ex = HooiExecutor(4)
    ex.run(lowrank_tensor, (2, 2, 2), "lite", n_invocations=1, seed=0)
    ex.run(lowrank_tensor, (2, 2, 2), "lite", n_invocations=1, seed=0,
           path="baseline")
    labels = {s["comm_backend"] for s in ex.calibration_samples()}
    assert labels == {"boundary", "psum"}


# ------------------------------------------------ P=1 structural parity
@pytest.mark.slow
@pytest.mark.parametrize("path", ["baseline", "liteopt", "auto"])
def test_p1_trajectory_identical_to_single_process(path, lowrank_tensor):
    """Acceptance: dist_hooi(P=1) runs the very same engine stages as
    single-process hooi (local backend, shared loop, shared key schedule),
    so the fit trajectories coincide — parity by architecture, not by
    differential tolerance."""
    from repro.core.hooi import hooi
    from repro.distributed.dist_hooi import dist_hooi

    t = lowrank_tensor
    core = (2, 2, 2)
    _, fits_ref = hooi(t, core, n_invocations=3, seed=0)
    _, st = dist_hooi(t, core, 1, scheme="lite", n_invocations=3,
                      path=path, seed=0)
    assert set(st.comm_backends.values()) == {"local"}
    np.testing.assert_allclose(st.fits, fits_ref, atol=1e-6)
    assert fits_ref[-1] > 0.99


# ---------------------------------------- rerun contract, every backend
@pytest.mark.slow
@pytest.mark.parametrize("P,path,backend", [
    (1, "liteopt", "local"),
    (4, "baseline", "psum"),
    (4, "liteopt", "boundary"),
])
def test_rerun_contract_all_backends(lowrank_tensor, P, path, backend):
    """Acceptance: the cached-plan rerun guarantee (0 new compilations,
    0 new uploads) holds on all three comm backends."""
    _need_devices(P)
    from repro.distributed.executor import HooiExecutor

    t = lowrank_tensor
    ex = HooiExecutor(P)
    pl = plan(t, "lite", P, core_dims=(2, 2, 2), path=path)
    _, s1 = ex.run(t, (2, 2, 2), pl, n_invocations=1, seed=0, path=path)
    assert set(s1.comm_backends.values()) == {backend}
    assert s1.step_compilations == t.ndim
    assert s1.uploads == 9 * t.ndim + 2
    _, s2 = ex.run(t, (2, 2, 2), pl, n_invocations=1, seed=1, path=path)
    assert s2.step_compilations == 0
    assert s2.uploads == 0
    assert s2.upload_cache_hit
    assert s2.step_cache_hits == t.ndim
    assert s2.fits[-1] > 0.99


# ----------------------------- plan persistence meets the fitted model
@pytest.mark.slow
def test_loaded_plan_upload_cache_and_fitted_cost(lowrank_tensor, tmp_path):
    """A save()/load() round-tripped plan must preserve the fitted
    CostModel's scoring (per-phase and per-backend entries included) and
    hit the executor's upload-cache semantics: jit shared via shapes on
    first run, one upload for the new object, then the full 0/0 rerun."""
    _need_devices(4)
    from repro.core.plan import PartitionPlan
    from repro.distributed.executor import HooiExecutor

    set_cost_model(CostModel(
        flop_rate=2e10, net_bandwidth=2e9,
        ttm_flop_rate=8e10, svd_flop_rate=1e10,
        psum_bandwidth=1e9, boundary_bandwidth=6e9,
        source="fitted-phases:test+backends"))
    t = lowrank_tensor
    ex = HooiExecutor(4)
    pl = plan(t, "auto", 4, core_dims=(2, 2, 2))
    assert pl.cost.backend_s is not None
    _, s1 = ex.run(t, (2, 2, 2), pl, n_invocations=1, seed=0)
    assert s1.uploads == 9 * t.ndim + 2

    f = str(tmp_path / "plan.npz")
    pl.save(f)
    loaded = PartitionPlan.load(f, t)
    assert loaded is not pl
    # fitted scoring survives the round-trip bit-exactly
    assert dataclasses.asdict(loaded.cost) == dataclasses.asdict(pl.cost)
    assert loaded.cost.backend_s == pl.cost.backend_s
    assert loaded.cost.mode_backends == pl.cost.mode_backends
    assert loaded.candidates == pl.candidates

    _, s2 = ex.run(t, (2, 2, 2), loaded, n_invocations=1, seed=0)
    assert s2.step_compilations == 0  # identical padded shapes share jit
    assert s2.uploads == 9 * t.ndim + 2  # new object -> one upload
    assert abs(s2.fits[-1] - s1.fits[-1]) < 1e-6
    _, s3 = ex.run(t, (2, 2, 2), loaded, n_invocations=1, seed=1)
    assert s3.step_compilations == 0 and s3.uploads == 0
    assert s3.upload_cache_hit
