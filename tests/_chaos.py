"""Deterministic fault injection for the serving-tier tests.

The pool/scheduler pipeline runs prepares on a multi-thread producer pool,
so "fail the 3rd call" is racy — thread interleaving changes which tensor
the 3rd call sees. Faults here are keyed by the *tensor fingerprint* the
executor call receives, which is interleaving-independent: a ``FaultPlan``
maps ``fingerprint -> FIFO list of actions`` per stage, and each executor
call for that tensor consumes the next action. Repeat runs with the same
submissions therefore hit the exact same faults, whatever the thread
schedule did.

Stages:
* ``"prepare"`` — wraps ``HooiExecutor.prepare`` (producer thread; a kill
  here surfaces through the scheduler's prepare-failure path).
* ``"run"``     — wraps ``HooiExecutor.run`` (consumer thread; a kill here
  surfaces through the sweep-failure path). The same stage also wraps
  ``run_stochastic`` when the executor has one, so a fingerprint-keyed
  fault fires whichever rung the scheduler routed the snapshot through.

Actions:
* ``kill(...)``  — raise ``ChaosError`` before the real call.
* ``delay(s)``   — sleep ``s`` seconds, then do the real call (for SLO-miss
  and backpressure tests).

``inject(executor, plan)`` patches the *instance* (original class methods
untouched) and restores on exit; ``plan.fired`` records what triggered, in
consumption order per (fingerprint, stage).
"""

from __future__ import annotations

import contextlib
import threading
import time


class ChaosError(RuntimeError):
    """An injected failure (never raised by real code paths)."""


class _Action:
    __slots__ = ("kind", "delay_s", "note", "event")

    def __init__(self, kind: str, delay_s: float = 0.0, note: str = "",
                 event: threading.Event | None = None):
        self.kind = kind  # "kill" | "delay" | "hold"
        self.delay_s = float(delay_s)
        self.note = note
        self.event = event


def kill(note: str = "injected kill") -> _Action:
    return _Action("kill", note=note)


def delay(delay_s: float, note: str = "injected delay") -> _Action:
    return _Action("delay", delay_s=delay_s, note=note)


def hold(event: threading.Event, note: str = "injected hold") -> _Action:
    """Block the call until ``event`` is set — deterministic congestion
    (backpressure tests fill a queue behind a held sweep, no sleeps)."""
    return _Action("hold", note=note, event=event)


class FaultPlan:
    """``(fingerprint, stage) -> FIFO of actions``; thread-safe consumption.

    ``at(fp, stage, *actions)`` arms actions for a tensor; each matching
    executor call pops one (calls past the end run clean — a killed stream
    that is resubmitted recovers). ``fired`` lists ``(fp8, stage, kind)``
    tuples in consumption order for assertions on what actually triggered.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._queues: dict[tuple[str, str], list[_Action]] = {}
        self.fired: list[tuple[str, str, str]] = []

    def at(self, fingerprint: str, stage: str, *actions: _Action) -> "FaultPlan":
        assert stage in ("prepare", "run"), stage
        key = (str(fingerprint), stage)
        with self._lock:
            self._queues.setdefault(key, []).extend(actions)
        return self

    def _next(self, fingerprint: str, stage: str) -> _Action | None:
        with self._lock:
            q = self._queues.get((str(fingerprint), stage))
            if not q:
                return None
            act = q.pop(0)
            self.fired.append((str(fingerprint)[:8], stage, act.kind))
            return act


def _apply(plan: FaultPlan, stage: str, t) -> None:
    fp = t.fingerprint()
    act = plan._next(fp, stage)
    if act is None:
        return
    if act.kind == "delay":
        time.sleep(act.delay_s)
        return
    if act.kind == "hold":
        act.event.wait()
        return
    raise ChaosError(f"{act.note} [{stage} fp={fp[:8]}]")


@contextlib.contextmanager
def inject(executor, plan: FaultPlan):
    """Patch ``executor.prepare``/``executor.run`` on the instance to consult
    ``plan`` before delegating; restores the instance on exit."""
    real_prepare = executor.prepare
    real_run = executor.run
    real_stoch = getattr(executor, "run_stochastic", None)

    def chaotic_prepare(t, *a, **kw):
        _apply(plan, "prepare", t)
        return real_prepare(t, *a, **kw)

    def chaotic_run(t, *a, **kw):
        _apply(plan, "run", t)
        return real_run(t, *a, **kw)

    def chaotic_run_stochastic(t, *a, **kw):
        _apply(plan, "run", t)
        return real_stoch(t, *a, **kw)

    executor.prepare = chaotic_prepare
    executor.run = chaotic_run
    if real_stoch is not None:
        executor.run_stochastic = chaotic_run_stochastic
    try:
        yield plan
    finally:
        # delete instance attributes -> class methods show through again
        del executor.prepare
        del executor.run
        if real_stoch is not None:
            del executor.run_stochastic
