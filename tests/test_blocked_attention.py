"""Blocked (flash-style) attention vs dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (_blocked_attention, _dense_attention)


def _qkv(seed, B, S, KV, G, Dh):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, S, KV, G, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, Dh))
    return q, k, v


@pytest.mark.parametrize("S", [2048, 4096])
@pytest.mark.parametrize("window", [None, 700])
def test_blocked_matches_dense(S, window):
    q, k, v = _qkv(0, 1, S, 2, 2, 16)
    want = _dense_attention(q, k, v, window=window)
    got = _blocked_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_blocked_grad_finite():
    q, k, v = _qkv(1, 1, 2048, 1, 2, 8)
    g = jax.grad(lambda qq: _blocked_attention(qq, k, v).sum())(q)
    assert np.isfinite(np.asarray(g)).all()
