"""Benchmark harness provenance: BENCH_*.json stamping + empty-list guard."""

import json
import os
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "benchmarks"))

import run as benchrun  # noqa: E402


def test_run_benches_refuses_empty_list(tmp_path):
    """A filtering bug upstream must fail loudly, not write no artifacts."""
    with pytest.raises(ValueError, match="empty bench list"):
        benchrun.run_benches([], out_dir=str(tmp_path))
    with pytest.raises(ValueError, match="empty bench list"):
        benchrun.run_benches(iter(()), out_dir=str(tmp_path))
    assert list(tmp_path.iterdir()) == []


def test_bench_json_carries_environment_stamp(tmp_path):
    """Every BENCH_<name>.json is stamped with git SHA, timestamp, jax
    version and device kind so the trajectory across PRs is comparable."""

    def bench_fake():
        benchrun._row("fake/row", 1.0, "derived=ok")

    paths = benchrun.run_benches([bench_fake], out_dir=str(tmp_path))
    assert len(paths) == 1
    with open(paths[0]) as f:
        data = json.load(f)
    assert data["bench"] == "bench_fake"
    assert data["rows"] and data["rows"][0]["name"] == "fake/row"
    meta = data["meta"]
    for field in ("git_sha", "timestamp", "jax_version", "device_kind",
                  "platform"):
        assert meta.get(field), field
    # a real git checkout resolves to a 40-hex SHA; degraded environments
    # record the sentinel rather than crashing the bench run
    assert meta["git_sha"] == "unknown" or len(meta["git_sha"]) == 40
    assert "T" in meta["timestamp"]  # ISO-8601


def test_bench_environment_is_self_contained():
    meta = benchrun.bench_environment()
    import jax

    assert meta["jax_version"] == jax.__version__


def test_artifact_path_guard(tmp_path):
    """Slug sanitization + containment: a dynamic bench name can never
    route a BENCH_*.json outside the artifact dir."""
    p = benchrun._artifact_path(str(tmp_path), "bench_hooi_time")
    assert p == os.path.join(os.path.realpath(str(tmp_path)),
                             "BENCH_hooi_time.json")
    for bad in ("bench_../evil", "bench_a/b", "bench_", "bench_a b"):
        with pytest.raises(RuntimeError, match="unsafe artifact slug"):
            benchrun._artifact_path(str(tmp_path), bad)


def test_run_benches_detects_stray_artifacts(tmp_path, monkeypatch):
    """A bench that drops BENCH_*.json into the working dir (instead of
    out_dir) fails the whole run loudly — CI would otherwise upload
    nothing while reading all green."""
    workdir = tmp_path / "cwd"
    workdir.mkdir()
    monkeypatch.chdir(workdir)

    def bench_rogue():
        with open("BENCH_rogue.json", "w") as f:
            f.write("{}")

    out = tmp_path / "artifacts"
    with pytest.raises(RuntimeError, match="outside the artifact dir"):
        benchrun.run_benches([bench_rogue], out_dir=str(out))
    # the well-routed artifact was still written before the guard fired
    assert (out / "BENCH_rogue.json").exists()

    # pre-existing strays don't trip the guard (only new ones do)
    def bench_clean():
        benchrun._row("clean/row", 1.0, "ok")

    paths = benchrun.run_benches([bench_clean], out_dir=str(out))
    assert len(paths) == 1
