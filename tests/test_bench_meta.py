"""Benchmark harness provenance: BENCH_*.json stamping + empty-list guard."""

import json
import os
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "benchmarks"))

import run as benchrun  # noqa: E402


def test_run_benches_refuses_empty_list(tmp_path):
    """A filtering bug upstream must fail loudly, not write no artifacts."""
    with pytest.raises(ValueError, match="empty bench list"):
        benchrun.run_benches([], out_dir=str(tmp_path))
    with pytest.raises(ValueError, match="empty bench list"):
        benchrun.run_benches(iter(()), out_dir=str(tmp_path))
    assert list(tmp_path.iterdir()) == []


def test_bench_json_carries_environment_stamp(tmp_path):
    """Every BENCH_<name>.json is stamped with git SHA, timestamp, jax
    version and device kind so the trajectory across PRs is comparable."""

    def bench_fake():
        benchrun._row("fake/row", 1.0, "derived=ok")

    paths = benchrun.run_benches([bench_fake], out_dir=str(tmp_path))
    assert len(paths) == 1
    with open(paths[0]) as f:
        data = json.load(f)
    assert data["bench"] == "bench_fake"
    assert data["rows"] and data["rows"][0]["name"] == "fake/row"
    meta = data["meta"]
    for field in ("git_sha", "timestamp", "jax_version", "device_kind",
                  "platform"):
        assert meta.get(field), field
    # a real git checkout resolves to a 40-hex SHA; degraded environments
    # record the sentinel rather than crashing the bench run
    assert meta["git_sha"] == "unknown" or len(meta["git_sha"]) == 40
    assert "T" in meta["timestamp"]  # ISO-8601


def test_bench_environment_is_self_contained():
    meta = benchrun.bench_environment()
    import jax

    assert meta["jax_version"] == jax.__version__
