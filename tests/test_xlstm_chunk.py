"""Chunkwise mLSTM must match the quadratic reference and the recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.xlstm import (XLSTMConfig, init_mlstm, init_mlstm_state,
                                mlstm, mlstm_decode, mlstm_quadratic_ref)


@pytest.mark.parametrize("S,chunk", [(16, 4), (32, 8), (64, 64), (128, 32)])
def test_mlstm_chunk_matches_quadratic(S, chunk):
    cfg = XLSTMConfig(d_model=32, n_heads=4)
    p = init_mlstm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, 32))
    want = mlstm_quadratic_ref(p, x, cfg)
    got = mlstm(p, x, cfg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunk_matches_decode_recurrence():
    cfg = XLSTMConfig(d_model=16, n_heads=2)
    p = init_mlstm(jax.random.PRNGKey(2), cfg)
    S = 12
    x = jax.random.normal(jax.random.PRNGKey(3), (1, S, 16))
    par = mlstm(p, x, cfg, chunk=4)
    state = init_mlstm_state(1, cfg)
    outs = []
    for t in range(S):
        y, state = mlstm_decode(p, x[:, t : t + 1], state, cfg)
        outs.append(y[:, 0])
    rec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(par), np.asarray(rec),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunk_grad_finite():
    cfg = XLSTMConfig(d_model=32, n_heads=4)
    p = init_mlstm(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, 32))
    g = jax.grad(lambda pp: mlstm(pp, x, cfg, chunk=16).sum())(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
