"""End-to-end dry-run deliverable path: run repro.launch.dryrun as a module
for one (arch x shape) on both production meshes (512 placeholder devices)
and validate the artifact schema the roofline reader consumes."""

import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_module_single_cell(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env.pop("XLA_FLAGS", None)  # dryrun.py sets its own 512-device flag
    out_dir = str(tmp_path / "dry")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
         "--shape", "decode_32k", "--mesh", "both", "--out", out_dir],
        capture_output=True, text=True, timeout=1200, env=env, cwd=_REPO,
    )
    assert res.returncode == 0, res.stdout + res.stderr
    assert "all cells OK" in res.stdout
    for mesh_kind, chips in (("single", 256), ("multi", 512)):
        path = os.path.join(out_dir, mesh_kind, "xlstm-125m",
                            "decode_32k.json")
        rec = json.load(open(path))
        assert rec["n_chips"] == chips
        r = rec["roofline"]
        for k in ("compute_s", "memory_s", "collective_s", "dominant",
                  "useful_flop_ratio", "model_flops_per_chip"):
            assert k in r
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        assert rec["memory"]["argument_bytes"] > 0
        assert rec["collectives"]["total_bytes"] >= 0


@pytest.mark.slow
def test_dryrun_skip_rule(tmp_path):
    """long_500k on a full-attention arch must be recorded as a skip."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env.pop("XLA_FLAGS", None)
    out_dir = str(tmp_path / "dry")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-1.5b",
         "--shape", "long_500k", "--mesh", "single", "--out", out_dir],
        capture_output=True, text=True, timeout=600, env=env, cwd=_REPO,
    )
    assert res.returncode == 0, res.stdout + res.stderr
    rec = json.load(open(os.path.join(out_dir, "single", "qwen2-1.5b",
                                      "long_500k.json")))
    assert rec["skipped"] and "full-attention" in rec["reason"]
