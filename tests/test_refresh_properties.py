"""Property tests for the streaming refresh ladder (paper §4 drift metric).

The ladder's correctness rests on three invariants that deserve more than
point examples, so these run property-style (hypothesis when installed,
the seeded fallback otherwise — see ``_hypothesis_compat``):

* ``refresh_decision`` is *monotone in drift*: piling more load onto the
  already-heaviest rank never lowers the measured imbalance ratio, and
  never demotes a ``reselect`` back to ``repartition``; loosening ``tol``
  never promotes one. Without this the ladder could flap.

* ``extend_scheme`` is an *extension*: every pre-existing element keeps
  its owner in every mode (device placement stays stable — the property
  the 0-new-uploads contract rides on) and each appended element joins
  exactly the rank its slice's owner map dictates.

* on a stream, ``reuse`` means what it says: a resubmit with no appends
  replays with 0 new compilations and 0 new uploads on the real executor
  (slow; random append/resubmit schedules).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.coo import SparseTensor
from repro.core.plan import extend_scheme, plan, refresh_decision
from repro.core.stochastic import (HOLDOUT_DOMAIN, RESERVOIR_DOMAIN,
                                   SAMPLE_DOMAIN, sample_batch, sample_unit)
from repro.streaming import StreamingTensor

CORE = (2, 2, 2)
SHAPE = (20, 16, 12)

# ladder position per decision: a drift increase may only move a decision
# *up* this order, never down (stochastic-refine demands the least drift)
LADDER = {"stochastic-refine": 0, "repartition": 1, "reselect": 2}


def _tiny_plan(seed=0, nnz=120, scheme="lite"):
    r = np.random.default_rng(seed)
    coords = np.stack([r.integers(0, L, nnz) for L in SHAPE], axis=1)
    t = SparseTensor(coords, r.standard_normal(nnz), SHAPE).dedup()
    return t, plan(t, scheme, 2, core_dims=CORE)


def _loads(rng, P, nmodes, lo=1, hi=200):
    return [rng.integers(lo, hi, size=P).astype(np.float64)
            for _ in range(nmodes)]


# ------------------------------------------------------ refresh_decision
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       extra=st.integers(min_value=1, max_value=500))
def test_drift_monotone_under_hotspot_growth(seed, extra):
    """Adding elements to the heaviest rank never lowers worst drift, and
    never turns a reselect back into a repartition."""
    _, pl = _tiny_plan()
    rng = np.random.default_rng(seed)
    P, nmodes = pl.P, pl.nmodes
    loads = _loads(rng, P, nmodes)
    baseline = [1.0 + rng.uniform(0.0, 0.5) for _ in range(nmodes)]
    tol = float(rng.uniform(0.05, 0.5))

    dec0, drift0 = refresh_decision(pl, loads, tol=tol, baseline=baseline)
    hot = [lv.copy() for lv in loads]
    for n in range(nmodes):
        hot[n][int(np.argmax(hot[n]))] += extra
    dec1, drift1 = refresh_decision(pl, hot, tol=tol, baseline=baseline)

    assert drift1["worst"] >= drift0["worst"] - 1e-12
    if dec0 == "reselect":
        assert dec1 == "reselect"


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_decision_monotone_in_tol(seed):
    """A scheme kept at a tight tolerance is kept at every looser one (and
    the drift report itself does not depend on tol)."""
    _, pl = _tiny_plan()
    rng = np.random.default_rng(seed)
    loads = _loads(rng, pl.P, pl.nmodes)
    baseline = [1.0] * pl.nmodes
    tols = sorted(float(x) for x in rng.uniform(0.01, 1.0, size=3))

    decisions, drifts = [], []
    for tol in tols:
        d, dr = refresh_decision(pl, loads, tol=tol, baseline=baseline)
        decisions.append(d)
        drifts.append(dr["worst"])
    assert len(set(drifts)) == 1  # drift is tol-independent
    # once loose enough to keep the scheme, looser never reselects
    for a, b in zip(decisions, decisions[1:]):
        if a == "repartition":
            assert b == "repartition"


def test_decision_threshold_exact():
    """The boundary is worst > 1 + tol, strictly."""
    _, pl = _tiny_plan()
    base = [1.0] * pl.nmodes
    # imbalance = max*P/total: [3,1] -> 1.5; tol 0.5 is the exact boundary
    loads = [np.array([3.0, 1.0])] * pl.nmodes
    dec_at, _ = refresh_decision(pl, loads, tol=0.5, baseline=base)
    dec_below, _ = refresh_decision(pl, loads, tol=0.49, baseline=base)
    assert dec_at == "repartition" and dec_below == "reselect"


# -------------------------------------------- four-rung ladder (sampling)
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       extra=st.integers(min_value=1, max_value=500))
def test_four_rung_ladder_monotone_in_drift(seed, extra):
    """With the stochastic rung offered, piling load onto the heaviest
    rank never moves the decision *down* the ladder
    (stochastic-refine -> repartition -> reselect)."""
    _, pl = _tiny_plan()
    rng = np.random.default_rng(seed)
    loads = _loads(rng, pl.P, pl.nmodes)
    baseline = [1.0 + rng.uniform(0.0, 0.5) for _ in range(pl.nmodes)]
    tol = float(rng.uniform(0.05, 0.5))
    # a cheap sampled pass, so the cost gate never masks the drift gate
    stoch = {"sampled_nnz": 1, "total_nnz": 10_000}

    dec0, drift0 = refresh_decision(pl, loads, tol=tol, baseline=baseline,
                                    stochastic=stoch)
    hot = [lv.copy() for lv in loads]
    for n in range(pl.nmodes):
        hot[n][int(np.argmax(hot[n]))] += extra
    dec1, drift1 = refresh_decision(pl, hot, tol=tol, baseline=baseline,
                                    stochastic=stoch)
    assert drift1["worst"] >= drift0["worst"] - 1e-12
    assert LADDER[dec1] >= LADDER[dec0]


def test_stochastic_rung_thresholds_exact():
    """stochastic-refine fires iff drift <= 1 + tol/2 (default stochastic
    tolerance) AND the modeled sampled pass undercuts the full sweep."""
    _, pl = _tiny_plan()
    base = [1.0] * pl.nmodes
    flat = [np.array([1.0, 1.0])] * pl.nmodes  # imbalance exactly 1.0
    cheap = {"sampled_nnz": 1, "total_nnz": 10_000}
    dec, drift = refresh_decision(pl, flat, tol=0.5, baseline=base,
                                  stochastic=cheap)
    assert dec == "stochastic-refine"
    assert drift["stochastic_s"] < drift["full_sweep_s"]
    # sampling the whole tensor can't beat a full sweep (overhead >= 1):
    # the cost gate alone demotes to repartition even at zero drift
    dec, drift = refresh_decision(
        pl, flat, tol=0.5, baseline=base,
        stochastic={"sampled_nnz": 10_000, "total_nnz": 10_000})
    assert dec == "repartition"
    assert drift["stochastic_s"] >= drift["full_sweep_s"]
    # drift beyond the stochastic tolerance but within tol: repartition
    # ([3,1] -> imbalance 1.5; tol=0.6 keeps the scheme, stoch tol 0.3
    # refuses sampling)
    skew = [np.array([3.0, 1.0])] * pl.nmodes
    dec, _ = refresh_decision(pl, skew, tol=0.6, baseline=base,
                              stochastic=cheap)
    assert dec == "repartition"
    # ... and an explicit stochastic tol admitting it flips the decision
    dec, _ = refresh_decision(pl, skew, tol=0.6, baseline=base,
                              stochastic=dict(cheap, tol=0.5))
    assert dec == "stochastic-refine"
    # no stochastic dict: the historical two-decision ladder, verbatim
    dec, _ = refresh_decision(pl, flat, tol=0.5, baseline=base)
    assert dec == "repartition"


# ------------------------------------------- sampled-index determinism
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       covered=st.integers(min_value=0, max_value=400),
       batch=st.integers(min_value=1, max_value=300))
def test_sampled_indices_bitwise_deterministic(seed, covered, batch):
    """Fixed seed + fixed append schedule => bitwise-identical sampled
    indices; appending more batches never reshuffles earlier decisions."""
    rng = np.random.default_rng(seed)
    nnz = covered + batch
    coords = np.stack([rng.integers(0, L, nnz) for L in SHAPE], axis=1)
    values = rng.standard_normal(nnz)
    sb1 = sample_batch(coords, values, covered, 0.5, seed, replay_nnz=64)
    sb2 = sample_batch(coords, values, covered, 0.5, seed, replay_nnz=64)
    np.testing.assert_array_equal(sb1.indices, sb2.indices)
    np.testing.assert_array_equal(sb1.coords, sb2.coords)
    np.testing.assert_array_equal(sb1.values, sb2.values)
    # replay first (prefix indices), then sampled new-batch indices
    assert (sb1.indices[:sb1.replay_nnz] < max(covered, 1)).all()
    assert (sb1.indices[sb1.replay_nnz:] >= covered).all()
    # append stability: the same covered prefix under a longer tensor
    # selects the same new-batch entries from the original window
    more = np.concatenate([values, rng.standard_normal(37)])
    morec = np.concatenate(
        [coords, np.stack([rng.integers(0, L, 37) for L in SHAPE], axis=1)])
    sb3 = sample_batch(morec, more, covered, 0.5, seed, replay_nnz=64)
    k = sb1.replay_nnz + sb1.sample_nnz
    np.testing.assert_array_equal(sb3.indices[:k], sb1.indices)


# ------------------------------- splitmix64 domain separation (bugfix)
def test_holdout_and_sampler_key_streams_are_domain_separated():
    """The completion holdout mask and the minibatch sampler share the
    splitmix64 primitive; their streams must not collide under equal
    seeds, or held-out entries would be preferentially resampled into
    training minibatches. Domain 0 is the historical holdout stream
    (bitwise); the sampler domains are disjoint from it and each other."""
    from repro.engine.objective import holdout_mask

    idx = np.arange(200_000, dtype=np.uint64)
    seed = 5
    held = holdout_mask(len(idx), 0.2, seed)
    # domain 0 reproduces the holdout stream bitwise — the collision the
    # domain constants exist to prevent
    collided = sample_unit(idx, seed, HOLDOUT_DOMAIN) < 0.2
    np.testing.assert_array_equal(collided, held)
    # the sampler's streams are independent of it: overlap ~= product of
    # the fractions (0.04), nowhere near the collided overlap (0.20)
    for domain in (SAMPLE_DOMAIN, RESERVOIR_DOMAIN):
        sampled = sample_unit(idx, seed, domain) < 0.2
        overlap = float(np.mean(held & sampled))
        assert abs(overlap - 0.04) < 0.01, (domain, overlap)
    assert not np.array_equal(sample_unit(idx, seed, SAMPLE_DOMAIN),
                              sample_unit(idx, seed, RESERVOIR_DOMAIN))


def test_completion_view_never_resamples_holdout_entries():
    """Masked completion + stochastic-refine compose: the sampler draws
    from the objective's training VIEW, whose element set is disjoint
    from the held-out coordinates by construction — so no minibatch can
    contain a held-out entry, at any (fraction, seed)."""
    from repro.engine.objective import CompletionObjective

    rng = np.random.default_rng(3)
    nnz = 4000
    coords = np.stack([rng.integers(0, L, nnz) for L in SHAPE], axis=1)
    t = SparseTensor(coords, rng.standard_normal(nnz), SHAPE).dedup()
    obj = CompletionObjective(holdout_fraction=0.25, holdout_seed=5)
    view = obj.prepare_tensor(t)
    held = {tuple(c) for c in np.asarray(view._holdout_coords)}
    for seed in (0, 5, 77):  # incl. seed == holdout_seed (the collision case)
        sb = sample_batch(np.asarray(view.coords), np.asarray(view.values),
                          view.nnz // 2, 0.7, seed, replay_nnz=256)
        n_real = sb.replay_nnz + sb.sample_nnz
        got = {tuple(c) for c in sb.coords[:n_real]}
        assert not (got & held), seed


# --------------------------------------------------------- extend_scheme
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       batch=st.integers(min_value=1, max_value=64))
def test_extend_scheme_preserves_existing_owners(seed, batch):
    from repro.core.plan import slice_owner_maps

    t, pl = _tiny_plan(seed=seed % 7)
    maps = slice_owner_maps(pl, t)
    rng = np.random.default_rng(seed)
    new_coords = np.stack([rng.integers(0, L, batch) for L in SHAPE], axis=1)

    ext = extend_scheme(pl.scheme, maps, new_coords)
    assert ext.P == pl.scheme.P and ext.uni is False
    for n in range(pl.nmodes):
        old = np.asarray(pl.scheme.policy(n))
        new = np.asarray(ext.policy(n))
        assert len(new) == len(old) + batch
        # extension, not reshuffle: pre-existing elements keep their owner
        np.testing.assert_array_equal(new[:len(old)], old)
        # appended elements land on their slice's owner, per mode
        np.testing.assert_array_equal(
            new[len(old):], np.asarray(maps[n])[new_coords[:, n]])


# ------------------------------------------------- reuse contract (slow)
@pytest.mark.slow
def test_reuse_means_no_jit_no_uploads_random_schedule():
    """Over a random append/resubmit schedule, every ``reuse`` run reports
    0 new compilations AND 0 new uploads (the serving tier's warm-path
    guarantee), while appends may pay — checked on the real executor."""
    from repro.distributed.executor import HooiExecutor
    from repro.engine.scheduler import StreamScheduler

    rng = np.random.default_rng(1234)
    ex = HooiExecutor(2)
    stream = StreamingTensor(SHAPE, name="prop")
    coords = np.stack([rng.integers(0, L, 150) for L in SHAPE], axis=1)
    stream.append(coords, rng.standard_normal(150))

    with StreamScheduler(ex, CORE, n_invocations=1, workers=2,
                         pad_geometric=True) as sched:
        sched.submit(stream, seed=0).result()
        for step in range(6):
            if rng.random() < 0.5:  # append a small batch
                b = int(rng.integers(5, 30))
                c = np.stack([rng.integers(0, L, b) for L in SHAPE], axis=1)
                stream.append(c, rng.standard_normal(b))
            r = sched.submit(stream, seed=step).result()
            if r.decision == "reuse":
                assert r.stats.step_compilations == 0, step
                assert r.stats.uploads == 0, step


def _appended_stream(rng, name, n0=150):
    stream = StreamingTensor(SHAPE, name=name)
    coords = np.stack([rng.integers(0, L, n0) for L in SHAPE], axis=1)
    stream.append(coords, rng.standard_normal(n0))
    return stream


@pytest.mark.slow
def test_stochastic_never_fires_on_unchanged_version():
    """A resubmit with no new appends must never take the sampled rung —
    there is no new batch to sample; it resolves to reuse or a full
    correction sweep, whatever the schedule did before it."""
    from repro.distributed.executor import HooiExecutor
    from repro.engine.scheduler import StreamScheduler

    rng = np.random.default_rng(7)
    stream = _appended_stream(rng, "noresample")
    fired = False
    with StreamScheduler(HooiExecutor(2), CORE, n_invocations=1, workers=2,
                         sample_fraction=0.5, replay_nnz=32,
                         stochastic_tol=0.25, correction_every=0) as sched:
        last_version = None
        for step in range(8):
            if step in (1, 3, 4):  # appends; the rest resubmit unchanged
                b = int(rng.integers(10, 30))
                c = np.stack([rng.integers(0, L, b) for L in SHAPE], axis=1)
                stream.append(c, rng.standard_normal(b))
            r = sched.submit(stream, seed=0).result()
            if r.stream_version == last_version:
                assert r.decision != "stochastic-refine", step
            fired = fired or r.decision == "stochastic-refine"
            last_version = r.stream_version
    assert fired  # the rung did engage on appends — the property is live


@pytest.mark.slow
def test_fixed_seed_schedule_reproduces_trajectory_bitwise():
    """Fixed sample seed + fixed append schedule => the two independent
    scheduler runs agree bitwise on decisions, sampled nnz, and the full
    fit trajectory of every submission."""
    from repro.distributed.executor import HooiExecutor
    from repro.engine.scheduler import StreamScheduler

    def run_schedule():
        rng = np.random.default_rng(42)
        stream = _appended_stream(rng, "traj")
        out = []
        with StreamScheduler(HooiExecutor(2), CORE, n_invocations=1,
                             workers=2, sample_fraction=0.5, sample_seed=9,
                             replay_nnz=32, stochastic_tol=0.25,
                             correction_every=3) as sched:
            sched.submit(stream, seed=0).result()
            for step in range(5):
                b = 20 + step
                c = np.stack([rng.integers(0, L, b) for L in SHAPE], axis=1)
                stream.append(c, rng.standard_normal(b))
                r = sched.submit(stream, seed=1 + step).result()
                out.append((r.decision, r.stats.sample_nnz,
                            tuple(float(f) for f in r.stats.fits)))
        return out

    a, b = run_schedule(), run_schedule()
    assert [x[0] for x in a] == [x[0] for x in b]
    assert "stochastic-refine" in [x[0] for x in a]
    for (da, sa, fa), (db, sb, fb) in zip(a, b):
        assert sa == sb, da
        assert fa == fb, da  # bitwise: exact float equality, no tolerance


@pytest.mark.slow
def test_fraction_one_with_correction_matches_full_sweep():
    """sample_fraction=1.0 (no new-batch subsampling — every appended
    entry enters the minibatch) plus a correction-sweep cadence lands
    within 5e-2 of the sampling-off trajectory's final fit on the same
    append schedule."""
    from repro.distributed.executor import HooiExecutor
    from repro.engine.scheduler import StreamScheduler

    def final_fit(fraction):
        rng = np.random.default_rng(11)
        stream = _appended_stream(rng, f"corr{fraction}", n0=300)
        kw = {}
        if fraction:
            kw = dict(sample_fraction=fraction, replay_nnz=64,
                      stochastic_tol=0.25, correction_every=2)
        with StreamScheduler(HooiExecutor(2), CORE, n_invocations=1,
                             workers=2, **kw) as sched:
            r = sched.submit(stream, seed=0).result()
            for step in range(4):
                c = np.stack([rng.integers(0, L, 25) for L in SHAPE], axis=1)
                stream.append(c, rng.standard_normal(25))
                r = sched.submit(stream, seed=1 + step).result()
        return float(r.stats.fits[-1])

    assert abs(final_fit(1.0) - final_fit(None)) <= 5e-2
