"""Property tests for the streaming refresh ladder (paper §4 drift metric).

The ladder's correctness rests on three invariants that deserve more than
point examples, so these run property-style (hypothesis when installed,
the seeded fallback otherwise — see ``_hypothesis_compat``):

* ``refresh_decision`` is *monotone in drift*: piling more load onto the
  already-heaviest rank never lowers the measured imbalance ratio, and
  never demotes a ``reselect`` back to ``repartition``; loosening ``tol``
  never promotes one. Without this the ladder could flap.

* ``extend_scheme`` is an *extension*: every pre-existing element keeps
  its owner in every mode (device placement stays stable — the property
  the 0-new-uploads contract rides on) and each appended element joins
  exactly the rank its slice's owner map dictates.

* on a stream, ``reuse`` means what it says: a resubmit with no appends
  replays with 0 new compilations and 0 new uploads on the real executor
  (slow; random append/resubmit schedules).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.coo import SparseTensor
from repro.core.plan import extend_scheme, plan, refresh_decision
from repro.streaming import StreamingTensor

CORE = (2, 2, 2)
SHAPE = (20, 16, 12)


def _tiny_plan(seed=0, nnz=120, scheme="lite"):
    r = np.random.default_rng(seed)
    coords = np.stack([r.integers(0, L, nnz) for L in SHAPE], axis=1)
    t = SparseTensor(coords, r.standard_normal(nnz), SHAPE).dedup()
    return t, plan(t, scheme, 2, core_dims=CORE)


def _loads(rng, P, nmodes, lo=1, hi=200):
    return [rng.integers(lo, hi, size=P).astype(np.float64)
            for _ in range(nmodes)]


# ------------------------------------------------------ refresh_decision
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       extra=st.integers(min_value=1, max_value=500))
def test_drift_monotone_under_hotspot_growth(seed, extra):
    """Adding elements to the heaviest rank never lowers worst drift, and
    never turns a reselect back into a repartition."""
    _, pl = _tiny_plan()
    rng = np.random.default_rng(seed)
    P, nmodes = pl.P, pl.nmodes
    loads = _loads(rng, P, nmodes)
    baseline = [1.0 + rng.uniform(0.0, 0.5) for _ in range(nmodes)]
    tol = float(rng.uniform(0.05, 0.5))

    dec0, drift0 = refresh_decision(pl, loads, tol=tol, baseline=baseline)
    hot = [lv.copy() for lv in loads]
    for n in range(nmodes):
        hot[n][int(np.argmax(hot[n]))] += extra
    dec1, drift1 = refresh_decision(pl, hot, tol=tol, baseline=baseline)

    assert drift1["worst"] >= drift0["worst"] - 1e-12
    if dec0 == "reselect":
        assert dec1 == "reselect"


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_decision_monotone_in_tol(seed):
    """A scheme kept at a tight tolerance is kept at every looser one (and
    the drift report itself does not depend on tol)."""
    _, pl = _tiny_plan()
    rng = np.random.default_rng(seed)
    loads = _loads(rng, pl.P, pl.nmodes)
    baseline = [1.0] * pl.nmodes
    tols = sorted(float(x) for x in rng.uniform(0.01, 1.0, size=3))

    decisions, drifts = [], []
    for tol in tols:
        d, dr = refresh_decision(pl, loads, tol=tol, baseline=baseline)
        decisions.append(d)
        drifts.append(dr["worst"])
    assert len(set(drifts)) == 1  # drift is tol-independent
    # once loose enough to keep the scheme, looser never reselects
    for a, b in zip(decisions, decisions[1:]):
        if a == "repartition":
            assert b == "repartition"


def test_decision_threshold_exact():
    """The boundary is worst > 1 + tol, strictly."""
    _, pl = _tiny_plan()
    base = [1.0] * pl.nmodes
    # imbalance = max*P/total: [3,1] -> 1.5; tol 0.5 is the exact boundary
    loads = [np.array([3.0, 1.0])] * pl.nmodes
    dec_at, _ = refresh_decision(pl, loads, tol=0.5, baseline=base)
    dec_below, _ = refresh_decision(pl, loads, tol=0.49, baseline=base)
    assert dec_at == "repartition" and dec_below == "reselect"


# --------------------------------------------------------- extend_scheme
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       batch=st.integers(min_value=1, max_value=64))
def test_extend_scheme_preserves_existing_owners(seed, batch):
    from repro.core.plan import slice_owner_maps

    t, pl = _tiny_plan(seed=seed % 7)
    maps = slice_owner_maps(pl, t)
    rng = np.random.default_rng(seed)
    new_coords = np.stack([rng.integers(0, L, batch) for L in SHAPE], axis=1)

    ext = extend_scheme(pl.scheme, maps, new_coords)
    assert ext.P == pl.scheme.P and ext.uni is False
    for n in range(pl.nmodes):
        old = np.asarray(pl.scheme.policy(n))
        new = np.asarray(ext.policy(n))
        assert len(new) == len(old) + batch
        # extension, not reshuffle: pre-existing elements keep their owner
        np.testing.assert_array_equal(new[:len(old)], old)
        # appended elements land on their slice's owner, per mode
        np.testing.assert_array_equal(
            new[len(old):], np.asarray(maps[n])[new_coords[:, n]])


# ------------------------------------------------- reuse contract (slow)
@pytest.mark.slow
def test_reuse_means_no_jit_no_uploads_random_schedule():
    """Over a random append/resubmit schedule, every ``reuse`` run reports
    0 new compilations AND 0 new uploads (the serving tier's warm-path
    guarantee), while appends may pay — checked on the real executor."""
    from repro.distributed.executor import HooiExecutor
    from repro.engine.scheduler import StreamScheduler

    rng = np.random.default_rng(1234)
    ex = HooiExecutor(2)
    stream = StreamingTensor(SHAPE, name="prop")
    coords = np.stack([rng.integers(0, L, 150) for L in SHAPE], axis=1)
    stream.append(coords, rng.standard_normal(150))

    with StreamScheduler(ex, CORE, n_invocations=1, workers=2,
                         pad_geometric=True) as sched:
        sched.submit(stream, seed=0).result()
        for step in range(6):
            if rng.random() < 0.5:  # append a small batch
                b = int(rng.integers(5, 30))
                c = np.stack([rng.integers(0, L, b) for L in SHAPE], axis=1)
                stream.append(c, rng.standard_normal(b))
            r = sched.submit(stream, seed=step).result()
            if r.decision == "reuse":
                assert r.stats.step_compilations == 0, step
                assert r.stats.uploads == 0, step
