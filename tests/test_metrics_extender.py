"""``MetricsExtender`` ≡ from-scratch ``scheme_metrics`` under appends.

The streaming scheduler's repartition ladder folds each appended batch
into the §4 metrics in O(batch) (`MetricsExtender.extend`) instead of
recomputing over the full tensor. These tests assert the incremental
result is *identical* — same tie-breaks, same integer arithmetic — to
``scheme_metrics`` on the extended tensor, field by field, across
multiple batches and schemes.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.coo import SparseTensor
from repro.core.distribution import build_scheme, row_owner_map
from repro.core.metrics import MetricsExtender, scheme_metrics
from repro.core.plan import extend_scheme

P = 8
CORE = (4, 3, 3)


def _coords(rng, shape, n):
    return np.stack([rng.integers(0, L, n) for L in shape], axis=1)


def _tensor(coords, shape):
    return SparseTensor(coords=coords,
                        values=np.ones(len(coords)), shape=shape)


def _assert_metrics_equal(inc, ref):
    assert dataclasses.asdict(inc) == dataclasses.asdict(ref)


@pytest.mark.parametrize("scheme_name", ["lite", "coarse", "medium"])
def test_extend_matches_recompute(scheme_name):
    rng = np.random.default_rng(7)
    shape = (30, 24, 20)
    prefix_coords = _coords(rng, shape, 500)
    prefix = _tensor(prefix_coords, shape)
    scheme = build_scheme(prefix, scheme_name, P)
    owner_maps = tuple(row_owner_map(prefix, scheme.policy(n), n, P)
                       for n in range(prefix.ndim))
    ext = MetricsExtender(prefix, scheme, CORE)

    all_coords = prefix_coords
    for batch_size in (1, 37, 200):
        new_coords = _coords(rng, shape, batch_size)
        scheme = extend_scheme(scheme, owner_maps, new_coords)
        m_inc = ext.extend(new_coords, scheme)
        all_coords = np.concatenate([all_coords, new_coords])
        m_ref = scheme_metrics(_tensor(all_coords, shape), scheme, CORE)
        _assert_metrics_equal(m_inc, m_ref)


def test_extend_with_duplicate_coords():
    """Streaming value-updates append duplicate coordinates; both the
    incremental and the from-scratch path count them as distinct elements."""
    rng = np.random.default_rng(3)
    shape = (16, 12, 10)
    prefix_coords = _coords(rng, shape, 300)
    prefix = _tensor(prefix_coords, shape)
    scheme = build_scheme(prefix, "medium", P)
    owner_maps = tuple(row_owner_map(prefix, scheme.policy(n), n, P)
                       for n in range(prefix.ndim))
    ext = MetricsExtender(prefix, scheme, CORE)

    # batch = half duplicates of existing coords, half fresh
    dup = prefix_coords[rng.integers(0, len(prefix_coords), 40)]
    fresh = _coords(rng, shape, 40)
    new_coords = np.concatenate([dup, fresh])
    scheme2 = extend_scheme(scheme, owner_maps, new_coords)
    m_inc = ext.extend(new_coords, scheme2)
    m_ref = scheme_metrics(
        _tensor(np.concatenate([prefix_coords, new_coords]), shape),
        scheme2, CORE)
    _assert_metrics_equal(m_inc, m_ref)


def test_extender_state_accumulates_across_batches():
    """metrics() after k extends equals a single recompute — the tracked
    nnz advances with each fold, so stale-scheme reuse cannot sneak by."""
    rng = np.random.default_rng(11)
    shape = (20, 20, 20)
    prefix_coords = _coords(rng, shape, 400)
    prefix = _tensor(prefix_coords, shape)
    scheme = build_scheme(prefix, "coarse", P)
    owner_maps = tuple(row_owner_map(prefix, scheme.policy(n), n, P)
                       for n in range(prefix.ndim))
    ext = MetricsExtender(prefix, scheme, CORE)
    assert ext.nnz == prefix.nnz

    total = prefix_coords
    for _ in range(3):
        batch = _coords(rng, shape, 60)
        scheme = extend_scheme(scheme, owner_maps, batch)
        ext.extend(batch, scheme)
        total = np.concatenate([total, batch])
    assert ext.nnz == len(total)
    _assert_metrics_equal(
        ext.metrics(), scheme_metrics(_tensor(total, shape), scheme, CORE))


def test_extend_rejects_non_extension_scheme():
    """Passing a scheme whose policies don't cover tracked + appended
    elements is a contract violation, not a silent miscount."""
    rng = np.random.default_rng(5)
    shape = (12, 10, 8)
    prefix = _tensor(_coords(rng, shape, 200), shape)
    scheme = build_scheme(prefix, "medium", P)
    ext = MetricsExtender(prefix, scheme, CORE)
    new_coords = _coords(rng, shape, 25)
    with pytest.raises(ValueError, match="not the extension"):
        ext.extend(new_coords, scheme)  # un-extended scheme: wrong length
