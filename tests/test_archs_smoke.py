"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness.

The FULL configs are only ever lowered via the dry-run (no allocation);
these reduced configs exercise the exact same code paths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer as tfm


def _batch_for(cfg, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {}
    n_tok = S
    if cfg.frontend in ("audio", "vision"):
        # modality stub: precomputed frame/patch embeddings (DESIGN.md)
        n_emb = 4
        batch["embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, n_emb, cfg.d_model), jnp.float32)
        n_tok = S - n_emb
    batch["tokens"] = jax.random.randint(
        jax.random.fold_in(key, 2), (B, n_tok), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(
        jax.random.fold_in(key, 3), (B, n_tok), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_exact_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.total_blocks() == cfg.n_layers, (
        f"{arch}: layout blocks {cfg.total_blocks()} != n_layers {cfg.n_layers}")
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()


def test_param_counts_plausible():
    """Analytic param counts within a loose factor of the published sizes."""
    expected = {
        "qwen3-moe-235b-a22b": 235e9,
        "grok-1-314b": 314e9,
        "zamba2-1.2b": 1.2e9,
        "granite-3-2b": 2.5e9,
        "qwen2-1.5b": 1.5e9,
        "stablelm-3b": 2.8e9,
        "chatglm3-6b": 6.2e9,
        "xlstm-125m": 125e6,
        "musicgen-medium": 1.5e9,
        "internvl2-1b": 0.6e9,  # LM backbone only (ViT stubbed)
    }
    for arch, target in expected.items():
        got = get_config(arch).param_count()
        assert 0.4 * target < got < 2.0 * target, (
            f"{arch}: {got/1e9:.2f}B vs expected ~{target/1e9:.2f}B")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = _batch_for(cfg)

    logits, aux = tfm.forward(params, cfg, tokens=batch["tokens"],
                              embeds=batch.get("embeds"))
    S_total = batch["tokens"].shape[1] + (
        batch["embeds"].shape[1] if "embeds" in batch else 0)
    assert logits.shape == (2, S_total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN/inf logits"

    loss, metrics = tfm.lm_loss(params, cfg, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"
    # one SGD step must change the loss and keep it finite
    grads = jax.grad(lambda p: tfm.lm_loss(p, cfg, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32)**2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"
    params2 = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = tfm.lm_loss(params2, cfg, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = tfm.init_params(cfg, key)
    B, S_max = 2, 32
    cache = tfm.init_cache(cfg, B, S_max, dtype=jnp.float32)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, cache = tfm.decode_step(params, cfg, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, cache = tfm.decode_step(params, cfg, cache, tok, jnp.int32(1))
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ["granite-3-2b", "zamba2-1.2b", "xlstm-125m"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the parallel forward logits."""
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(2)
    params = tfm.init_params(cfg, key)
    B, S = 1, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full_logits, _ = tfm.forward(params, cfg, tokens=toks)

    cache = tfm.init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = tfm.decode_step(params, cfg, cache, toks[:, t : t + 1],
                                    jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits),
        rtol=2e-2, atol=2e-2,
    )


def test_remat_forward_matches():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0, cfg.vocab)
    l1, _ = tfm.forward(params, cfg, tokens=toks, remat=False)
    l2, _ = tfm.forward(params, cfg, tokens=toks, remat=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)
