"""``hypothesis`` if installed, else a seeded-numpy stand-in.

Tier-1 must collect and run on a bare interpreter (numpy + jax + pytest).
When hypothesis is missing, ``given``/``settings``/``st`` degrade to a
deterministic sampler: each ``@given`` test runs ``max_examples`` times with
arguments drawn from a fixed-seed numpy Generator. That keeps the property
tests' *coverage style* (many random instances) without the shrinking or
example database — and the real hypothesis takes over automatically wherever
it is installed (e.g. CI).
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # seeded fallback
    import numpy as np

    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 20
    _SEED = 0xC0FFEE

    class _Integers:
        def __init__(self, min_value, max_value):
            self.lo = int(min_value)
            self.hi = int(max_value)

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

    st = _St()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # deliberately NOT functools.wraps: the wrapper must expose a
            # zero-arg signature or pytest resolves the drawn parameters as
            # fixtures
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = np.random.default_rng(_SEED)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
