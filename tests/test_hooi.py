"""HOOI / TTM / Lanczos correctness against dense oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coo import SparseTensor
from repro.core.hooi import (
    Decomposition,
    fit_score,
    hooi,
    hosvd_init,
    random_factors,
)
from repro.core.lanczos import svd_via_lanczos
from repro.core.ttm import (
    core_from_factors,
    dense_ttm,
    dense_ttm_chain,
    kron_contributions,
    penultimate,
    unfold,
)
from repro.data.tensors import synth_tensor


def _small_tensor(seed=0, shape=(7, 6, 5), frac=0.3):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal(shape) * (rng.random(shape) < frac)
    return SparseTensor.fromdense(dense), dense


# ------------------------------------------------------------------ TTM
@pytest.mark.parametrize("mode", [0, 1, 2])
def test_penultimate_matches_dense(mode):
    t, dense = _small_tensor()
    key = jax.random.PRNGKey(1)
    core_dims = (3, 3, 3)
    factors = random_factors(t.shape, core_dims, key)
    # dense: TTM-chain skipping `mode`, then unfold
    mats = {j: factors[j].T for j in range(3) if j != mode}
    Z_dense = unfold(dense_ttm_chain(jnp.asarray(dense, jnp.float32), mats), mode)
    Z_sparse = penultimate(
        jnp.asarray(t.coords, jnp.int32), jnp.asarray(t.values, jnp.float32),
        factors, mode, t.shape[mode],
    )
    np.testing.assert_allclose(Z_sparse, Z_dense, rtol=2e-4, atol=2e-4)


def test_penultimate_4d():
    rng = np.random.default_rng(3)
    shape = (5, 4, 3, 6)
    dense = rng.standard_normal(shape) * (rng.random(shape) < 0.4)
    t = SparseTensor.fromdense(dense)
    factors = random_factors(shape, (2, 2, 2, 2), jax.random.PRNGKey(0))
    for mode in range(4):
        mats = {j: factors[j].T for j in range(4) if j != mode}
        Z_dense = unfold(dense_ttm_chain(jnp.asarray(dense, jnp.float32), mats), mode)
        Z_sp = penultimate(jnp.asarray(t.coords, jnp.int32),
                           jnp.asarray(t.values, jnp.float32),
                           factors, mode, shape[mode])
        np.testing.assert_allclose(Z_sp, Z_dense, rtol=2e-4, atol=2e-4)


def test_ttm_chain_commutative():
    _, dense = _small_tensor(4)
    T = jnp.asarray(dense, jnp.float32)
    key = jax.random.PRNGKey(2)
    A = jax.random.normal(key, (2, 7))
    B = jax.random.normal(jax.random.fold_in(key, 1), (3, 6))
    ab = dense_ttm(dense_ttm(T, 0, A), 1, B)
    ba = dense_ttm(dense_ttm(T, 1, B), 0, A)
    np.testing.assert_allclose(ab, ba, rtol=1e-5, atol=1e-5)


def test_kron_contribution_order():
    """Single-element tensor: contribution must match dense unfold exactly."""
    shape = (3, 4, 5)
    coords = np.array([[1, 2, 3]])
    vals = np.array([2.0])
    t = SparseTensor(coords, vals, shape)
    factors = random_factors(shape, (2, 3, 2), jax.random.PRNGKey(5))
    dense = jnp.asarray(t.todense(), jnp.float32)
    for mode in range(3):
        mats = {j: factors[j].T for j in range(3) if j != mode}
        Z_dense = unfold(dense_ttm_chain(dense, mats), mode)
        c = kron_contributions(jnp.asarray(coords, jnp.int32),
                               jnp.asarray(vals, jnp.float32), factors, mode)
        np.testing.assert_allclose(Z_dense[coords[0, mode]], c[0],
                                   rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------ Lanczos
@pytest.mark.parametrize("shape,k", [((40, 12), 4), ((12, 40), 4), ((30, 30), 6)])
def test_lanczos_matches_svd(shape, k):
    key = jax.random.PRNGKey(7)
    # well-separated spectrum for stable comparison
    m, n = shape
    u, _ = jnp.linalg.qr(jax.random.normal(key, (m, m)))
    v, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (n, n)))
    s = jnp.concatenate([10.0 * 0.5 ** jnp.arange(k), 1e-3 * jnp.ones(min(m, n) - k)])
    Z = (u[:, : min(m, n)] * s) @ v[: min(m, n), :]
    res = svd_via_lanczos(Z, k, key=jax.random.fold_in(key, 2))
    np.testing.assert_allclose(res.singular_values, s[:k], rtol=1e-3)
    # subspace match: projector difference small
    u_true = u[:, :k]
    proj_err = jnp.linalg.norm(
        res.left_vectors @ res.left_vectors.T - u_true @ u_true.T
    )
    assert float(proj_err) < 1e-2
    # orthonormality
    eye = res.left_vectors.T @ res.left_vectors
    np.testing.assert_allclose(eye, np.eye(k), atol=1e-4)
    assert res.n_queries == 2 * min(2 * k, m, n)


def test_lanczos_rank_deficient():
    Z = jnp.zeros((10, 8))
    Z = Z.at[0, 0].set(3.0)
    res = svd_via_lanczos(Z, 4)
    eye = res.left_vectors.T @ res.left_vectors
    np.testing.assert_allclose(eye, np.eye(4), atol=1e-4)
    np.testing.assert_allclose(res.singular_values[0], 3.0, rtol=1e-4)


# ------------------------------------------------------------------ HOOI
def test_hooi_recovers_lowrank_tensor():
    """Exact low-rank tensor => HOOI reaches fit ~ 1."""
    key = jax.random.PRNGKey(11)
    core_dims = (3, 3, 3)
    shape = (15, 14, 13)
    factors = random_factors(shape, core_dims, key)
    g = jax.random.normal(jax.random.fold_in(key, 9), core_dims)
    dense = g
    for n in range(3):
        dense = dense_ttm(dense, n, factors[n])  # note: F (L,K): use F not F^T
    t = SparseTensor.fromdense(np.asarray(dense), tol=0.0)
    dec, fits = hooi(t, core_dims, n_invocations=6, seed=1)
    assert fits[-1] > 0.999, fits
    for n in range(3):
        eye = dec.factors[n].T @ dec.factors[n]
        np.testing.assert_allclose(eye, np.eye(core_dims[n]), atol=1e-3)


def test_hooi_monotone_fit_on_random_sparse():
    t = synth_tensor((20, 25, 30), 900, alphas=0.8, seed=5)
    dec, fits = hooi(t, (4, 4, 4), n_invocations=5, seed=2)
    assert fits[-1] >= fits[0] - 1e-3  # ALS-style refinement improves fit
    assert 0.0 <= fits[-1] <= 1.0


def test_hooi_hosvd_init_at_least_as_good_early():
    t = synth_tensor((15, 15, 15), 500, alphas=0.5, seed=6)
    _, fits_r = hooi(t, (3, 3, 3), n_invocations=2, init="random", seed=3)
    _, fits_h = hooi(t, (3, 3, 3), n_invocations=2, init="hosvd", seed=3)
    assert fits_h[0] >= fits_r[0] - 0.05  # HOSVD bootstrap no worse (slack)


def test_fit_score_identity():
    """fit via ||T||^2-||G||^2 identity == fit via explicit reconstruction."""
    t, dense = _small_tensor(8, shape=(6, 5, 4), frac=0.5)
    dec, _ = hooi(t, (3, 3, 3), n_invocations=4, seed=4)
    recon = dec.core
    for n in range(3):
        recon = dense_ttm(recon, n, dec.factors[n])
    err = float(jnp.linalg.norm(jnp.asarray(dense, jnp.float32) - recon))
    tnorm = float(np.linalg.norm(t.values))
    fit_explicit = 1.0 - err / tnorm
    np.testing.assert_allclose(fit_score(t, dec), fit_explicit, atol=5e-3)


def test_core_from_factors_matches_dense():
    t, dense = _small_tensor(9)
    factors = random_factors(t.shape, (3, 2, 4), jax.random.PRNGKey(3))
    g_sparse = core_from_factors(jnp.asarray(t.coords, jnp.int32),
                                 jnp.asarray(t.values, jnp.float32), factors)
    g_dense = dense_ttm_chain(jnp.asarray(dense, jnp.float32),
                              {n: factors[n].T for n in range(3)})
    np.testing.assert_allclose(g_sparse, g_dense, rtol=2e-4, atol=2e-4)
