"""Docs and repo-hygiene gates: links resolve, the import graph is clean.

Wired into the fast CI job (no ``slow`` marker) so documentation rot and
resurrected dead modules block merge:

  * every intra-repo markdown link and every backticked ``path/to/file``
    reference in README.md and docs/*.md points at a file that exists;
  * every module under src/repro imports (no dangling imports left behind
    by refactors);
  * the pruned LLM seed modules (configs/models/train/launch/checkpoint)
    stay deleted and unreferenced — they are unrelated to sparse Tucker.
"""

import glob
import importlib
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = sorted(
    [os.path.join(REPO, "README.md")]
    + glob.glob(os.path.join(REPO, "docs", "*.md"))
)

# [text](target) — target split off; external schemes and pure anchors are
# skipped below
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `some/path.ext` — only slash-containing backticked refs are checked, so
# prose like `BENCH_<name>.json` or bare module names stay out of scope
_CODE_REF = re.compile(
    r"`([A-Za-z0-9_\-.]+(?:/[A-Za-z0-9_\-.]+)+"
    r"\.(?:py|md|json|yml|yaml|toml|txt))`")


def _doc_targets(path):
    text = open(path, encoding="utf-8").read()
    for m in _MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#")[0]
    for m in _CODE_REF.finditer(text):
        ref = m.group(1)
        if any(ch in ref for ch in "<>*{}"):
            continue
        yield ref


def test_doc_files_exist():
    assert os.path.exists(os.path.join(REPO, "README.md")), \
        "README.md is the repo's front door — it must exist"
    assert len(DOC_FILES) >= 4


@pytest.mark.parametrize("doc", DOC_FILES,
                         ids=[os.path.relpath(d, REPO) for d in DOC_FILES])
def test_intra_repo_links_resolve(doc):
    missing = []
    for target in _doc_targets(doc):
        if not target:
            continue
        # docs may shorten source paths to be src/- or src/repro/-relative
        # (`core/hooi.py`, `repro/core/plan.py`); each shorthand must still
        # resolve to a real file
        roots = (os.path.dirname(doc), REPO, os.path.join(REPO, "src"),
                 os.path.join(REPO, "src", "repro"))
        cand = (os.path.normpath(os.path.join(r, target)) for r in roots)
        if not any(os.path.exists(c) for c in cand):
            missing.append(target)
    assert not missing, (
        f"{os.path.relpath(doc, REPO)} references files that do not exist: "
        f"{missing}")


# ------------------------------------------------------------ import graph
def _repro_modules():
    src = os.path.join(REPO, "src")
    for py in sorted(glob.glob(os.path.join(src, "repro", "**", "*.py"),
                               recursive=True)):
        rel = os.path.relpath(py, src)
        mod = rel[:-3].replace(os.sep, ".")
        if mod.endswith(".__init__"):
            mod = mod[: -len(".__init__")]
        yield mod


def test_every_repro_module_imports():
    failures = {}
    for mod in _repro_modules():
        try:
            importlib.import_module(mod)
        except Exception as e:  # noqa: BLE001 — report all, not just first
            failures[mod] = f"{type(e).__name__}: {e}"
    assert not failures, f"modules with dangling imports: {failures}"


PRUNED = ("configs", "models", "train", "launch", "checkpoint")


def test_pruned_seed_modules_stay_deleted():
    for name in PRUNED:
        path = os.path.join(REPO, "src", "repro", name)
        assert not os.path.exists(path), (
            f"src/repro/{name} was pruned (LLM seed scaffolding unrelated "
            "to sparse Tucker) — do not resurrect it")


def test_no_references_to_pruned_modules():
    pat = re.compile(r"\brepro\.(?:%s)\b" % "|".join(PRUNED))
    offenders = {}
    for root in ("src", "tests", "examples", "benchmarks"):
        for py in glob.glob(os.path.join(REPO, root, "**", "*.py"),
                            recursive=True):
            if os.path.basename(py) == os.path.basename(__file__):
                continue
            hits = pat.findall(open(py, encoding="utf-8").read())
            if hits:
                offenders[os.path.relpath(py, REPO)] = sorted(set(hits))
    assert not offenders, f"imports of pruned modules: {offenders}"
