"""COO container + FROSTT IO edge cases."""

import numpy as np
import pytest

from repro.core.coo import SparseTensor, read_tns, write_tns
from repro.data.tensors import paper_suite, synth_tensor


def test_tns_roundtrip(tmp_path):
    t = synth_tensor((10, 12, 8), 200, seed=0)
    p = str(tmp_path / "x.tns")
    write_tns(p, t)
    t2 = read_tns(p)
    # shape inferred from max coord; values/coords preserved
    assert t2.nnz == t.nnz
    key1 = np.ravel_multi_index(tuple(t.coords.T), t.shape)
    key2 = np.ravel_multi_index(tuple(t2.coords.T), t.shape)
    o1, o2 = np.argsort(key1), np.argsort(key2)
    np.testing.assert_array_equal(key1[o1], key2[o2])
    np.testing.assert_allclose(t.values[o1], t2.values[o2])


def test_dedup_sums_duplicates():
    coords = np.array([[0, 0], [0, 0], [1, 1]])
    t = SparseTensor(coords, np.array([1.0, 2.0, 5.0]), (2, 2))
    d = t.dedup()
    assert d.nnz == 2
    dense = d.todense()
    assert dense[0, 0] == 3.0 and dense[1, 1] == 5.0


def test_permute_mode_roundtrip():
    t = synth_tensor((6, 7, 8), 100, seed=1)
    perm = np.random.default_rng(0).permutation(6)
    inv = np.argsort(perm)
    t2 = t.permute_mode(0, perm).permute_mode(0, inv)
    np.testing.assert_array_equal(t2.coords, t.coords)


def test_bounds_validation():
    with pytest.raises(ValueError, match="out of bounds"):
        SparseTensor(np.array([[5, 0]]), np.array([1.0]), (3, 3))
    with pytest.raises(ValueError, match="non-negative"):
        SparseTensor(np.array([[-1, 0]]), np.array([1.0]), (3, 3))


def test_sorted_by_mode_and_slices():
    t = synth_tensor((5, 9, 4), 300, seed=2)
    s = t.sorted_by_mode(1)
    assert (np.diff(s.coords[:, 1]) >= 0).all()
    assert s.slice_sizes(1).sum() == t.nnz
    assert set(s.nonempty_slices(1)) == set(np.unique(t.coords[:, 1]))


def test_paper_suite_mirrors_shape_families():
    suite = paper_suite(scale=0.05)
    assert len(suite) == 8
    four_d = [n for n, t in suite.items() if t.ndim == 4]
    three_d = [n for n, t in suite.items() if t.ndim == 3]
    assert len(four_d) == 3 and len(three_d) == 5  # paper Fig 9 split
    # hub tensors have pathological slices (CoarseG's failure mode)
    enron = suite["enron-s"]
    assert enron.slice_sizes(0).max() > 10 * enron.nnz / enron.shape[0]
