"""Optimizer / data / checkpoint / grad-compression unit tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticStream
from repro.checkpoint import ckpt
from repro.train.grad_compress import (CompressConfig, compress_grads,
                                       init_error_state)
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   cosine_schedule, global_norm)
from repro.train.train_step import make_train_state, make_train_step


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}  # d/dw |w|^2
        params, state, m = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, abs=1e-3)


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    _, state, m = adamw_update(cfg, params, {"w": jnp.full(4, 100.0)}, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert float(global_norm(state.mu)) <= 0.11  # clipped to ~0.1*1


# --------------------------------------------------------------------- data
def test_stream_determinism_and_resume():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    s1 = SyntheticStream(cfg)
    b0, b1 = s1.next_batch(), s1.next_batch()
    s2 = SyntheticStream(cfg)
    s2.load_state_dict({"step": 1, "seed": 7})
    b1b = s2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"a": jnp.arange(6.0).reshape(2, 3),
                   "nested": {"b": jnp.ones((4,), jnp.int32)}},
        "meta": {"data_step": 42, "note": "x"},
    }
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 7, state)
    assert ckpt.latest_step(d) == 7
    tmpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        {"params": state["params"]})
    restored, step = ckpt.restore_checkpoint(d, tmpl)
    assert step == 7
    np.testing.assert_array_equal(restored["params"]["a"],
                                  np.asarray(state["params"]["a"]))
    assert restored["meta"]["data_step"] == 42


def test_checkpoint_atomicity_and_cleanup(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        ckpt.save_checkpoint(d, s, {"params": {"w": jnp.zeros(2)},
                                    "meta": {}})
    ckpt.cleanup_old(d, keep=2)
    assert ckpt.latest_step(d) == 4
    remaining = sorted(os.listdir(d))
    assert remaining == ["step_00000003", "step_00000004"]
    # a stale .tmp dir must never be picked up
    os.makedirs(os.path.join(d, "step_00000099.tmp"))
    assert ckpt.latest_step(d) == 4


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 1, {"params": {"w": jnp.zeros((2, 2))}, "meta": {}})
    bad = {"params": {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)}}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore_checkpoint(d, bad)


# --------------------------------------------------------- grad compression
def test_compress_error_feedback_preserves_signal():
    """Sum over steps of (compressed + error drift) tracks the true sum."""
    key = jax.random.PRNGKey(0)
    G = jax.random.normal(key, (64, 48))
    grads = {"w": G}
    err = init_error_state(grads)
    cfg = CompressConfig(rank=4, min_size=1)
    total = jnp.zeros_like(G)
    for i in range(30):
        out, err, stats = compress_grads(grads, err, cfg,
                                         jax.random.fold_in(key, i))
        total = total + out["w"]
    # with constant G, sum of compressed steps + final error == 30*G exactly
    np.testing.assert_allclose(np.asarray(total + err["w"]),
                               np.asarray(30.0 * G), rtol=1e-3, atol=1e-3)
    assert stats["compression_ratio"] < 0.2


def test_compress_small_tensors_passthrough():
    grads = {"b": jnp.ones((8,))}
    err = init_error_state(grads)
    out, err2, stats = compress_grads(grads, err, CompressConfig(rank=2),
                                      jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(out["b"]), 1.0)
    assert stats["compression_ratio"] == 1.0


# --------------------------------------------------------------- train step
def test_train_step_descends_and_microbatch_equivalence():
    cfg = get_config("qwen2-1.5b", smoke=True)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=1e9,
                      weight_decay=0.0)
    key = jax.random.PRNGKey(0)
    state = make_train_state(cfg, key)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
    }
    step1 = make_train_step(cfg, opt, microbatches=1, remat=False)
    step2 = make_train_step(cfg, opt, microbatches=2, remat=False)
    s1, m1 = step1(state, batch, key)
    s2, m2 = step2(state, batch, key)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    # gradient accumulation must produce (nearly) the same update
    d1 = jax.tree.leaves(s1.params)[0] - jax.tree.leaves(state.params)[0]
    d2 = jax.tree.leaves(s2.params)[0] - jax.tree.leaves(state.params)[0]
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=2e-2, atol=1e-6)
    # several steps reduce the loss on a fixed batch
    st = state
    losses = []
    for i in range(5):
        st, m = step1(st, batch, jax.random.fold_in(key, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
