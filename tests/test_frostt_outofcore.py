"""Out-of-core extents test for the FROSTT data layer (slow).

Generates a multi-hundred-MB synthetic ``.tns`` file and streams it
through ``iter_tns_batches``/``stream_tns`` inside a fresh subprocess
(no jax — ``repro.data.frostt`` imports stay numpy-only), sampling peak
RSS via ``resource.getrusage``. Two contracts:

* **bounded memory** — peak RSS stays under a ceiling proportional to the
  *binary* size of the accumulated arrays (~2.5x + a fixed interpreter
  margin). Holding the whole text file, or the whole file's parse lists,
  blows the ceiling by several GB; true batch streaming does not.
* **integrity** — the stream's final chain fingerprint equals the sha1
  chain recomputed directly from the source arrays at the same batch
  boundaries: the text write -> parse round trip (1-based coords,
  ``repr`` float values) is bitwise lossless and batching is file-ordered.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow

REPO = Path(__file__).resolve().parent.parent

# The subprocess: generate, fingerprint, stream, report. Kept jax-free so
# the RSS baseline is a bare numpy interpreter.
_SCRIPT = r"""
import hashlib, json, resource, sys
import numpy as np

sys.path.insert(0, "src")
from repro.data.frostt import stream_tns

SHAPE = (64, 64, 64)
BATCH = 750_000
NBATCH = 12  # 9M elements, ~250 MB of text
path = sys.argv[1]


def gen(b):
    rng = np.random.default_rng(1000 + b)
    coords = np.stack([rng.integers(0, L, BATCH) for L in SHAPE], axis=1)
    return np.ascontiguousarray(coords), rng.standard_normal(BATCH)


# write the file batch by batch (1-based coordinates; repr() of a float64
# round-trips bitwise through float()), never holding more than one batch
with open(path, "w") as f:
    f.write("# synthetic out-of-core extents tensor\n")
    for b in range(NBATCH):
        coords, values = gen(b)
        f.write("\n".join(
            f"{c0 + 1} {c1 + 1} {c2 + 1} {v!r}"
            for (c0, c1, c2), v in zip(coords.tolist(), values.tolist())))
        f.write("\n")

# the expected chain fingerprint, straight from the source arrays at the
# same batch boundaries iter_tns_batches will produce (BATCH-aligned, the
# comment line is skipped before batching)
h = hashlib.sha1()
h.update(b"stream:")
h.update(repr(SHAPE).encode())
fp = h.hexdigest()
for b in range(NBATCH):
    coords, values = gen(b)
    h = hashlib.sha1()
    h.update(fp.encode())
    h.update(coords.tobytes())
    h.update(values.tobytes())
    fp = h.hexdigest()

stream = stream_tns(path, batch_nnz=BATCH, shape=SHAPE, name="ooc")
maxrss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
print("JSON::" + json.dumps({
    "nnz": stream.nnz,
    "version": stream.version,
    "fingerprint": stream.fingerprint(),
    "expected": fp,
    "maxrss_bytes": maxrss_kb * 1024,
    "data_bytes": stream.nnz * (3 * 8 + 8),  # int64 coords + float64 value
    "file_bytes": __import__("os").path.getsize(path),
}))
"""


def test_stream_tns_multi_hundred_mb_bounded_memory(tmp_path):
    pytest.importorskip("resource")  # POSIX-only RSS accounting
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, str(tmp_path / "ooc.tns")],
        cwd=REPO, capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("JSON::")][-1]
    r = json.loads(line[len("JSON::"):])

    assert r["nnz"] == 12 * 750_000
    assert r["version"] == 12  # one stream version per file batch
    assert r["file_bytes"] > 200 * 2**20  # genuinely multi-hundred-MB text
    # integrity: text round trip + batching reproduced the binary chain
    assert r["fingerprint"] == r["expected"]
    # bounded peak memory: the accumulated arrays plus one batch of parse
    # transients plus a bare interpreter — nowhere near whole-file scale
    ceiling = 2.5 * r["data_bytes"] + 300 * 2**20
    assert r["maxrss_bytes"] < ceiling, (r["maxrss_bytes"], ceiling)
