"""Distributed HOOI integration tests.

These need multiple XLA devices; since device count is locked at first jax
init, they run in a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 (the main test process keeps seeing 1 device, per the
dry-run isolation rule).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(body: str, devices: int = 8, timeout: int = 900) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import numpy as np, jax
        assert len(jax.devices()) == {devices}
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


@pytest.mark.slow
def test_dist_hooi_matches_reference_all_paths():
    out = _run_in_subprocess("""
        from repro.data.tensors import synth_tensor
        from repro.core.hooi import hooi
        from repro.distributed.dist_hooi import dist_hooi

        t = synth_tensor((30, 40, 25), 3000, alphas=0.9, hub_fraction=0.2,
                         hub_modes=(0,), seed=0)
        core = (4, 4, 4)
        dec_ref, fits_ref = hooi(t, core, n_invocations=3, seed=0)
        for path in ("baseline", "liteopt"):
            for scheme in ("lite", "coarse", "medium"):
                dec, stats = dist_hooi(t, core, 8, scheme=scheme,
                                       n_invocations=3, path=path, seed=0)
                assert abs(stats.fits[-1] - fits_ref[-1]) < 0.03, (
                    path, scheme, stats.fits, fits_ref)
        print("DIST_OK")
    """)
    assert "DIST_OK" in out


@pytest.mark.slow
def test_liteopt_comm_advantage():
    """The analytic comm model must show liteopt << baseline for Lite
    (boundary rows <= ~P, Theorem 6.1.2) and the 4-D path must work."""
    out = _run_in_subprocess("""
        from repro.data.tensors import synth_tensor
        from repro.distributed.dist_hooi import dist_hooi

        # mode lengths >> K_hat so the row-space term dominates the model
        t = synth_tensor((300, 250, 200, 60), 6000, alphas=0.8, seed=1)
        dec, stats = dist_hooi(t, (3, 3, 3, 3), 8, scheme="lite",
                               n_invocations=2, path="liteopt", seed=0)
        assert 0.0 <= stats.fits[-1] <= 1.0
        for n, c in stats.comm.items():
            assert c["boundary_rows"] <= 3 * 8  # ~O(P) split rows
            # the advantage is in the row-space term; it only shows when
            # L >> K_hat (modes 0..2 here; mode 3 has L=60 ~ K_hat floor)
            if n < 3:
                assert c["liteopt_bytes"] < 0.25 * c["baseline_bytes"], (n, c)
        print("COMM_OK")
    """)
    assert "COMM_OK" in out


@pytest.mark.slow
def test_dist_hooi_single_device_mesh():
    """P=1 degenerate mesh must work in-process too (no fake devices)."""
    out = _run_in_subprocess("""
        from repro.data.tensors import synth_tensor
        from repro.distributed.dist_hooi import dist_hooi
        t = synth_tensor((20, 20, 20), 1500, seed=2)
        dec, stats = dist_hooi(t, (3, 3, 3), 1, scheme="lite",
                               n_invocations=2, path="liteopt")
        assert 0.0 <= stats.fits[-1] <= 1.0
        print("P1_OK")
    """, devices=1)
    assert "P1_OK" in out
