"""Kernel-backed distributed mode step: differential vs segment_sum,
step-key discrimination, empty/padding-heavy inputs, cache behavior.

The Pallas kron_segsum kernel runs in interpret mode here (CPU); the jnp
segment_sum reference path is the law. In-process multi-device tests rely on
conftest.py setting 8 simulated host devices before jax initializes.
"""

import numpy as np
import pytest

from repro.core.coo import SparseTensor
from repro.core.plan import plan


def _need_devices(n):
    import jax

    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} simulated devices (conftest sets XLA_FLAGS)")


@pytest.fixture
def executor():
    _need_devices(4)
    from repro.distributed.executor import HooiExecutor

    return HooiExecutor(4)


@pytest.fixture
def uneven_tensor():
    """Uneven mode lengths, nnz not divisible by P — every rank list gets
    padding elements, and mode steps see ragged R_pad/E_pad shapes."""
    r = np.random.default_rng(11)
    shape = (13, 7, 9)
    coords = np.stack([r.integers(0, L, 153) for L in shape], axis=1)
    return SparseTensor(coords, r.standard_normal(153), shape).dedup()


# ------------------------------------------------------------ differential
@pytest.mark.slow
@pytest.mark.parametrize("path", ["baseline", "liteopt"])
def test_kernel_matches_reference_lowrank(executor, lowrank_tensor, path):
    """On an exactly rank-(2,2,2) tensor both Z-build variants must converge
    to the same (near-1) fit and the same factor subspaces."""
    t = lowrank_tensor
    pl = plan(t, "lite", 4, core_dims=(2, 2, 2), path=path)
    dec_k, sk = executor.run(t, (2, 2, 2), pl, n_invocations=2, seed=0,
                             path=path, use_kernel=True)
    dec_r, sr = executor.run(t, (2, 2, 2), pl, n_invocations=2, seed=0,
                             path=path, use_kernel=False)
    assert all(sk.z_kernel.values()), sk.z_kernel
    assert not any(sr.z_kernel.values()), sr.z_kernel
    np.testing.assert_allclose(sk.fits, sr.fits, atol=1e-4)
    assert sk.fits[-1] > 0.99
    for n in range(t.ndim):  # same column space, sign/rotation-invariant
        Fk, Fr = np.asarray(dec_k.factors[n]), np.asarray(dec_r.factors[n])
        np.testing.assert_allclose(Fk @ Fk.T, Fr @ Fr.T, atol=1e-3)


@pytest.mark.slow
@pytest.mark.parametrize("path", ["baseline", "liteopt"])
@pytest.mark.parametrize("scheme", ["lite", "coarse"])
def test_kernel_matches_reference_uneven_padded(executor, uneven_tensor,
                                                path, scheme):
    """All modes, uneven shapes, padding elements present on most ranks."""
    t = uneven_tensor
    pl = plan(t, scheme, 4, core_dims=(2, 3, 2), path=path)
    # the partitions really contain padding elements (value-0 tail)
    assert any((mp.e_per_rank < mp.E_pad).any() for mp in pl.parts)
    _, sk = executor.run(t, (2, 3, 2), pl, n_invocations=2, seed=3,
                         path=path, use_kernel=True)
    _, sr = executor.run(t, (2, 3, 2), pl, n_invocations=2, seed=3,
                         path=path, use_kernel=False)
    assert all(sk.z_kernel.values())
    np.testing.assert_allclose(sk.fits, sr.fits, atol=1e-3)


@pytest.mark.slow
def test_kernel_path_with_nearly_empty_ranks(executor):
    """nnz < P: most ranks hold only padding elements — the kernel must
    produce the same decomposition as the reference on pure-padding blocks."""
    coords = np.array([[0, 0, 0], [4, 3, 2]])
    t = SparseTensor(coords, np.array([2.0, -3.0]), (5, 4, 3))
    _, sk = executor.run(t, (1, 1, 1), "lite", n_invocations=2, seed=0,
                         use_kernel=True)
    _, sr = executor.run(t, (1, 1, 1), "lite", n_invocations=2, seed=0,
                         use_kernel=False)
    assert all(sk.z_kernel.values())
    np.testing.assert_allclose(sk.fits, sr.fits, atol=1e-5)
    assert np.isfinite(sk.fits).all()


# --------------------------------------------------------------- step keys
def test_kernel_and_fallback_have_distinct_step_keys():
    """Kernel and reference variants of the same shapes must not share a
    compiled executable — the Z build is baked into the trace."""
    _need_devices(4)
    from repro.distributed.executor import HooiExecutor

    ex = HooiExecutor(4)

    class FakeMP:
        P = 4

        def __init__(self):
            self.mode, self.R_pad, self.Lp, self.S_pad = 0, 8, 3, 1

    mp = FakeMP()
    k_kern = ex._step_key(mp, "liteopt", 2, 4, use_kernel=True)
    k_ref = ex._step_key(mp, "liteopt", 2, 4, use_kernel=False)
    assert k_kern != k_ref
    ex._get_step(mp, "liteopt", 2, use_kernel=True)
    ex._get_step(mp, "liteopt", 2, use_kernel=False)
    assert k_kern in ex._steps and k_ref in ex._steps
    assert len(ex._steps) == 2


@pytest.mark.slow
def test_step_cache_holds_both_variants_after_runs(executor, lowrank_tensor):
    t = lowrank_tensor
    pl = plan(t, "lite", 4, core_dims=(2, 2, 2))
    _, s1 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                         use_kernel=True)
    assert s1.step_compilations == t.ndim
    _, s2 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                         use_kernel=False)
    # fallback variants are new executables, not cache hits of the kernel's
    assert s2.step_compilations == t.ndim
    assert s2.executor["cached_steps"] == 2 * t.ndim


# ------------------------------------------------------------ cache reuse
@pytest.mark.slow
def test_second_kernel_run_zero_compilations_zero_uploads(executor,
                                                          lowrank_tensor):
    """Acceptance: the cached-plan rerun guarantee holds on the kernel path
    too — 0 new compilations, 0 new uploads."""
    t = lowrank_tensor
    pl = plan(t, "lite", 4, core_dims=(2, 2, 2))
    _, s1 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                         use_kernel=True)
    assert s1.step_compilations == t.ndim
    assert s1.uploads == 9 * t.ndim + 2
    _, s2 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=1,
                         use_kernel=True)
    assert s2.step_compilations == 0
    assert s2.uploads == 0
    assert s2.upload_cache_hit
    assert all(s2.z_kernel.values())


def test_resolve_kernel_vmem_gate():
    """The static choice honors the VMEM gate and the force/pin modes."""
    _need_devices(4)
    import jax
    from repro.distributed.executor import HooiExecutor
    from repro.kernels import ops

    ex = HooiExecutor(4)

    class FakeMP:
        def __init__(self, R_pad):
            self.mode, self.R_pad = 0, R_pad

    small, huge = FakeMP(64), FakeMP(4_000_000)
    core = (4, 4, 4)
    assert ops.kernel_fits_vmem(64, 4, 4)
    assert not ops.kernel_fits_vmem(4_000_000, 4, 4)
    assert ex.resolve_kernel(small, core, True) is True
    assert ex.resolve_kernel(huge, core, True) is False  # gate wins
    assert ex.resolve_kernel(small, core, False) is False
    # None: auto — kernel only on a real TPU backend, unless the CI matrix
    # forces the interpret-mode kernel path via REPRO_FORCE_KERNEL=1
    from repro.engine.zbuild import kernel_forced_by_env

    expect = jax.default_backend() == "tpu" or kernel_forced_by_env()
    assert ex.resolve_kernel(small, core, None) is expect


# -------------------------------------------------------- phase profiling
@pytest.mark.slow
def test_profile_phases_feeds_per_phase_fit(executor, lowrank_tensor):
    """The zbuild probe + full sweeps give fit_cost_model a full-rank
    per-phase design; the fitted model carries separate TTM/SVD rates."""
    from repro.core.calibrate import fit_cost_model

    t = lowrank_tensor
    executor.run(t, (2, 2, 2), "lite", n_invocations=2, seed=0)
    prof = executor.profile_phases(t, (2, 2, 2), "lite", repeats=2)
    assert prof["ttm_s"] > 0 and prof["full_s"] >= prof["ttm_s"] > 0
    assert set(prof["per_mode"]) == {0, 1, 2}
    samples = executor.calibration_samples()
    assert any(s.get("phase") == "ttm" and s["svd_flops"] == 0
               for s in samples)
    cm = fit_cost_model(samples)
    assert cm.source.startswith("fitted")
    if cm.source.startswith("fitted-phases"):
        rt, rs = cm.phase_rates()
        assert rt > 0 and rs > 0


@pytest.mark.slow
def test_profile_phases_registers_compilations(executor, lowrank_tensor):
    """Regression: profile_phases compiles (and runs) the mode steps, so a
    subsequent run() on the same shapes must report 0 new compilations and
    record its first sweep as warm — the probe must register its shape
    signatures through the same ledger as run()."""
    t = lowrank_tensor
    executor.profile_phases(t, (2, 2, 2), "lite", repeats=1)
    _, s = executor.run(t, (2, 2, 2), "lite", n_invocations=1, seed=0)
    assert s.step_compilations == 0
    assert s.step_cache_hits == t.ndim
    assert executor.calibration_samples()[-1]["warm"] is True
