"""Objective-pluggable sweeps: completion, NN-ADMM, and the FROSTT layer.

The ``Objective`` seam (``repro.engine.objective``) decides what the shared
sweep loop optimizes: the tensor view, the post-oracle factor refinement,
the reported core, and the per-sweep scoring. These tests pin the contract:

* ``objective="tucker"`` (and the default) is bitwise the historical
  trajectory; ``CompletionObjective(holdout_fraction=0)`` reduces to it
  exactly.
* completion trains on a masked view, improves monotonically, and reports
  a held-out RMSE trajectory;
* NN-ADMM emits exactly nonnegative factors on every comm backend;
* plans, compiled steps, and uploads never alias across objectives, and
  reruns under one objective stay 0 jit / 0 uploads;
* the FROSTT ``.tns`` layer round-trips, streams in bounded batches, and
  rejects malformed files loudly.

In-process multi-device tests rely on conftest.py setting 8 simulated host
devices before jax initializes.
"""

import numpy as np
import pytest

from repro.core.coo import SparseTensor, write_tns
from repro.core.hooi import hooi
from repro.core.plan import PartitionPlan, plan
from repro.data.frostt import iter_tns_batches, load_tns, stream_tns
from repro.engine.objective import (
    CompletionObjective,
    NNTuckerObjective,
    Objective,
    TuckerObjective,
    holdout_mask,
    predict_at_coords,
    resolve_objective,
)

CORE = (3, 3, 3)


def _need_devices(n):
    import jax

    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} simulated devices (conftest sets XLA_FLAGS)")


def _nonneg_block_tensor(rng, shape=(16, 14, 12), rank=3, nnz=700):
    """Block-supported nonnegative low-rank data — the regime NN-ADMM is
    for (random sign-mixed data gives it nothing nonnegative to find)."""
    us = []
    for L in shape:
        f = np.zeros((L, rank))
        for j in range(rank):
            lo, hi = j * L // rank, (j + 1) * L // rank
            f[lo:hi, j] = np.abs(rng.standard_normal(hi - lo)) + 0.1
        us.append(f)
    g = np.abs(rng.standard_normal((rank,) * len(shape)))
    coords = np.unique(
        np.stack([rng.integers(0, L, 2 * nnz) for L in shape], axis=1),
        axis=0)[:nnz]
    vals = predict_at_coords(g, us, coords)
    return SparseTensor(coords, vals / max(vals.max(), 1e-12), shape)


# ------------------------------------------------------------ holdout mask
def test_holdout_mask_prefix_stable():
    """Appending entries never reshuffles the split of the covered prefix —
    the scheduler's repartition path depends on append-extended views."""
    base = holdout_mask(500, 0.2, 0)
    grown = holdout_mask(800, 0.2, 0)
    np.testing.assert_array_equal(grown[:500], base)


def test_holdout_mask_fraction_and_seed():
    m = holdout_mask(20_000, 0.2, 0)
    assert abs(m.mean() - 0.2) < 0.02
    assert not np.array_equal(m, holdout_mask(20_000, 0.2, 1))
    assert not holdout_mask(100, 0.0, 0).any()
    assert holdout_mask(100, 1.0, 0).all()
    assert holdout_mask(0, 0.5, 0).shape == (0,)


# ------------------------------------------------------------- resolution
def test_resolve_objective():
    assert resolve_objective("tucker").name == "tucker"
    assert resolve_objective(None).name == "tucker"
    obj = CompletionObjective(holdout_fraction=0.3)
    assert resolve_objective(obj) is obj
    with pytest.raises(ValueError, match="unknown objective"):
        resolve_objective("ridge")
    with pytest.raises(TypeError, match="Objective"):
        resolve_objective(42)


def test_cache_tokens_discriminate():
    tokens = {TuckerObjective().cache_token(),
              CompletionObjective().cache_token(),
              CompletionObjective(holdout_fraction=0.3).cache_token(),
              NNTuckerObjective().cache_token(),
              NNTuckerObjective(admm_iters=4).cache_token()}
    assert len(tokens) == 5


def test_completion_view_is_memoized(small_tensor):
    """Repeated prepare_tensor on one snapshot returns the *same* view
    object (plan/upload caches key on identity), and views re-enter
    unchanged — no double-masking through stacked layers."""
    obj = CompletionObjective()
    view = obj.prepare_tensor(small_tensor)
    assert view is obj.prepare_tensor(small_tensor)
    assert obj.prepare_tensor(view) is view
    held = holdout_mask(small_tensor.nnz, obj.holdout_fraction,
                        obj.holdout_seed)
    assert view.nnz == small_tensor.nnz - int(held.sum())
    np.testing.assert_array_equal(view._holdout_coords,
                                  small_tensor.coords[held])


# ------------------------------------------------- single-process contract
def test_default_objective_is_tucker_exactly(small_tensor):
    dec_d, fits_d = hooi(small_tensor, CORE, n_invocations=2, seed=0)
    dec_t, fits_t = hooi(small_tensor, CORE, n_invocations=2, seed=0,
                         objective="tucker")
    assert fits_d == fits_t
    np.testing.assert_array_equal(np.asarray(dec_d.core),
                                  np.asarray(dec_t.core))
    for a, b in zip(dec_d.factors, dec_t.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_completion_fraction_zero_reduces_to_tucker(small_tensor):
    """fraction=0 is the all-ones mask: identical view, identical fit call,
    identity refinement — the trajectory must be *exactly* tucker's."""
    _, fits_t = hooi(small_tensor, CORE, n_invocations=2, seed=0,
                     objective="tucker")
    _, fits_c = hooi(small_tensor, CORE, n_invocations=2, seed=0,
                     objective=CompletionObjective(holdout_fraction=0.0))
    assert fits_c == fits_t


def test_completion_fit_monotone_and_holdout_trajectory(small_tensor):
    out = {}
    _, fits = hooi(small_tensor, CORE, n_invocations=4, seed=0,
                   objective="completion", metrics_out=out)
    assert len(fits) == 4
    for a, b in zip(fits, fits[1:]):
        assert b >= a - 1e-6  # masked residual never worsens across sweeps
    assert len(out["holdout_rmse"]) == 4
    assert all(np.isfinite(r) for r in out["holdout_rmse"])


def test_nn_factors_nonneg_and_fit_positive(rng):
    """Exact nonnegativity is the hard contract (projection, not clipping
    noise); the residual-expansion fit must be finite and capture signal —
    ADMM's per-sweep trajectory is not monotone, so we don't assert that."""
    t = _nonneg_block_tensor(rng)
    dec, fits = hooi(t, CORE, n_invocations=3, seed=0, objective="nn")
    for f in dec.factors:
        assert float(np.asarray(f).min()) >= 0.0
    assert all(np.isfinite(f) for f in fits)
    assert max(fits) > 0.0


def test_admm_residual_balance_schedule():
    """Satellite contract for the Boyd §3.4.1 adaptive-rho schedule.

    The fixed-rho default stays bitwise the historical unrolled loop (its
    cache token unchanged); the balanced branch emits a valid nonneg
    factor and, started from a badly over-damped rho, lands measurably
    closer to the converged prox solution in the same iteration budget."""
    import jax
    import jax.numpy as jnp

    from repro.engine.objective import admm_nonneg_factor

    key = jax.random.PRNGKey(3)
    F, _ = jnp.linalg.qr(jax.random.normal(key, (60, 5), jnp.float32))
    S = jnp.asarray([8.0, 4.0, 2.0, 1.0, 0.5], jnp.float32)

    # fixed path == the historical inline iteration, bitwise
    M = F * S[None, :]
    W = jnp.maximum(M, 0.0)
    Y = jnp.zeros_like(M)
    for _ in range(8):
        X = (M + 1.0 * (W - Y)) / 2.0
        W = jnp.maximum(X + Y, 0.0)
        Y = Y + X - W
    legacy = W / jnp.maximum(jnp.sqrt(jnp.sum(W * W, 0)), 1e-6)[None, :]
    assert np.array_equal(np.asarray(admm_nonneg_factor(F, S)),
                          np.asarray(legacy))

    # over-damped regime: rho=100 barely moves X toward M in 8 iterations
    kw = dict(iters=8, rho=100.0, ridge=0.1)
    fixed = np.asarray(admm_nonneg_factor(F, S, **kw))
    bal = np.asarray(admm_nonneg_factor(F, S, residual_balance=True, **kw))
    assert np.all(bal >= 0.0) and np.all(np.isfinite(bal))
    assert not np.array_equal(bal, fixed)

    # closed-form prox solution: max(M, 0)/(1+ridge), column-normalized
    Wstar = jnp.maximum(M, 0.0) / 1.1
    ref = np.asarray(
        Wstar / jnp.maximum(jnp.sqrt(jnp.sum(Wstar**2, 0)), 1e-6)[None, :])
    assert np.linalg.norm(bal - ref) < np.linalg.norm(fixed - ref)

    # cache tokens: default unchanged; balanced variants discriminate
    assert NNTuckerObjective().cache_token() == ("nn", 8, 1.0, 0.0)
    rb = NNTuckerObjective(residual_balance=True)
    assert rb.cache_token() == ("nn", 8, 1.0, 0.0, "rb", 10.0, 2.0)
    assert rb.cache_token() != NNTuckerObjective().cache_token()


# ------------------------------------------------- distributed + backends
@pytest.mark.parametrize("P,path,backend", [
    (1, "liteopt", "local"),
    (4, "baseline", "psum"),
    (4, "liteopt", "boundary"),
])
def test_nn_nonneg_on_every_backend(rng, P, path, backend):
    """refine_factor runs after the comm backend's finalize and the
    row-perm restore, so the exact same ADMM update executes regardless
    of how oracle answers crossed the mesh."""
    _need_devices(P)
    from repro.distributed.dist_hooi import dist_hooi

    t = _nonneg_block_tensor(rng)
    dec, stats = dist_hooi(t, CORE, P, scheme="lite", path=path,
                           n_invocations=2, seed=0, objective="nn")
    assert stats.objective == "nn"
    assert set(stats.comm_backends.values()) == {backend}
    for f in dec.factors:
        assert float(np.asarray(f).min()) >= 0.0


def test_completion_p1_parity_and_stats(small_tensor):
    """P=1 structural parity holds per objective, and the executor stamps
    the objective name + extra per-sweep metrics on DistHooiStats.

    Parity is bitwise on the default path. When the CI leg resolves the
    warm start to ``sketch`` (``REPRO_WARM_START=sketch``), the executor's
    jitted step may fuse/reorder the sketch graph's float ops differently
    from the eager local path, so a float32-roundoff tolerance applies —
    the structural path is still identical (same seed, same panel, same
    budget)."""
    _need_devices(1)
    from repro.distributed.dist_hooi import dist_hooi
    from repro.engine.oracle import resolve_warm_start

    out = {}
    _, fits_sp = hooi(small_tensor, CORE, n_invocations=2, seed=0,
                      objective="completion", metrics_out=out)
    _, stats = dist_hooi(small_tensor, CORE, 1, scheme="lite",
                         n_invocations=2, seed=0, objective="completion")
    assert stats.objective == "completion"
    atol = 0 if resolve_warm_start(None) == "none" else 1e-6
    np.testing.assert_allclose(stats.fits, fits_sp, atol=atol)
    if atol == 0:
        assert stats.objective_metrics["holdout_rmse"] == out["holdout_rmse"]
    else:
        np.testing.assert_allclose(stats.objective_metrics["holdout_rmse"],
                                   out["holdout_rmse"], atol=1e-6)


def test_objective_rerun_contract_no_aliasing(lowrank_tensor):
    """Reruns under one objective stay 0 new jit / 0 new uploads; a
    different objective on the same executor compiles and uploads fresh
    (its name is in the step key, its plan keys the upload cache)."""
    _need_devices(4)
    from repro.distributed.executor import HooiExecutor

    t = lowrank_tensor
    ex = HooiExecutor(4)
    pl_c = plan(t, "lite", 4, core_dims=(2, 2, 2), objective="completion")
    _, s1 = ex.run(t, (2, 2, 2), pl_c, n_invocations=1, seed=0,
                   objective="completion")
    assert s1.objective == "completion"
    assert s1.step_compilations == t.ndim
    assert s1.uploads == 9 * t.ndim + 2
    _, s2 = ex.run(t, (2, 2, 2), pl_c, n_invocations=1, seed=1,
                   objective="completion")
    assert s2.step_compilations == 0
    assert s2.uploads == 0
    assert s2.upload_cache_hit
    assert s2.step_cache_hits == t.ndim

    pl_n = plan(t, "lite", 4, core_dims=(2, 2, 2), objective="nn")
    _, s3 = ex.run(t, (2, 2, 2), pl_n, n_invocations=1, seed=0,
                   objective="nn")
    assert s3.objective == "nn"
    assert s3.step_compilations == t.ndim  # no cross-objective aliasing
    assert s3.uploads > 0


def test_plan_cache_keys_on_objective(small_tensor):
    pl_t = plan(small_tensor, "lite", 2, core_dims=CORE)
    pl_c = plan(small_tensor, "lite", 2, core_dims=CORE,
                objective="completion")
    assert pl_t is not pl_c
    assert pl_t.objective == "tucker" and pl_c.objective == "completion"
    assert plan(small_tensor, "lite", 2, core_dims=CORE,
                objective="completion") is pl_c


def test_executor_refuses_objective_mismatched_plan(small_tensor):
    _need_devices(1)
    from repro.distributed.executor import HooiExecutor

    pl = plan(small_tensor, "lite", 1, core_dims=CORE)
    with pytest.raises(ValueError, match="objective"):
        HooiExecutor(1).run(small_tensor, CORE, pl, n_invocations=1,
                            objective="nn")


def test_plan_file_objective_mismatch_refused(small_tensor, tmp_path):
    pl = plan(small_tensor, "lite", 2, core_dims=CORE,
              objective="completion")
    f = str(tmp_path / "plan.npz")
    pl.save(f)
    loaded = PartitionPlan.load(f, small_tensor, objective="completion")
    assert loaded.objective == "completion"
    with pytest.raises(ValueError, match="refusing"):
        PartitionPlan.load(f, small_tensor, objective="tucker")


# ------------------------------------------------------------ FROSTT layer
def test_tns_round_trip_exact(small_tensor, tmp_path):
    path = str(tmp_path / "t.tns")
    write_tns(path, small_tensor)
    back = load_tns(path, shape=small_tensor.shape)
    assert back.shape == small_tensor.shape
    np.testing.assert_array_equal(back.coords, small_tensor.coords)
    np.testing.assert_array_equal(back.values, small_tensor.values)


def test_tns_shape_inference_and_pinning(tmp_path):
    path = str(tmp_path / "t.tns")
    with open(path, "w") as f:
        f.write("# a comment line\n")
        f.write("% another comment style\n\n")
        f.write("1 1 1 2.0\n")
        f.write("3 2 4 -1.5\n")
    t = load_tns(path)
    assert t.shape == (3, 2, 4)  # inferred: per-mode max coordinate
    pinned = load_tns(path, shape=(5, 6, 7))  # trailing slices empty
    assert pinned.shape == (5, 6, 7)
    np.testing.assert_array_equal(pinned.coords, t.coords)


def test_iter_tns_batches_bounded_and_ordered(small_tensor, tmp_path):
    path = str(tmp_path / "t.tns")
    write_tns(path, small_tensor)
    batches = list(iter_tns_batches(path, batch_nnz=150))
    sizes = [len(c) for c, _ in batches]
    assert all(s <= 150 for s in sizes)
    assert sizes[:-1] == [150] * (len(sizes) - 1)  # full until the tail
    coords = np.concatenate([c for c, _ in batches])
    values = np.concatenate([v for _, v in batches])
    np.testing.assert_array_equal(coords, small_tensor.coords)
    np.testing.assert_array_equal(values, small_tensor.values)


def test_stream_tns_versions_and_snapshot(small_tensor, tmp_path):
    path = str(tmp_path / "t.tns")
    write_tns(path, small_tensor)
    stream = stream_tns(path, batch_nnz=150, shape=small_tensor.shape,
                        name="fixture")
    n_batches = -(-small_tensor.nnz // 150)
    assert stream.version == n_batches
    snap = stream.snapshot()
    assert snap.shape == small_tensor.shape
    np.testing.assert_array_equal(snap.coords, small_tensor.coords)
    np.testing.assert_array_equal(snap.values, small_tensor.values)


def test_tns_malformed_inputs(tmp_path):
    zero_based = str(tmp_path / "zero.tns")
    with open(zero_based, "w") as f:
        f.write("0 1 1 3.0\n")
    with pytest.raises(ValueError, match="1-based"):
        load_tns(zero_based)

    ragged = str(tmp_path / "ragged.tns")
    with open(ragged, "w") as f:
        f.write("1 1 1 3.0\n2 2 0.5\n")
    with pytest.raises(ValueError, match="inconsistent"):
        load_tns(ragged)

    empty = str(tmp_path / "empty.tns")
    with open(empty, "w") as f:
        f.write("# only a comment\n")
    with pytest.raises(ValueError, match="no elements"):
        load_tns(empty)
    with pytest.raises(ValueError, match="no elements"):
        stream_tns(empty)

    ok = str(tmp_path / "ok.tns")
    with open(ok, "w") as f:
        f.write("1 1 1 3.0\n")
    with pytest.raises(ValueError, match="batch_nnz"):
        list(iter_tns_batches(ok, batch_nnz=0))
    with pytest.raises(ValueError, match="modes"):
        load_tns(ok, shape=(4, 4))
