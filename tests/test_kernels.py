"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels execute in interpret mode on CPU (the TPU is the target, the
oracle is the law).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.kron_segsum import kron_segsum
from repro.kernels.oracle_fused import oracle_pair as oracle_kernel
from repro.core.hooi import random_factors
from repro.core import ttm


def _mk(seed, E, Ka, Kb, R, dense=True):
    rng = np.random.default_rng(seed)
    rows = np.sort(rng.integers(0, R, size=E))
    if dense:  # dense renumbering as the wrapper provides
        _, rows = np.unique(rows, return_inverse=True)
        rows = np.sort(rows)
        R = max(int(rows.max()) + 1 if E else 1, 1)
    a = rng.standard_normal((E, Ka)).astype(np.float32)
    b = rng.standard_normal((E, Kb)).astype(np.float32)
    return (jnp.asarray(rows, jnp.int32), jnp.asarray(a), jnp.asarray(b), R)


# -------------------------------------------------------------- kron_segsum
@pytest.mark.parametrize(
    "E,Ka,Kb,R",
    [
        (1, 1, 1, 1),          # degenerate
        (7, 3, 5, 4),          # tiny, unaligned everything
        (256, 8, 16, 40),      # one exact element block
        (300, 4, 130, 50),     # Kb > 128 -> multiple kb blocks
        (1000, 10, 10, 300),   # paper-like: K=10 3-D (K_hat=100)
        (515, 2, 257, 1),      # all elements in one row
        (64, 5, 7, 64),        # one element per row
    ],
)
def test_kron_segsum_matches_ref(E, Ka, Kb, R):
    rows, a, b, R = _mk(0, E, Ka, Kb, R)
    want = ref.kron_segsum_ref(rows, a, b, R)
    got = kron_segsum(rows, a, b, R, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_e", [128, 256, 512])
def test_kron_segsum_block_sweep(block_e):
    rows, a, b, R = _mk(1, 700, 6, 20, 120)
    want = ref.kron_segsum_ref(rows, a, b, R)
    got = kron_segsum(rows, a, b, R, block_e=block_e, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 1000),
    E=st.integers(1, 400),
    Ka=st.integers(1, 12),
    Kb=st.integers(1, 40),
    R=st.integers(1, 200),
)
def test_kron_segsum_property(seed, E, Ka, Kb, R):
    rows, a, b, R = _mk(seed, E, Ka, Kb, R)
    want = ref.kron_segsum_ref(rows, a, b, R)
    got = kron_segsum(rows, a, b, R, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_kron_segsum_empty_input():
    """Regression: E == 0 used to launch an empty grid — the @pl.when zero
    init never ran (uninitialized output) and the padding logic indexed
    rows[-1] on an empty array. The sum over no elements is zeros."""
    rows = jnp.zeros((0,), jnp.int32)
    a = jnp.zeros((0, 3), jnp.float32)
    b = jnp.zeros((0, 5), jnp.float32)
    z = kron_segsum(rows, a, b, 4, interpret=True)
    assert z.shape == (4, 15)
    np.testing.assert_array_equal(np.asarray(z), np.zeros((4, 15)))


def test_kron_segsum_empty_matches_ref():
    rows, a, b, R = _mk(0, 0, 2, 7, 6)
    want = ref.kron_segsum_ref(rows, a, b, 6)
    got = kron_segsum(rows, a, b, 6, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kron_segsum_skewed_rows():
    """Heavy-hub row distribution (one giant slice) — the paper's regime."""
    rng = np.random.default_rng(3)
    E, R = 2000, 64
    rows = np.where(rng.random(E) < 0.6, 7, rng.integers(0, R, E))
    rows = np.sort(rows).astype(np.int32)
    a = rng.standard_normal((E, 4)).astype(np.float32)
    b = rng.standard_normal((E, 25)).astype(np.float32)
    want = ref.kron_segsum_ref(jnp.asarray(rows), jnp.asarray(a), jnp.asarray(b), R)
    got = kron_segsum(jnp.asarray(rows), jnp.asarray(a), jnp.asarray(b), R,
                      interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- oracle_pair
@pytest.mark.parametrize(
    "R,K", [(1, 1), (5, 3), (128, 128), (300, 100), (1000, 400), (40, 513)]
)
def test_oracle_pair_matches_ref(R, K):
    rng = np.random.default_rng(5)
    Z = jnp.asarray(rng.standard_normal((R, K)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(K), jnp.float32)
    y = jnp.asarray(rng.standard_normal(R), jnp.float32)
    want_x, want_y = ref.oracle_pair_ref(Z, x, y)
    got_x, got_y = oracle_kernel(Z, x, y, interpret=True)
    np.testing.assert_allclose(got_x, want_x, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got_y, want_y, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999), R=st.integers(1, 300), K=st.integers(1, 300))
def test_oracle_pair_property(seed, R, K):
    rng = np.random.default_rng(seed)
    Z = jnp.asarray(rng.standard_normal((R, K)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(K), jnp.float32)
    y = jnp.asarray(rng.standard_normal(R), jnp.float32)
    want_x, want_y = ref.oracle_pair_ref(Z, x, y)
    got_x, got_y = oracle_kernel(Z, x, y, interpret=True)
    np.testing.assert_allclose(got_x, want_x, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(got_y, want_y, rtol=5e-4, atol=5e-4)


# ------------------------------------------------- wrapper = core.ttm oracle
@pytest.mark.parametrize("N,mode", [(3, 0), (3, 2), (4, 1), (4, 3)])
def test_ops_penultimate_matches_core(N, mode):
    rng = np.random.default_rng(7)
    shape = tuple(rng.integers(5, 12, N))
    nnz = 150
    coords = jnp.asarray(
        np.stack([rng.integers(0, L, nnz) for L in shape], 1), jnp.int32)
    values = jnp.asarray(rng.standard_normal(nnz), jnp.float32)
    factors = random_factors(shape, tuple([3] * N), jax.random.PRNGKey(0))
    want = ttm.penultimate(coords, values, factors, mode, shape[mode])
    got = ops.penultimate(coords, values, factors, mode, shape[mode],
                          interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_ops_vmem_fallback():
    """Shapes over the VMEM budget must silently use the reference path."""
    assert not ops.kernel_fits_vmem(num_rows=200_000, Ka=64, Kb=512)
    rng = np.random.default_rng(8)
    coords = jnp.asarray(np.stack([rng.integers(0, 30, 50)] * 3, 1), jnp.int32)
    values = jnp.asarray(rng.standard_normal(50), jnp.float32)
    factors = random_factors((30, 30, 30), (3, 3, 3), jax.random.PRNGKey(1))
    got = ops.penultimate(coords, values, factors, 0, 30, use_kernel=False)
    want = ttm.penultimate(coords, values, factors, 0, 30)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ops_penultimate_empty_tensor():
    """nnz == 0 through the kernel wrapper: all-zero Z, correct shape."""
    factors = random_factors((6, 5, 4), (2, 2, 2), jax.random.PRNGKey(0))
    coords = jnp.zeros((0, 3), jnp.int32)
    values = jnp.zeros((0,), jnp.float32)
    got = ops.penultimate(coords, values, factors, 0, 6, interpret=True)
    assert got.shape == (6, 4)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((6, 4)))


@pytest.mark.parametrize("N,mode", [(3, 0), (3, 2), (4, 1)])
def test_ops_penultimate_sorted_matches_core(N, mode):
    """The sorted fast path (partition.py contract: rows pre-sorted, dense)
    must equal the core oracle without any runtime argsort."""
    rng = np.random.default_rng(9)
    shape = tuple(rng.integers(5, 12, N))
    nnz = 150
    coords = np.stack([rng.integers(0, L, nnz) for L in shape], 1)
    order = np.argsort(coords[:, mode], kind="stable")
    coords = coords[order]
    # dense-renumber the mode column like the partition layer does
    uniq, local = np.unique(coords[:, mode], return_inverse=True)
    R = len(uniq)
    values = rng.standard_normal(nnz).astype(np.float32)
    factors = random_factors(shape, tuple([3] * N), jax.random.PRNGKey(0))
    want = ttm.penultimate_local(
        jnp.asarray(coords, jnp.int32), jnp.asarray(values),
        jnp.asarray(local, jnp.int32), factors, mode, R)
    got = ops.penultimate_sorted(
        jnp.asarray(coords, jnp.int32), jnp.asarray(values),
        jnp.asarray(local, jnp.int32), factors, mode, R, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_tile_geometry_single_source_of_truth():
    """The VMEM gate must derive from the same helper the kernel uses."""
    from repro.kernels.kron_segsum import tile_geometry

    for num_rows, Ka, Kb in [(64, 4, 4), (1000, 10, 10), (50_000, 16, 256)]:
        g = tile_geometry(num_rows, Ka, Kb)
        assert ops.kernel_fits_vmem(num_rows, Ka, Kb) == \
            (g.vmem_bytes <= ops._VMEM_BUDGET)
        assert g.R_pad >= num_rows
        assert g.Kb_pad % g.kb_blk == 0


def test_split_kron_dims_matches_split_ab():
    rng = np.random.default_rng(4)
    shape = (9, 8, 7, 6)
    core = (2, 3, 4, 5)  # K_n <= L_n so factor widths equal core dims
    nnz = 40
    coords = jnp.asarray(
        np.stack([rng.integers(0, L, nnz) for L in shape], 1), jnp.int32)
    values = jnp.asarray(rng.standard_normal(nnz), jnp.float32)
    factors = random_factors(shape, core, jax.random.PRNGKey(2))
    for mode in range(4):
        a, b = ops._split_ab(coords, values, factors, mode)
        Ka, Kb = ops.split_kron_dims(core, mode)
        assert (a.shape[1], b.shape[1]) == (Ka, Kb)


# ------------------------------------------- oracle_pair panel operands
@pytest.mark.parametrize(
    "R,K,s",
    [
        (5, 3, 4),       # K_hat not a multiple of 128; panel wider than K
        (300, 513, 8),   # multiple K blocks with a ragged tail
        (40, 128, 16),   # exact single K block
        (128, 100, 1),   # single-row-block Z, width-1 panel
        (1, 1, 4),       # degenerate Z, panel wider than both dims
    ],
)
def test_oracle_pair_panel_edge_geometry(R, K, s):
    """Panel operands (block Lanczos) on edge geometries: tail masking must
    not leak padded rows/columns into either product."""
    rng = np.random.default_rng(11)
    Z = jnp.asarray(rng.standard_normal((R, K)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((K, s)), jnp.float32)
    Y = jnp.asarray(rng.standard_normal((R, s)), jnp.float32)
    got_x, got_y = oracle_kernel(Z, X, Y, interpret=True)
    assert got_x.shape == (R, s) and got_y.shape == (K, s)
    np.testing.assert_allclose(got_x, Z @ X, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got_y, Z.T @ Y, rtol=2e-4, atol=2e-4)


def test_oracle_pair_vector_panel_consistent():
    """A width-1 panel must reproduce the vector call column for column."""
    rng = np.random.default_rng(12)
    Z = jnp.asarray(rng.standard_normal((60, 37)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(37), jnp.float32)
    y = jnp.asarray(rng.standard_normal(60), jnp.float32)
    vx, vy = oracle_kernel(Z, x, y, interpret=True)
    px, py = oracle_kernel(Z, x[:, None], y[:, None], interpret=True)
    np.testing.assert_array_equal(np.asarray(vx), np.asarray(px[:, 0]))
    np.testing.assert_array_equal(np.asarray(vy), np.asarray(py[:, 0]))


# ------------------------------------------------- fused Z-build -> oracle
@pytest.mark.parametrize(
    "E,Ka,Kb,R,s",
    [
        (7, 3, 5, 4, 4),
        (300, 4, 130, 50, 8),    # Kb > 128 -> multiple kb blocks
        (515, 2, 257, 1, 3),     # single-row Z
        (64, 5, 7, 64, 1),       # width-1 panel
    ],
)
def test_kron_segsum_oracle_matches_ref(E, Ka, Kb, R, s):
    """The fused kernel must produce the same Z as the unfused kernel AND
    the first oracle product Z @ X of that very Z."""
    from repro.kernels.kron_segsum import kron_segsum_oracle

    rows, a, b, R = _mk(13, E, Ka, Kb, R)
    X = jnp.asarray(
        np.random.default_rng(14).standard_normal((Ka * Kb, s)), jnp.float32)
    want_z, want_zx = ref.kron_segsum_oracle_ref(rows, a, b, R, X)
    got_z, got_zx = kron_segsum_oracle(rows, a, b, R, X, interpret=True)
    np.testing.assert_allclose(got_z, want_z, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_zx, want_zx, rtol=2e-4, atol=2e-4)
    # the Z the fused call produces is the unfused kernel's Z exactly
    plain = kron_segsum(rows, a, b, R, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_z), np.asarray(plain))


def test_kron_segsum_bf16_contract():
    """bf16 precision: kernel and reference round operands identically
    (bit-identical Z) and stay within the documented bound of f32."""
    rows, a, b, R = _mk(15, 200, 6, 9, 30)
    got = kron_segsum(rows, a, b, R, interpret=True, precision="bf16")
    want = ref.kron_segsum_ref(rows, a, b, R, precision="bf16")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.float32  # f32 accumulation is part of the contract
    f32 = ref.kron_segsum_ref(rows, a, b, R)
    scale = np.abs(np.asarray(f32)).max()
    assert np.abs(np.asarray(got) - np.asarray(f32)).max() <= 2e-2 * scale


def test_tile_geometry_itemsize_and_oracle_terms():
    """VMEM accounting: bf16 halves the element-block term; the fused
    oracle adds the panel + output terms; the gate consumes both."""
    from repro.kernels.kron_segsum import tile_geometry

    g32 = tile_geometry(1000, 10, 10)
    g16 = tile_geometry(1000, 10, 10, itemsize=2)
    gfo = tile_geometry(1000, 10, 10, oracle_s=8)
    assert g16.vmem_bytes < g32.vmem_bytes
    assert gfo.vmem_bytes > g32.vmem_bytes
    assert ops.kernel_fits_vmem(1000, 10, 10, precision="bf16",
                                vmem_budget=g16.vmem_bytes)
    assert not ops.kernel_fits_vmem(1000, 10, 10,
                                    vmem_budget=g16.vmem_bytes)


def test_penultimate_sorted_oracle_matches_unfused():
    """ops-level fused entry: (Z, Z@X) vs the unfused sorted path."""
    rng = np.random.default_rng(16)
    shape = (14, 9, 8)
    nnz = 120
    coords = np.stack([rng.integers(0, L, nnz) for L in shape], 1)
    mode = 0
    order = np.argsort(coords[:, mode], kind="stable")
    coords = coords[order]
    uniq, local = np.unique(coords[:, mode], return_inverse=True)
    R = len(uniq)
    values = rng.standard_normal(nnz).astype(np.float32)
    factors = random_factors(shape, (3, 3, 3), jax.random.PRNGKey(3))
    X = jnp.asarray(rng.standard_normal((9, 4)), jnp.float32)
    Z, ZX = ops.penultimate_sorted_oracle(
        jnp.asarray(coords, jnp.int32), jnp.asarray(values),
        jnp.asarray(local, jnp.int32), factors, mode, R, X, interpret=True)
    want = ops.penultimate_sorted(
        jnp.asarray(coords, jnp.int32), jnp.asarray(values),
        jnp.asarray(local, jnp.int32), factors, mode, R, interpret=True)
    np.testing.assert_array_equal(np.asarray(Z), np.asarray(want))
    np.testing.assert_allclose(ZX, want @ X, rtol=2e-4, atol=2e-4)
