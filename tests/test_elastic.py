"""Elastic scaling: a checkpoint written under one mesh restores under a
different device count (the fault-tolerance contract at 1000+ nodes:
mesh-shape-agnostic checkpoints + deterministic data stream resume)."""

import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int, timeout=900) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


@pytest.mark.slow
def test_checkpoint_survives_mesh_change(tmp_path):
    ckdir = str(tmp_path / "ck")
    # phase 1: train 6 steps on 8 devices, checkpoint at 5
    out = _run(f"""
        from repro.launch.train import train_main
        res = train_main(["--arch", "granite-3-2b", "--smoke",
                          "--steps", "6", "--batch", "8", "--seq", "32",
                          "--ckpt-dir", {ckdir!r}, "--ckpt-every", "5",
                          "--log-every", "1"])
        print("LOSS_AT_5::%.6f" % res["last_loss"])
    """, devices=8)
    # phase 2: resume on 4 devices (elastic shrink) — must pick up step 5
    out2 = _run(f"""
        from repro.launch.train import train_main
        res = train_main(["--arch", "granite-3-2b", "--smoke",
                          "--steps", "8", "--batch", "8", "--seq", "32",
                          "--ckpt-dir", {ckdir!r}, "--ckpt-every", "5",
                          "--log-every", "1"])
        print("RESUMED_OK")
    """, devices=4)
    assert "resumed from step 5" in out2
    assert "RESUMED_OK" in out2
