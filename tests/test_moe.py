"""MoE dispatch correctness: capacity, grouping invariance, reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoEConfig, init_moe, moe_apply


def _ref_moe(params, x, cfg):
    """Dense reference: every token times its top-k experts, no capacity."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.num_experts):
        g = jax.nn.silu(xf @ params["w_gate"][e])
        u = xf @ params["w_up"][e]
        outs.append((g * u) @ params["w_down"][e])
    outs = jnp.stack(outs, 1)  # (T, E, d)
    y = jnp.zeros_like(xf)
    for j in range(cfg.top_k):
        y = y + jnp.take_along_axis(
            outs, top_e[:, j][:, None, None], 1)[:, 0] * top_p[:, j][:, None]
    return y.reshape(B, S, d)


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_moe_matches_dense_reference_when_capacity_ample(groups):
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                    capacity_factor=8.0)  # ample: nothing drops
    key = jax.random.PRNGKey(0)
    params = init_moe(key, 16, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 16))
    want = _ref_moe(params, x, cfg)
    got, aux = moe_apply(params, x, cfg, groups=groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_group_invariance():
    """With ample capacity the grouped dispatch is exact => groups don't
    change the output."""
    cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=16,
                    capacity_factor=8.0)
    params = init_moe(jax.random.PRNGKey(2), 24, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, 24))
    y1, _ = moe_apply(params, x, cfg, groups=1)
    y4, _ = moe_apply(params, x, cfg, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_overflow():
    """Tiny capacity: outputs bounded, finite, and strictly 'less' than the
    ample-capacity output (some tokens fall back to the residual stream)."""
    cfg_small = MoEConfig(num_experts=2, top_k=1, d_ff_expert=16,
                          capacity_factor=0.25)
    cfg_big = MoEConfig(num_experts=2, top_k=1, d_ff_expert=16,
                        capacity_factor=8.0)
    params = init_moe(jax.random.PRNGKey(4), 8, cfg_big)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 8))
    y_small, _ = moe_apply(params, x, cfg_small)
    y_big, _ = moe_apply(params, x, cfg_big)
    assert np.isfinite(np.asarray(y_small)).all()
    n_small = float(jnp.sum(jnp.abs(y_small) > 0))
    n_big = float(jnp.sum(jnp.abs(y_big) > 0))
    assert n_small < n_big  # overflow dropped (Lite hard-limit discipline)


def test_moe_grad_finite():
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=1.25)
    params = init_moe(jax.random.PRNGKey(6), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, 16))
    def loss(p):
        y, aux = moe_apply(p, x, cfg, groups=2)
        return jnp.sum(y**2) + aux
    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
