"""StreamingTensor + streaming plan support: host-side contracts.

Covers the incremental (chain) fingerprint, snapshot semantics, the
geometric pad quantization that keeps compiled shapes stable under
appends, the §4-drift invalidation predicate, cheap policy extension, and
the save/load round-trip of the new stream fields. Device-side scheduler
behavior lives in tests/test_scheduler.py.
"""

import numpy as np
import pytest

from repro.core.coo import SparseTensor
from repro.streaming import StreamingTensor


def _batch(rng, shape, n):
    coords = np.stack([rng.integers(0, L, n) for L in shape], axis=1)
    return coords, rng.standard_normal(n)


# ------------------------------------------------------------ StreamingTensor
def test_append_validates_bounds_and_shapes(rng):
    s = StreamingTensor((4, 5, 6))
    with pytest.raises(ValueError, match="out of bounds"):
        s.append([[0, 0, 6]], [1.0])
    with pytest.raises(ValueError, match="non-negative"):
        s.append([[0, -1, 0]], [1.0])
    with pytest.raises(ValueError, match="coords must be"):
        s.append([[0, 0]], [1.0])
    with pytest.raises(ValueError, match="values"):
        s.append([[0, 0, 0]], [1.0, 2.0])
    assert s.version == 0 and s.nnz == 0


def test_empty_append_is_a_noop(rng):
    """A timer-driven flush with nothing buffered must not look like a
    change: version and fingerprint stay put, so the scheduler keeps
    hitting the zero-cost reuse path."""
    shape = (6, 5, 4)
    s = StreamingTensor(shape)
    c, v = _batch(rng, shape, 20)
    s.append(c, v)
    fp, ver, snap = s.fingerprint(), s.version, s.snapshot()
    assert s.append(np.zeros((0, 3), dtype=np.int64), []) == ver
    assert s.fingerprint() == fp and s.version == ver
    assert s.snapshot() is snap  # cache not invalidated either


def test_chain_fingerprint_deterministic_and_order_sensitive(rng):
    shape = (10, 8, 6)
    c1, v1 = _batch(rng, shape, 50)
    c2, v2 = _batch(rng, shape, 30)
    a, b, c = (StreamingTensor(shape) for _ in range(3))
    a.append(c1, v1), a.append(c2, v2)
    b.append(c1, v1), b.append(c2, v2)
    c.append(c2, v2), c.append(c1, v1)
    assert a.fingerprint() == b.fingerprint()  # same history -> same fp
    assert a.fingerprint() != c.fingerprint()  # different order -> different


def test_snapshot_matches_concatenation_and_presets_fingerprint(rng):
    shape = (10, 8, 6)
    s = StreamingTensor(shape, name="x")
    c1, v1 = _batch(rng, shape, 50)
    c2, v2 = _batch(rng, shape, 30)
    s.append(c1, v1)
    s.append(c2, v2)
    t = s.snapshot()
    assert isinstance(t, SparseTensor)
    np.testing.assert_array_equal(t.coords, np.concatenate([c1, c2]))
    np.testing.assert_array_equal(t.values, np.concatenate([v1, v2]))
    # the memoized fingerprint is the chain value (no O(nnz) rehash), and
    # the snapshot records the stream version it captures
    assert t.fingerprint() == s.fingerprint()
    assert getattr(t, "_stream_version") == 2
    # cached until the next append; invalidated afterwards
    assert s.snapshot() is t
    s.append(c1[:1], v1[:1])
    assert s.snapshot() is not t


def test_incremental_histograms_and_coords_since(rng):
    shape = (7, 9, 5)
    s = StreamingTensor(shape)
    c1, v1 = _batch(rng, shape, 40)
    c2, v2 = _batch(rng, shape, 25)
    s.append(c1, v1)
    s.append(c2, v2)
    t = s.snapshot()
    for n in range(3):
        np.testing.assert_array_equal(s.slice_hist(n), t.slice_sizes(n))
    np.testing.assert_array_equal(s.coords_since(1), c2)
    assert s.coords_since(2).shape == (0, 3)
    with pytest.raises(ValueError, match="outside"):
        s.coords_since(3)


def test_from_tensor_seeds_first_batch(small_tensor):
    s = StreamingTensor.from_tensor(small_tensor)
    assert s.version == 1 and s.nnz == small_tensor.nnz
    t = s.snapshot()
    np.testing.assert_array_equal(t.coords, small_tensor.coords)
    # chain fp differs from the content hash (different derivations), but
    # is stable across equal histories
    assert t.fingerprint() == StreamingTensor.from_tensor(
        small_tensor).fingerprint()


def test_snapshot_true_norm_handles_duplicate_appends(lowrank_tensor):
    """Value updates (duplicate coords) break the sum(values**2) norm
    identity; snapshots carry the accumulated true ||T||^2 and fit_score
    prefers it, so the streamed fit equals the dedup'd tensor's fit."""
    from repro.core.hooi import fit_score, hooi

    t = lowrank_tensor
    s = StreamingTensor.from_tensor(t)
    # reinforcing update: double the first 30 values via duplicate coords
    s.append(t.coords[:30], t.values[:30])
    snap = s.snapshot()
    merged = snap.dedup()
    assert np.isclose(getattr(snap, "_true_norm2"),
                      float(np.sum(merged.values**2)))
    dec, fits = hooi(merged, (2, 2, 2), n_invocations=2, seed=0)
    # same decomposition scored against the duplicated snapshot must give
    # the same fit (it would be inflated under the naive norm)
    assert np.isclose(fit_score(snap, dec), fit_score(merged, dec),
                      atol=1e-6)
    naive = 1.0 - np.sqrt(
        max(float(np.sum(snap.values**2))
            - float(np.asarray(dec.core**2).sum()), 0.0)
    ) / np.sqrt(float(np.sum(snap.values**2)))
    assert not np.isclose(fit_score(snap, dec), naive, atol=1e-6), \
        "test tensor too tame: duplicates did not change the norm"


# ------------------------------------------------------- pad quantization
def test_round_up_pow2():
    from repro.distributed.partition import round_up_pow2

    assert [round_up_pow2(x) for x in (0, 1, 2, 3, 4, 5, 1023, 1024)] == \
        [1, 1, 2, 4, 4, 8, 1024, 1024]


def test_pad_geometric_quantizes_but_preserves_real_content(small_tensor):
    from repro.core.distribution import build_scheme
    from repro.distributed.partition import make_mode_partition

    scheme = build_scheme(small_tensor, "lite", 4)
    tight = make_mode_partition(small_tensor, scheme, 0)
    quant = make_mode_partition(small_tensor, scheme, 0, pad_geometric=True)
    for dim in ("E_pad", "R_pad", "S_pad", "B_pad"):
        q = getattr(quant, dim)
        assert q >= getattr(tight, dim)
        assert q & (q - 1) == 0, f"{dim}={q} not a power of two"
    # identical real content: per-rank counts unchanged, the real element
    # region (first e_per_rank[p] slots) identical
    np.testing.assert_array_equal(tight.e_per_rank, quant.e_per_rank)
    np.testing.assert_array_equal(tight.r_per_rank, quant.r_per_rank)
    for p in range(4):
        k = int(tight.e_per_rank[p])
        np.testing.assert_array_equal(tight.coords[p, :k],
                                      quant.coords[p, :k])
        np.testing.assert_array_equal(tight.values[p, :k],
                                      quant.values[p, :k])
    # quantized padding elements still carry value 0 (scatter no-ops)
    for p in range(4):
        k = int(quant.e_per_rank[p])
        assert not quant.values[p, k:].any()


def test_plan_pad_geometric_is_part_of_cache_key(small_tensor):
    from repro.core.plan import plan

    a = plan(small_tensor, "lite", 4, core_dims=(3, 3, 3))
    b = plan(small_tensor, "lite", 4, core_dims=(3, 3, 3),
             pad_geometric=True)
    assert a is not b
    assert b.pad_geometric and not a.pad_geometric
    assert b is plan(small_tensor, "lite", 4, core_dims=(3, 3, 3),
                     pad_geometric=True)


# ------------------------------------------------- invalidation predicate
def _plan_with_maps(t, P=4):
    from repro.core.plan import plan, slice_owner_maps

    pl = plan(t, "lite", P, core_dims=(3, 3, 3))
    return pl, slice_owner_maps(pl, t)


def test_owner_maps_cover_every_slice(small_tensor):
    pl, maps = _plan_with_maps(small_tensor)
    for n, m in enumerate(maps):
        assert m.shape == (small_tensor.shape[n],)
        assert ((m >= 0) & (m < 4)).all()


def test_owner_maps_refuse_mismatched_tensor(small_tensor, skewed_tensor):
    from repro.core.plan import slice_owner_maps

    pl, _ = _plan_with_maps(small_tensor)
    with pytest.raises(ValueError, match="snapshot"):
        slice_owner_maps(pl, skewed_tensor)


def test_refresh_decision_balanced_vs_skewed(small_tensor, rng):
    from repro.core.plan import refresh_decision

    pl, maps = _plan_with_maps(small_tensor)
    base = [np.asarray(mp.e_per_rank) for mp in pl.parts]

    # value updates at existing coordinates follow the owner maps exactly:
    # load grows near-uniformly, the plan survives
    idx = rng.integers(0, small_tensor.nnz, 60)
    batch = small_tensor.coords[idx]
    loads = [base[n] + np.bincount(maps[n][batch[:, n]], minlength=4)
             for n in range(3)]
    decision, drift = refresh_decision(pl, loads)
    assert decision == "repartition"
    assert drift["worst"] <= 1.25
    assert set(drift) == {0, 1, 2, "worst"}

    # a hub batch: every element in one slice -> one rank's load explodes
    hub = np.tile(small_tensor.coords[0], (10 * small_tensor.nnz, 1))
    loads = [base[n] + np.bincount(maps[n][hub[:, n]], minlength=4)
             for n in range(3)]
    decision, drift = refresh_decision(pl, loads)
    assert decision == "reselect"
    assert drift["worst"] > 1.25


def test_refresh_decision_baseline_override_prevents_ratchet(small_tensor,
                                                             rng):
    """A caller refreshing repeatedly must compare against the
    selection-time imbalance: with the baseline pinned, gradual skew
    crosses the tolerance even though each step alone stays within it."""
    from repro.core.plan import refresh_decision

    pl, maps = _plan_with_maps(small_tensor)
    selection_baseline = tuple(max(float(m.ttm_imbalance), 1.0)
                               for m in pl.metrics.per_mode)
    loads = [np.asarray(mp.e_per_rank).astype(np.int64)
             for mp in pl.parts]
    hub_ranks = [int(maps[n][small_tensor.coords[0][n]]) for n in range(3)]
    decisions = []
    for _ in range(12):
        # each batch adds 15% of mode-0's current max load onto one rank —
        # individually under the 25% tolerance vs the *current* loads
        step = max(int(0.15 * loads[0].max()), 1)
        for n in range(3):
            loads[n][hub_ranks[n]] += step
        d, _ = refresh_decision(pl, loads, baseline=selection_baseline)
        decisions.append(d)
    assert decisions[0] == "repartition"  # small drift tolerated at first
    assert "reselect" in decisions, (
        "cumulative skew must eventually cross the pinned baseline")


def test_extend_scheme_keeps_existing_assignments(small_tensor, rng):
    from repro.core.plan import extend_scheme

    pl, maps = _plan_with_maps(small_tensor)
    idx = rng.integers(0, small_tensor.nnz, 40)
    batch = small_tensor.coords[idx]
    ext = extend_scheme(pl.scheme, maps, batch)
    assert ext.P == pl.scheme.P and ext.name == pl.scheme.name
    for n in range(3):
        old = pl.scheme.policy(n)
        new = ext.policy(n)
        assert len(new) == len(old) + len(batch)
        np.testing.assert_array_equal(new[:len(old)], old)
        np.testing.assert_array_equal(new[len(old):],
                                      maps[n][batch[:, n]])


# ------------------------------------------------------ plan cache + I/O
def test_same_version_snapshots_share_one_plan(small_tensor):
    from repro.core.plan import plan

    s = StreamingTensor.from_tensor(small_tensor)
    a = plan(s.snapshot(), "lite", 4, core_dims=(3, 3, 3),
             pad_geometric=True)
    b = plan(s.snapshot(), "lite", 4, core_dims=(3, 3, 3),
             pad_geometric=True)
    assert a is b  # identity contract -> executor upload cache works
    assert a.stream_version == 1


def test_save_load_roundtrips_stream_fingerprint(tmp_path, small_tensor):
    from repro.core.plan import PartitionPlan, plan

    s = StreamingTensor.from_tensor(small_tensor)
    s.append(small_tensor.coords[:5], small_tensor.values[:5])
    t = s.snapshot()
    pl = plan(t, "lite", 4, core_dims=(3, 3, 3), pad_geometric=True)
    path = str(tmp_path / "stream_plan.npz")
    pl.save(path)
    got = PartitionPlan.load(path, t)
    assert got.fingerprint == s.fingerprint()
    assert got.stream_version == 2
    assert got.pad_geometric is True
    for mp, mq in zip(pl.parts, got.parts):
        assert mp.E_pad == mq.E_pad and mp.R_pad == mq.R_pad
    # a snapshot from a diverged history is refused
    s.append(small_tensor.coords[:1], small_tensor.values[:1])
    with pytest.raises(ValueError, match="stale plan"):
        PartitionPlan.load(path, s.snapshot())


def test_old_plan_files_without_stream_fields_still_load(tmp_path,
                                                         small_tensor):
    """Forward-compat: pre-streaming plans (no stream_version /
    pad_geometric in meta) must load with the defaults."""
    import json

    import numpy as _np

    from repro.core.plan import PartitionPlan, plan

    pl = plan(small_tensor, "lite", 4, core_dims=(3, 3, 3))
    path = str(tmp_path / "legacy.npz")
    pl.save(path)
    # strip the new fields to emulate a pre-streaming file
    with _np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files if k != "__meta__"}
        meta = json.loads(str(z["__meta__"]))
    meta.pop("stream_version", None)
    meta.pop("pad_geometric", None)
    _np.savez_compressed(path, __meta__=_np.array(json.dumps(meta)),
                         **arrays)
    got = PartitionPlan.load(path, small_tensor)
    assert got.stream_version is None
    assert got.pad_geometric is False
