"""Fault-injection suite for the scheduler/pool pipeline (``_chaos``).

Real (small) distributed runs with deterministic faults keyed by tensor
fingerprint — interleaving-independent, so the same script hits the same
faults on every run. Contracts:

  * a killed *prepare* surfaces on that job's future only; the stream it
    belonged to recovers on resubmit (no half-adopted state);
  * a killed *sweep* (consumer side) surfaces the same way and leaves the
    executor's caches healthy for every other tensor;
  * injected delay is visible in SLO accounting (``slo_met``/``slo_miss``)
    without affecting correctness;
  * the whole fault script is deterministic: rerunning it on a fresh
    executor fires the same faults and yields the same per-submit
    outcomes and decisions.
"""

import numpy as np
import pytest

import _chaos
from repro.core.coo import SparseTensor
from repro.streaming import StreamingTensor

CORE = (2, 2, 2)
SHAPE = (24, 18, 15)

pytestmark = pytest.mark.slow


def _tensor(seed, nnz=250):
    r = np.random.default_rng(seed)
    coords = np.stack([r.integers(0, L, nnz) for L in SHAPE], axis=1)
    return SparseTensor(coords, r.standard_normal(nnz), SHAPE).dedup()


def _stream(seed, nnz=250, name="s"):
    return StreamingTensor.from_tensor(_tensor(seed, nnz), name=name)


@pytest.fixture
def executor():
    from repro.distributed.executor import HooiExecutor

    return HooiExecutor(2)


@pytest.fixture
def scheduler(executor):
    from repro.engine.scheduler import StreamScheduler

    with StreamScheduler(executor, CORE, n_invocations=1, workers=2) as s:
        yield s


def test_kill_prepare_surfaces_and_stream_recovers(scheduler, executor):
    stream = _stream(0)
    fp = stream.snapshot().fingerprint()
    plan = _chaos.FaultPlan().at(fp, "prepare", _chaos.kill())

    with _chaos.inject(executor, plan):
        bad = scheduler.submit(stream, seed=0)
        with pytest.raises(_chaos.ChaosError):
            bad.result()
        # the fault consumed itself: the same stream recovers on resubmit,
        # and because the kill preceded adoption it re-plans from scratch
        good = scheduler.submit(stream, seed=0).result()
    assert good.decision == "plan"
    assert plan.fired == [(fp[:8], "prepare", "kill")]
    st = scheduler.stats()
    assert st["failed"] == 1 and st["completed"] == 1


def test_kill_sweep_recovers_and_does_not_poison_caches(scheduler, executor):
    victim, healthy = _tensor(1), _tensor(2)
    plan = _chaos.FaultPlan().at(victim.fingerprint(), "run", _chaos.kill())

    with _chaos.inject(executor, plan):
        futs = [scheduler.submit(victim, name="victim"),
                scheduler.submit(healthy, name="healthy")]
        out = scheduler.drain(return_exceptions=True)
        # one entry per submit, in submission order, failure in-place
        assert len(out) == 2
        assert isinstance(out[0], _chaos.ChaosError)
        assert out[1].name == "healthy"
        # the killed sweep left no wreckage: the victim reruns clean, and
        # the healthy tensor's caches were never poisoned (full warm rerun)
        r2 = scheduler.submit(victim, name="victim").result()
        r3 = scheduler.submit(healthy, name="healthy").result()
    assert np.isfinite(r2.stats.fits[-1])
    assert r3.stats.step_compilations == 0 and r3.stats.uploads == 0
    assert futs[1].result() is out[1]


def test_delay_shows_up_as_slo_miss(scheduler, executor):
    t_slow, t_fast = _tensor(3), _tensor(4)
    plan = _chaos.FaultPlan().at(t_slow.fingerprint(), "run",
                                 _chaos.delay(0.4))

    with _chaos.inject(executor, plan):
        slow = scheduler.submit(t_slow, deadline_s=0.2)
        fast = scheduler.submit(t_fast, deadline_s=120.0)
        r_slow, r_fast = slow.result(), fast.result()
    assert r_slow.slo_met is False and r_slow.stats.slo_met is False
    assert r_slow.stats.slo_deadline_s == 0.2
    assert r_fast.slo_met is True
    # the delay cost time, not correctness
    assert np.isfinite(r_slow.stats.fits[-1])
    st = scheduler.stats()
    assert st["slo_miss"] == 1 and st["slo_hit"] == 1
    assert st["queue_wait_s"] >= 0.0


def test_stream_chain_recovers_past_mid_chain_kill(scheduler, executor):
    """Kill the prepare of one *version* of a stream; earlier and later
    versions still decompose, and the ladder resumes where it should."""
    rng = np.random.default_rng(7)
    stream = _stream(5, name="chain")
    first = scheduler.submit(stream, seed=0).result()
    assert first.decision == "plan"

    b = 20
    c = np.stack([rng.integers(0, L, b) for L in SHAPE], axis=1)
    stream.append(c, rng.standard_normal(b))
    fp_v2 = stream.snapshot().fingerprint()
    plan = _chaos.FaultPlan().at(fp_v2, "prepare", _chaos.kill())

    with _chaos.inject(executor, plan):
        dead = scheduler.submit(stream, seed=1)
        alive = scheduler.submit(stream, seed=2)  # same version, retried
        with pytest.raises(_chaos.ChaosError):
            dead.result()
        r = alive.result()
    # the retry saw the same appended batch and took a real ladder step
    assert r.decision in ("stochastic-refine", "repartition", "reselect",
                          "plan")
    assert r.stream_version == 2
    assert plan.fired == [(fp_v2[:8], "prepare", "kill")]


def test_kill_mid_stochastic_refine_recovers_via_correction_sweep(executor):
    """A fingerprint-keyed kill inside ``run_stochastic`` surfaces on that
    job's future only, leaves the step/upload caches healthy for other
    tensors, and the next submit of the stream recovers through a full
    correction sweep — with one drain entry per submit throughout."""
    from repro.engine.scheduler import StreamScheduler

    rng = np.random.default_rng(21)
    stream = _stream(13, name="stoch")
    healthy = _tensor(14)

    def append(n=20):
        c = np.stack([rng.integers(0, L, n) for L in SHAPE], axis=1)
        stream.append(c, rng.standard_normal(n))

    with StreamScheduler(executor, CORE, n_invocations=1, workers=2,
                         sample_fraction=0.5, replay_nnz=32,
                         stochastic_tol=0.25, correction_every=0) as sched:
        assert sched.submit(stream, seed=0).result().decision == "plan"
        sched.submit(healthy, name="healthy").result()  # warm full caches
        # prove the rung is live on this schedule before injecting faults
        append()
        r1 = sched.submit(stream, seed=1).result()
        assert r1.decision == "stochastic-refine"
        assert r1.stats.sample_fraction == 0.5 and r1.stats.sample_nnz > 0

        append()
        fp_v3 = stream.snapshot().fingerprint()
        plan = _chaos.FaultPlan().at(fp_v3, "run", _chaos.kill())
        with _chaos.inject(executor, plan):
            sched.submit(stream, seed=2)  # the refine that dies mid-run
            sched.submit(healthy, name="healthy")
            out = sched.drain(return_exceptions=True)
            # one entry per submit, in order; the kill stayed in its lane
            assert len(out) == 5  # all submits so far, none dropped
            out = out[-2:]
            assert isinstance(out[0], _chaos.ChaosError)
            # the other tensor's caches were never poisoned: full warm rerun
            assert out[1].stats.step_compilations == 0
            assert out[1].stats.uploads == 0
            # recovery: same stream version, sampled rung now distrusted —
            # the scheduler routes a full correction sweep and re-anchors
            r2 = sched.submit(stream, seed=3).result()
        assert plan.fired == [(fp_v3[:8], "run", "kill")]
        assert r2.decision in ("repartition", "reselect")
        assert r2.stats.sample_fraction is None  # a full sweep, not sampled
        assert np.isfinite(r2.stats.fits[-1])
        # ...and the rung comes back once the stream is re-anchored
        append()
        r3 = sched.submit(stream, seed=4).result()
        assert r3.decision == "stochastic-refine"
        assert np.isfinite(r3.stats.fits[-1])
    st = sched.stats()
    assert st["failed"] == 1


def test_fault_script_is_deterministic():
    """Same submissions + same fault plan on a fresh executor => same fired
    faults and identical per-submit outcomes/decisions, regardless of
    thread interleaving."""
    from repro.distributed.executor import HooiExecutor
    from repro.engine.scheduler import StreamScheduler

    def run_script():
        ex = HooiExecutor(2)
        s1, s2 = _stream(11, name="a"), _stream(12, name="b")
        fp1 = s1.snapshot().fingerprint()
        plan = _chaos.FaultPlan().at(fp1, "prepare",
                                     _chaos.kill(), _chaos.delay(0.05))
        outcomes = []
        with StreamScheduler(ex, CORE, n_invocations=1, workers=2) as sched:
            with _chaos.inject(ex, plan):
                for seed in range(3):
                    sched.submit(s1, seed=seed)
                    sched.submit(s2, seed=seed)
                for r in sched.drain(return_exceptions=True):
                    if isinstance(r, Exception):
                        outcomes.append(("fail", type(r).__name__))
                    else:
                        outcomes.append((r.name, r.decision))
        return outcomes, sorted(plan.fired)

    out_a, fired_a = run_script()
    out_b, fired_b = run_script()
    assert out_a == out_b
    assert fired_a == fired_b
    assert out_a[0] == ("fail", "ChaosError")  # s1's first prepare killed
