"""Table-driven coverage of the centralized ``REPRO_*`` env knobs.

``repro.envknobs`` is the single parsing point: unset/empty means "no
override", malformed values raise ``ValueError`` naming the variable, and
every historical consumer (``resolve_precision`` etc.) delegates its env
step here — so a typo'd CI leg fails loudly instead of silently running
the wrong configuration.
"""

import pytest

from repro import envknobs

# (variable, raw value, expected parse result or ValueError)
CASES = [
    ("REPRO_FORCE_KERNEL", "", False),
    ("REPRO_FORCE_KERNEL", "0", False),
    ("REPRO_FORCE_KERNEL", "1", True),
    ("REPRO_FORCE_KERNEL", "yes", ValueError),
    ("REPRO_FORCE_KERNEL", "2", ValueError),
    ("REPRO_FUSED_ZBUILD", "", False),
    ("REPRO_FUSED_ZBUILD", "1", True),
    ("REPRO_FUSED_ZBUILD", "true", ValueError),
    ("REPRO_PRECISION", "", None),
    ("REPRO_PRECISION", "f32", "f32"),
    ("REPRO_PRECISION", "bf16", "bf16"),
    ("REPRO_PRECISION", "fp16", ValueError),
    ("REPRO_LANCZOS_BLOCK", "", None),
    ("REPRO_LANCZOS_BLOCK", "1", 1),
    ("REPRO_LANCZOS_BLOCK", "4", 4),
    ("REPRO_LANCZOS_BLOCK", "0", ValueError),
    ("REPRO_LANCZOS_BLOCK", "-2", ValueError),
    ("REPRO_LANCZOS_BLOCK", "four", ValueError),
    ("REPRO_VMEM_BUDGET", "", None),
    ("REPRO_VMEM_BUDGET", "1048576", 1048576),
    ("REPRO_VMEM_BUDGET", "0", ValueError),
    ("REPRO_VMEM_BUDGET", "-1", ValueError),
    ("REPRO_VMEM_BUDGET", "12MB", ValueError),
    ("REPRO_OBJECTIVE", "", None),
    ("REPRO_OBJECTIVE", "tucker", "tucker"),
    ("REPRO_OBJECTIVE", "completion", "completion"),
    ("REPRO_OBJECTIVE", "nn", "nn"),
    ("REPRO_OBJECTIVE", "ridge", ValueError),
    ("REPRO_WARM_START", "", None),
    ("REPRO_WARM_START", "none", "none"),
    ("REPRO_WARM_START", "sketch", "sketch"),
    ("REPRO_WARM_START", "auto", "auto"),
    ("REPRO_WARM_START", "randomized", ValueError),
    ("REPRO_SAMPLE_FRACTION", "", None),
    ("REPRO_SAMPLE_FRACTION", "0.25", 0.25),
    ("REPRO_SAMPLE_FRACTION", "1", 1.0),
    ("REPRO_SAMPLE_FRACTION", "0", ValueError),
    ("REPRO_SAMPLE_FRACTION", "1.5", ValueError),
    ("REPRO_SAMPLE_FRACTION", "-0.1", ValueError),
    ("REPRO_SAMPLE_FRACTION", "half", ValueError),
]


@pytest.mark.parametrize(
    "var,raw,expect", CASES,
    ids=[f"{v}={r!r}" for v, r, _ in CASES])
def test_knob_parsing(monkeypatch, var, raw, expect):
    monkeypatch.setenv(var, raw)
    parse = envknobs.KNOBS[var]
    if expect is ValueError:
        with pytest.raises(ValueError, match=var):
            parse()
    else:
        assert parse() == expect


def test_whitespace_is_stripped(monkeypatch):
    monkeypatch.setenv("REPRO_LANCZOS_BLOCK", "  8  ")
    assert envknobs.lanczos_block() == 8
    monkeypatch.setenv("REPRO_PRECISION", " bf16 ")
    assert envknobs.precision() == "bf16"


def test_snapshot_covers_every_knob_unset(monkeypatch):
    for var in envknobs.KNOBS:
        monkeypatch.delenv(var, raising=False)
    assert envknobs.snapshot() == {
        "REPRO_FORCE_KERNEL": False,
        "REPRO_FUSED_ZBUILD": False,
        "REPRO_PRECISION": None,
        "REPRO_LANCZOS_BLOCK": None,
        "REPRO_VMEM_BUDGET": None,
        "REPRO_OBJECTIVE": None,
        "REPRO_WARM_START": None,
        "REPRO_SAMPLE_FRACTION": None,
    }


def test_consumers_delegate_to_envknobs(monkeypatch):
    """The historical resolvers honor the centralized parsers — overrides
    take effect and malformed values surface instead of being ignored."""
    from repro.engine.objective import resolve_objective
    from repro.engine.oracle import resolve_block_size, resolve_warm_start
    from repro.engine.zbuild import (
        kernel_forced_by_env, resolve_fused_zbuild, resolve_precision)
    from repro.kernels.ops import vmem_budget_bytes

    monkeypatch.setenv("REPRO_PRECISION", "bf16")
    assert resolve_precision(None) == "bf16"
    monkeypatch.setenv("REPRO_WARM_START", "sketch")
    assert resolve_warm_start(None) == "sketch"
    monkeypatch.setenv("REPRO_LANCZOS_BLOCK", "3")
    assert resolve_block_size(None) == 3
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")
    assert vmem_budget_bytes() == 4096
    monkeypatch.setenv("REPRO_OBJECTIVE", "nn")
    assert resolve_objective(None).name == "nn"
    monkeypatch.setenv("REPRO_FUSED_ZBUILD", "1")
    assert resolve_fused_zbuild(None) is True
    monkeypatch.setenv("REPRO_FORCE_KERNEL", "1")
    assert kernel_forced_by_env() is True

    monkeypatch.setenv("REPRO_PRECISION", "half")
    with pytest.raises(ValueError, match="REPRO_PRECISION"):
        resolve_precision(None)
    monkeypatch.setenv("REPRO_OBJECTIVE", "sparse")
    with pytest.raises(ValueError, match="REPRO_OBJECTIVE"):
        resolve_objective(None)
    monkeypatch.setenv("REPRO_WARM_START", "cold")
    with pytest.raises(ValueError, match="REPRO_WARM_START"):
        resolve_warm_start(None)


def test_explicit_argument_beats_env(monkeypatch):
    """A caller-supplied value never consults the environment — even a
    malformed variable stays dormant until the default path would read it."""
    from repro.engine.objective import resolve_objective
    from repro.engine.oracle import resolve_block_size, resolve_warm_start
    from repro.engine.zbuild import resolve_precision

    monkeypatch.setenv("REPRO_PRECISION", "garbage")
    assert resolve_precision("f32") == "f32"
    monkeypatch.setenv("REPRO_LANCZOS_BLOCK", "garbage")
    assert resolve_block_size(2) == 2
    monkeypatch.setenv("REPRO_OBJECTIVE", "garbage")
    assert resolve_objective("completion").name == "completion"
    monkeypatch.setenv("REPRO_WARM_START", "garbage")
    assert resolve_warm_start("sketch") == "sketch"
