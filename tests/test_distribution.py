"""Distribution-scheme tests, including Theorem 6.1 property tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.coo import SparseTensor
from repro.core.distribution import (
    SCHEMES,
    build_scheme,
    coarse_policy,
    lite_policy,
    medium_policies,
    row_owner_map,
)
from repro.core.metrics import mode_metrics, scheme_metrics
from repro.data.tensors import synth_tensor


def _rand_tensor(rng, N=3, Lmax=40, nnz=300):
    shape = tuple(int(rng.integers(2, Lmax)) for _ in range(N))
    coords = np.stack([rng.integers(0, L, nnz) for L in shape], axis=1)
    values = rng.standard_normal(nnz)
    return SparseTensor(coords, values, shape).dedup()


# ---------------------------------------------------------------- invariants
@pytest.mark.parametrize("scheme", SCHEMES)
def test_policies_are_total_and_in_range(scheme):
    rng = np.random.default_rng(0)
    t = _rand_tensor(rng)
    P = 7
    s = build_scheme(t, scheme, P)
    assert s.nmodes == t.ndim
    for n in range(t.ndim):
        pol = s.policy(n)
        assert pol.shape == (t.nnz,)
        assert pol.min() >= 0 and pol.max() < P


# ------------------------------------------------------- Theorem 6.1 (Lite)
@settings(max_examples=60, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    P=st.integers(1, 24),
    N=st.integers(2, 4),
    nnz=st.integers(1, 600),
    Lmax=st.integers(2, 60),
)
def test_lite_theorem_bounds(seed, P, N, nnz, Lmax):
    """Theorem 6.1: E_max <= ceil(|E|/P); R_sum <= L+P; R_max <= ceil(L/P)+2."""
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(1, Lmax + 1)) for _ in range(N))
    coords = np.stack([rng.integers(0, L, nnz) for L in shape], axis=1)
    t = SparseTensor(coords, rng.standard_normal(nnz), shape).dedup()
    for n in range(N):
        pol = lite_policy(t, n, P)
        m = mode_metrics(t, pol, n, P)
        limit = -(-t.nnz // P)
        assert m.E_max <= limit, f"E_max {m.E_max} > {limit}"
        assert m.R_sum <= t.shape[n] + P, f"R_sum {m.R_sum} > L+P"
        assert m.R_max <= -(-t.shape[n] // P) + 2, f"R_max {m.R_max}"


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), P=st.integers(2, 16))
def test_lite_split_slice_structure(seed, P):
    """Theorem 6.1 proof structure: every rank shares at most 2 split slices
    (head of <= 1, tail of <= 1), and split-slice sharer sets are contiguous
    among ranks that actually receive elements of the slice."""
    rng = np.random.default_rng(seed)
    t = _rand_tensor(rng, N=3, Lmax=12, nnz=500)  # small L => big slices
    for n in range(t.ndim):
        pol = lite_policy(t, n, P)
        split_count = np.zeros(P, dtype=int)
        for l in np.unique(t.coords[:, n]):
            ranks = np.unique(pol[t.coords[:, n] == l])
            if len(ranks) > 1:  # split (bad) slice
                split_count[ranks] += 1
        assert split_count.max(initial=0) <= 2, split_count


def test_lite_zero_and_tiny():
    t = SparseTensor(np.zeros((0, 3), dtype=np.int64), np.zeros(0), (4, 4, 4))
    assert lite_policy(t, 0, 4).shape == (0,)
    t1 = SparseTensor(np.array([[0, 1, 2]]), np.array([1.0]), (3, 3, 3))
    assert lite_policy(t1, 0, 8).shape == (1,)


def test_lite_on_pathological_hub():
    """One giant slice: Lite must split it and stay at the optimal limit."""
    t = synth_tensor((50, 200, 200), 20_000, alphas=0.3,
                     hub_fraction=0.5, hub_modes=(0,), seed=1)
    P = 16
    pol = lite_policy(t, 0, P)
    m = mode_metrics(t, pol, 0, P)
    assert m.E_max <= -(-t.nnz // P)
    # CoarseG on the same tensor must be far worse on E_max
    cp = coarse_policy(t, 0, P, strategy="lpt")
    mc = mode_metrics(t, cp, 0, P)
    assert mc.E_max > 2 * m.E_max


# ------------------------------------------------------------- baselines
def test_coarse_slices_never_split():
    rng = np.random.default_rng(3)
    t = _rand_tensor(rng)
    for strat in ("lpt", "block"):
        for n in range(t.ndim):
            pol = coarse_policy(t, n, 5, strategy=strat)
            m = mode_metrics(t, pol, n, 5)
            assert m.R_sum == m.L_nonempty  # every slice good => optimal R_sum


def test_medium_grid_shape():
    rng = np.random.default_rng(4)
    t = _rand_tensor(rng, N=3)
    pol, q = medium_policies(t, 12)
    assert int(np.prod(q)) == 12
    assert pol.max() < 12


def test_medium_slice_sharers_bounded_by_grid():
    """Mode-n slice can be shared by at most P/q_n ranks (paper §5)."""
    rng = np.random.default_rng(5)
    t = _rand_tensor(rng, N=3, nnz=2000, Lmax=30)
    P = 12
    pol, q = medium_policies(t, P)
    for n in range(t.ndim):
        cap = P // q[n]
        for l in np.unique(t.coords[:, n]):
            sharers = np.unique(pol[t.coords[:, n] == l])
            assert len(sharers) <= cap


def test_hypergraph_balance_cap():
    rng = np.random.default_rng(6)
    t = _rand_tensor(rng, nnz=800)
    s = build_scheme(t, "hypergraph", 6)
    counts = np.bincount(s.policy(0), minlength=6)
    cap = int(np.ceil(t.nnz / 6 * 1.05))
    assert counts.max() <= cap


# ------------------------------------------------------------- sigma_n map
def test_row_owner_is_a_sharer():
    rng = np.random.default_rng(7)
    t = _rand_tensor(rng)
    P = 6
    pol = lite_policy(t, 0, P)
    sigma = row_owner_map(t, pol, 0, P)
    for l in np.unique(t.coords[:, 0]):
        sharers = set(np.unique(pol[t.coords[:, 0] == l]).tolist())
        assert int(sigma[l]) in sharers


# ------------------------------------------------------------- metrics
def test_metrics_against_bruteforce():
    rng = np.random.default_rng(8)
    t = _rand_tensor(rng, nnz=200)
    P = 5
    s = build_scheme(t, "lite", P)
    for n in range(t.ndim):
        pol = s.policy(n)
        m = mode_metrics(t, pol, n, P)
        # brute force
        e_max = max((pol == p).sum() for p in range(P))
        r = [len(np.unique(t.coords[pol == p, n])) for p in range(P)]
        assert m.E_max == e_max
        assert m.R_sum == sum(r)
        assert m.R_max == max(r)


def test_scheme_metrics_ordering():
    """Qualitative reproduction of paper Fig 12: on a skewed tensor,
    Lite ~ optimal on both E_max and redundancy; CoarseG bad on E_max;
    Medium/HyperG (uni) worse on redundancy than Lite."""
    t = synth_tensor((60, 300, 300), 30_000, alphas=(1.3, 1.1, 1.1),
                     hub_fraction=0.25, hub_modes=(0,), seed=2)
    P = 16
    core = (8, 8, 8)
    res = {name: scheme_metrics(t, build_scheme(t, name, P), core)
           for name in ("lite", "coarse", "medium")}
    lite_imb = max(m.ttm_imbalance for m in res["lite"].per_mode)
    coarse_imb = max(m.ttm_imbalance for m in res["coarse"].per_mode)
    assert lite_imb <= 1.05
    assert coarse_imb > 2.0
    lite_red = max(m.svd_redundancy for m in res["lite"].per_mode)
    med_red = max(m.svd_redundancy for m in res["medium"].per_mode)
    assert lite_red < med_red
    # critical-path FLOPs: lite strictly better than coarse
    assert res["lite"].critical_path_flops < res["coarse"].critical_path_flops


def test_memory_model_runs():
    t = synth_tensor((40, 50, 60), 5_000, seed=3)
    s = build_scheme(t, "lite", 8)
    sm = scheme_metrics(t, s, (6, 6, 6))
    mem = sm.memory_bytes_per_rank()
    assert set(mem) == {"tensor", "penultimate", "factors", "total"}
    assert mem["total"] == mem["tensor"] + mem["penultimate"] + mem["factors"]
