"""ExecutorPool + StreamRouter: the serving tier under load and faults.

Real distributed runs on the 8 simulated host devices (2 lanes x P=2 uses
4 of them on disjoint slices). The acceptance contracts:

  * a 2-executor pool sustains >= 8 concurrent streams submitted from
    multiple threads, with per-stream SLO accounting and with injected
    prepare failures — no deadlock, no leaked worker threads after
    ``close()``, one drain entry per submit, failures never poisoning the
    healthy lanes' caches;
  * admission control is a bounded queue with per-priority shares —
    ``batch`` is refused (``PoolSaturated``) while ``interactive`` still
    gets in, and backpressure surfaces to the ``submit()`` caller;
  * ``PartitionPlan.save()/load()`` is a working warm-start path across
    executors: shape-compatible plans replay with 0 new compilations, and
    a padding mismatch means a clean recompile, never a shape error;
  * the plan-cache-hit flag on ``DistHooiStats`` is per-call-correct
    under concurrent submitters (thread-local, not global-counter diffs).
"""

import io
import threading

import numpy as np
import pytest

import _chaos
from repro.core.coo import SparseTensor
from repro.core.plan import PartitionPlan, plan as build_plan
from repro.engine import ExecutorPool, PoolSaturated, StreamRouter
from repro.streaming import StreamingTensor

CORE = (2, 2, 2)
SHAPE = (24, 18, 15)

pytestmark = pytest.mark.slow


def _tensor(seed, nnz=250):
    r = np.random.default_rng(seed)
    coords = np.stack([r.integers(0, L, nnz) for L in SHAPE], axis=1)
    return SparseTensor(coords, r.standard_normal(nnz), SHAPE).dedup()


def _stream(seed, name=None):
    return StreamingTensor.from_tensor(
        _tensor(seed), name=name or f"s{seed}")


@pytest.fixture
def pool():
    with ExecutorPool(2, 2, CORE, workers=2, n_invocations=1,
                      pad_geometric=True) as p:
        yield p


def _alive_pipeline_threads():
    return [th for th in threading.enumerate()
            if th.is_alive() and th.name.startswith(("sched-prepare",
                                                     "sched-run"))]


# ------------------------------------------------------------ routing
def test_routing_spreads_lanes_and_aggregates_stats(pool):
    router = StreamRouter(pool, max_pending=32)
    streams = [_stream(i) for i in range(4)]
    for s in streams:
        router.submit(s, deadline_s=120.0)
    first = router.drain()

    lanes = [r.stats.lane for r in first]
    assert set(lanes) == {0, 1}  # least-loaded routing uses both lanes
    assert all(r.slo_met for r in first)

    # resubmits are sticky: same lane, warm ladder
    for s in streams:
        router.submit(s)
    again = router.drain()
    assert [r.stats.lane for r in again] == lanes
    assert all(r.decision == "reuse" for r in again)
    assert all(r.stats.step_compilations == 0 for r in again)

    st = router.stats()
    assert st.n_lanes == 2
    assert st.submitted == 8 and st.completed == 8 and st.failed == 0
    assert st.slo_hit == 4 and st.slo_miss == 0
    assert st.decisions == {"plan": 4, "reuse": 4}
    assert len(st.lane_stats) == 2 and len(st.lane_executors) == 2
    assert sum(ls["completed"] for ls in st.lane_stats) == 8
    assert st.backlog_s == (0.0, 0.0)  # everything drained
    assert st.as_dict()["n_lanes"] == 2
    router.close()


# -------------------------------------------------- concurrency stress
def test_many_threads_many_streams_with_failures(pool):
    """10 streams from 4 threads into the 2-lane pool, two streams' first
    prepares killed: every submit gets exactly one drain entry, healthy
    lanes' caches stay warm, and close() leaks no pipeline threads."""
    n_streams, per_stream = 10, 2
    streams = [_stream(100 + i) for i in range(n_streams)]
    chaos_victims = streams[:2]
    fault = _chaos.FaultPlan()
    for v in chaos_victims:
        fault.at(v.snapshot().fingerprint(), "prepare", _chaos.kill())

    router = StreamRouter(pool, max_pending=64)
    injections = [
        _chaos.inject(lane.executor, fault) for lane in pool.lanes]
    for inj in injections:
        inj.__enter__()
    try:
        errs = []

        def worker(chunk):
            try:
                for s in chunk:
                    for k in range(per_stream):
                        router.submit(s, seed=k, deadline_s=300.0)
            except Exception as e:  # pragma: no cover - fails the test
                errs.append(e)

        threads = [threading.Thread(target=worker, args=(streams[i::4],))
                   for i in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errs

        out = router.drain(return_exceptions=True)
    finally:
        for inj in injections:
            inj.__exit__(None, None, None)

    assert len(out) == n_streams * per_stream  # one entry per submit
    failures = [r for r in out if isinstance(r, Exception)]
    assert len(failures) == 2
    assert all(isinstance(e, _chaos.ChaosError) for e in failures)

    # killed streams recovered during the stress itself (their second
    # submit re-planned after the killed first one never adopted state),
    # so they are warm now; healthy streams' caches were never poisoned
    for v in chaos_victims:
        r = router.submit(v).result()
        assert r.decision == "reuse"
    healthy = router.submit(streams[5]).result()
    assert healthy.decision == "reuse"
    assert healthy.stats.step_compilations == 0
    assert healthy.stats.uploads == 0

    st = router.stats()
    assert st.failed == 2
    assert st.completed == n_streams * per_stream - 2 + 3
    assert st.slo_hit >= n_streams * per_stream - 2  # deadlines were generous

    router.close()  # closes the pool's lanes too
    leftover = _alive_pipeline_threads()
    assert not leftover, leftover
    with pytest.raises(RuntimeError):
        router.submit(streams[0])


# -------------------------------------------- admission / backpressure
def test_admission_shares_and_backpressure():
    """Behind a held sweep, the bounded queue fills: batch is refused
    first, normal next, interactive last — and the refusal is an
    exception to the submitter, not silent buffering."""
    gate = threading.Event()
    held = _tensor(200)
    fault = _chaos.FaultPlan().at(held.fingerprint(), "run",
                                  _chaos.hold(gate))
    with ExecutorPool(1, 2, CORE, workers=2, n_invocations=1) as pool:
        router = StreamRouter(pool, max_pending=4)
        try:
            with _chaos.inject(pool.lanes[0].executor, fault):
                router.submit(held, priority="interactive")  # inflight 1
                router.submit(_tensor(201), priority="normal")  # 2
                # batch share: 0.5 * 4 = 2 -> full
                with pytest.raises(PoolSaturated) as exc:
                    router.submit(_tensor(202), priority="batch")
                assert exc.value.priority == "batch"
                assert exc.value.pending == 2 and exc.value.limit == 2
                # normal share: 0.85 * 4 -> 3; one more fits, then refused
                router.submit(_tensor(203), priority="normal")  # 3
                with pytest.raises(PoolSaturated):
                    router.submit(_tensor(204), priority="normal")
                # interactive may use the full queue
                router.submit(_tensor(205), priority="interactive")  # 4
                with pytest.raises(PoolSaturated):
                    router.submit(_tensor(206), priority="interactive")
                assert router.pending() == 4
                gate.set()  # release the held sweep; queue drains
                res = router.drain()
            assert len(res) == 4
            st = router.stats()
            assert st.rejected == 3
            assert st.rejected_by_priority == {
                "batch": 1, "normal": 1, "interactive": 1}
            assert st.completed == 4 and st.failed == 0
        finally:
            gate.set()
            router.close()


# ----------------------------------------------------- warm-start path
def test_warm_start_save_load_zero_jit_across_executors():
    """A plan serialized on executor A replays on executor B with 0 new
    compilations when B has already compiled shape-compatible steps
    (pad_geometric quantizes the padded shapes)."""
    from repro.distributed.executor import HooiExecutor

    t = _tensor(300)
    ex_a, ex_b = HooiExecutor(2), HooiExecutor(2)

    pl_a, _ = ex_a.prepare(t, CORE, "lite", pad_geometric=True)
    ex_a.run(t, CORE, pl_a, n_invocations=1)

    # warm B with a *different* tensor sharing coords (lite policies are
    # coordinate-only, so partitions — and padded shapes — are identical)
    warmup = SparseTensor(t.coords, t.values * 2.0 + 1.0, SHAPE)
    pl_w, _ = ex_b.prepare(warmup, CORE, "lite", pad_geometric=True)
    _, w_stats = ex_b.run(warmup, CORE, pl_w, n_invocations=1)
    assert w_stats.step_compilations > 0  # B really did its own jit

    # the warm-start path: save on A, load against the tensor, run on B
    buf = io.BytesIO()
    pl_a.save(buf)
    pl_loaded = PartitionPlan.load(io.BytesIO(buf.getvalue()), t)
    ex_b.stage_upload(pl_loaded, t)
    dec_b, stats_b = ex_b.run(t, CORE, pl_loaded, n_invocations=1)
    assert stats_b.step_compilations == 0  # 0 new jit across executors
    assert stats_b.uploads == 0  # staged ahead of the hot path

    # same plan, same seed => identical trajectory as executor A
    _, stats_a = ex_a.run(t, CORE, pl_a, n_invocations=1)
    assert stats_a.fits == stats_b.fits


def test_warm_start_pad_mismatch_recompiles_cleanly():
    """A tight-padded (pad_geometric=False) plan landing on an executor
    warmed with geometric pads is a cache miss, not a shape error."""
    from repro.distributed.executor import HooiExecutor

    t = _tensor(301)
    ex_a, ex_b = HooiExecutor(2), HooiExecutor(2)

    # B compiled geometric shapes only
    pl_geo, _ = ex_b.prepare(t, CORE, "lite", pad_geometric=True)
    ex_b.run(t, CORE, pl_geo, n_invocations=1)

    pl_tight, _ = ex_a.prepare(t, CORE, "lite", pad_geometric=False)
    buf = io.BytesIO()
    pl_tight.save(buf)
    pl_loaded = PartitionPlan.load(io.BytesIO(buf.getvalue()), t)
    _, stats = ex_b.run(t, CORE, pl_loaded, n_invocations=1)
    assert stats.step_compilations > 0  # clean recompile for new shapes
    assert np.isfinite(stats.fits[-1])

    # a stale plan (tensor changed) is refused with a clear error
    other = _tensor(302)
    with pytest.raises(ValueError, match="fingerprint|built for"):
        PartitionPlan.load(io.BytesIO(buf.getvalue()), other)


def test_router_reroute_is_a_warm_start(pool):
    """reroute() moves a stream between lanes and its next run replays as
    ``reuse`` with 0 new uploads on the target (plan carried via
    save()/load(), staged on adopt)."""
    router = StreamRouter(pool, max_pending=16)
    s = _stream(400)
    first = router.submit(s).result()
    home = first.stats.lane

    new_lane = router.reroute(s)
    assert new_lane != home
    r = router.submit(s).result()
    assert r.stats.lane == new_lane
    assert r.decision == "reuse"
    assert r.stats.uploads == 0  # adopt staged the loaded plan's arrays
    assert router.stats().rerouted == 1
    router.close()


# ------------------------------------- plan-cache-hit flag thread-safety
def test_plan_cache_hit_flag_is_per_thread():
    """Two threads build *different* cold plans simultaneously: neither
    may observe the other's activity as its own cache hit (the old
    global-counter diff misreported exactly this interleaving)."""
    from repro.core.plan import last_plan_call_cache_hit, plan_cache_clear

    plan_cache_clear()
    barrier = threading.Barrier(2)
    results = {}

    def build(key, seed):
        t = _tensor(500 + seed, nnz=150)
        barrier.wait()
        build_plan(t, "lite", 2, core_dims=CORE)
        cold = last_plan_call_cache_hit()
        build_plan(t, "lite", 2, core_dims=CORE)
        warm = last_plan_call_cache_hit()
        results[key] = (cold, warm)

    threads = [threading.Thread(target=build, args=(k, k)) for k in (0, 1)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert results[0] == (False, True)
    assert results[1] == (False, True)


def test_executor_counters_consistent_under_concurrent_submit():
    """Concurrent runs on one executor keep stats()/calibration_samples()
    internally consistent: counter totals equal the per-call tallies."""
    from repro.distributed.executor import HooiExecutor

    ex = HooiExecutor(2)
    tensors = [_tensor(600 + i, nnz=180) for i in range(4)]
    out = [None] * len(tensors)

    def run(i):
        _, st = ex.run(tensors[i], CORE, "lite", n_invocations=1)
        out[i] = st

    threads = [threading.Thread(target=run, args=(i,))
               for i in range(len(tensors))]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    st = ex.stats()
    assert st["step_compilations"] == sum(s.step_compilations for s in out)
    assert st["uploads"] == sum(s.uploads for s in out)
    assert len(ex.calibration_samples()) == len(tensors)
    # every per-call delta is sane (no negative/other-thread bleed)
    assert all(s.step_compilations >= 0 and s.uploads >= 0 for s in out)
