"""StreamScheduler: pipelined multi-tensor serving on one executor.

These run real (small) distributed decompositions on the 8 simulated host
devices from conftest, so they carry the ``slow`` marker like the executor
suite. The contracts under test:

  * device runs happen in submission order and match a direct
    ``HooiExecutor.run`` on the same plan bit-for-bit (the scheduler adds
    pipelining, not math);
  * the streaming refresh ladder — reuse / repartition / reselect — with
    the rerun contract (0 new compilations, 0 new uploads) extended to
    the scheduler path, and distribution-preserving appends keeping the
    selected scheme with 0 new compilations (geometric pads);
  * producer failures surface on the job's future without wedging the
    pipeline.
"""

import numpy as np
import pytest

from repro.core.coo import SparseTensor
from repro.streaming import StreamingTensor

CORE = (2, 2, 2)


@pytest.fixture
def executor():
    from repro.distributed.executor import HooiExecutor

    return HooiExecutor(4)


@pytest.fixture
def scheduler(executor):
    from repro.engine.scheduler import StreamScheduler

    with StreamScheduler(executor, CORE, n_invocations=1,
                         workers=2) as sched:
        yield sched


@pytest.mark.slow
def test_pipeline_preserves_order_and_trajectories(scheduler, executor,
                                                   lowrank_tensor,
                                                   small_tensor):
    futs = [scheduler.submit(lowrank_tensor, name="a", seed=0),
            scheduler.submit(small_tensor, name="b", seed=1)]
    res = scheduler.drain()
    assert [r.name for r in res] == ["a", "b"]
    assert [r.seq for r in res] == [0, 1]
    assert all(r.decision == "plan" for r in res)
    assert futs[0].result() is res[0]
    # the scheduler is pipelining, not changing math: a direct run on the
    # same plan and seed reproduces the fit trajectory exactly
    _, direct = executor.run(lowrank_tensor, CORE, res[0].plan,
                             n_invocations=1, seed=0)
    assert direct.fits == res[0].fits
    st = scheduler.stats()
    assert st["completed"] == 2 and st["failed"] == 0
    assert st["host_s"] > 0 and st["device_s"] > 0 and st["wall_s"] > 0
    assert st["decisions"] == {"plan": 2}


@pytest.mark.slow
def test_streaming_refresh_ladder(scheduler, small_tensor):
    rng = np.random.default_rng(0)
    t = small_tensor
    stream = StreamingTensor.from_tensor(t, name="s")

    first = scheduler.submit(stream, seed=0).result()
    assert first.decision == "plan"
    assert first.stream_version == 1
    assert first.stats.stream_decision == "plan"

    # rerun on the unchanged stream: same plan object, fully cached run
    rerun = scheduler.submit(stream, seed=1).result()
    assert rerun.decision == "reuse"
    assert rerun.plan is first.plan
    assert rerun.stats.step_compilations == 0
    assert rerun.stats.uploads == 0
    assert rerun.stats.upload_cache_hit

    # value updates at existing coordinates preserve the distribution:
    # the scheme survives (no re-selection) and — thanks to geometric
    # pads — so do the compiled shapes
    idx = rng.integers(0, t.nnz, 25)
    stream.append(t.coords[idx], rng.standard_normal(25) * 0.1)
    upd = scheduler.submit(stream, seed=2).result()
    assert upd.decision == "repartition"
    assert upd.stats.stream_decision == "repartition"
    assert upd.plan is not first.plan
    assert upd.plan.candidates is None  # auto selection did NOT rerun
    assert upd.plan.scheme.name == first.plan.scheme.name
    assert upd.stats.step_compilations == 0
    assert upd.stats.uploads == 0  # staged off the hot path by the producer
    assert upd.drift is not None and upd.drift["worst"] <= 1.25

    # rerun after the append: the refreshed plan is now the cached one
    rerun2 = scheduler.submit(stream, seed=3).result()
    assert rerun2.decision == "reuse"
    assert rerun2.plan is upd.plan
    assert rerun2.stats.step_compilations == 0
    assert rerun2.stats.uploads == 0

    # a hub append skews mode loads past the tolerance -> full re-selection
    hub = np.tile(t.coords[0], (4 * t.nnz, 1))
    stream.append(hub, rng.standard_normal(4 * t.nnz))
    skew = scheduler.submit(stream, seed=4).result()
    assert skew.decision == "reselect"
    assert skew.drift["worst"] > 1.25
    assert skew.plan.candidates is not None  # auto selector ran again
    assert skew.stats.stream_drift == skew.drift


@pytest.mark.slow
def test_producer_failure_does_not_wedge_pipeline(scheduler,
                                                  lowrank_tensor):
    bad = SparseTensor(np.zeros((1, 2), dtype=np.int64), np.ones(1),
                       (3, 3))  # 2-D: plan() must reject CORE of length 3
    f_bad = scheduler.submit(bad, name="bad")
    f_ok = scheduler.submit(lowrank_tensor, name="ok", seed=0)
    # drain with return_exceptions keeps the batch's good results: the
    # failure appears in-place instead of aborting the collection
    res = scheduler.drain(return_exceptions=True)
    assert isinstance(res[0], ValueError)
    assert res[1].fits  # pipeline advanced past the failure
    with pytest.raises(ValueError):
        f_bad.result()
    st = scheduler.stats()
    assert st["failed"] == 1 and st["completed"] == 1


@pytest.mark.slow
def test_cancelled_future_does_not_wedge_pipeline(scheduler,
                                                  lowrank_tensor,
                                                  small_tensor):
    """Future.cancel() on a pending job must not kill the worker threads:
    later submissions still complete and the counters stay consistent."""
    f1 = scheduler.submit(lowrank_tensor, name="a", seed=0)
    f2 = scheduler.submit(small_tensor, name="b", seed=1)
    cancelled = f2.cancel()  # may lose the race; both outcomes are legal
    f3 = scheduler.submit(lowrank_tensor, name="c", seed=2)
    assert f1.result().fits
    assert f3.result().fits  # the consumer survived the cancellation
    st = scheduler.stats()
    if cancelled:
        assert f2.cancelled()
        assert st["completed"] == 2 and st["failed"] == 1
    else:
        assert f2.result().fits
        assert st["completed"] == 3 and st["failed"] == 0


@pytest.mark.slow
def test_submit_after_close_raises(executor, lowrank_tensor):
    from repro.engine.scheduler import StreamScheduler

    sched = StreamScheduler(executor, CORE, n_invocations=1)
    sched.close()
    with pytest.raises(RuntimeError, match="closed"):
        sched.submit(lowrank_tensor)
