"""PartitionPlan subsystem: theorem bounds, auto selection, cache contract,
and the differential test against single-process HOOI.

The in-process distributed tests rely on conftest.py setting 8 simulated
host devices before jax initializes.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.coo import SparseTensor
from repro.core.distribution import build_scheme
from repro.core.plan import (
    AUTO_CANDIDATES,
    PartitionPlan,
    load_plan,
    plan,
    plan_cache_clear,
    plan_cache_stats,
)


def _rand_tensor(seed, N=3, Lmax=40, nnz=300):
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(2, Lmax)) for _ in range(N))
    coords = np.stack([rng.integers(0, L, nnz) for L in shape], axis=1)
    return SparseTensor(coords, rng.standard_normal(nnz), shape).dedup()


# -------------------------------------------------- Theorem 6 via plan()
@pytest.mark.parametrize("seed", range(12))
def test_lite_plan_theorem_bounds(seed):
    """Theorem 6.1 on plans: E_max <= ceil(nnz/P), R_sum <= L+P,
    R_max <= ceil(L/P)+2 — checked through the plan layer so the cached
    metrics are what is verified."""
    rng = np.random.default_rng(seed)
    P = int(rng.integers(1, 24))
    N = int(rng.integers(2, 5))
    nnz = int(rng.integers(1, 600))
    t = _rand_tensor(seed + 1000, N=N, Lmax=int(rng.integers(3, 60)), nnz=nnz)
    pl = plan(t, "lite", P, use_cache=False)
    limit = -(-t.nnz // P)
    for n, m in enumerate(pl.metrics.per_mode):
        assert m.E_max <= limit, f"mode {n}: E_max {m.E_max} > {limit}"
        assert m.R_sum <= t.shape[n] + P
        assert m.R_max <= -(-t.shape[n] // P) + 2


# ------------------------------------------------------------ auto scheme
@pytest.mark.parametrize("seed", range(8))
def test_auto_never_worse_than_candidates(seed):
    t = _rand_tensor(seed, nnz=400)
    P = 8
    auto = plan(t, "auto", P, use_cache=False)
    assert auto.name in AUTO_CANDIDATES
    assert set(auto.candidates) == set(AUTO_CANDIDATES)
    for cand in AUTO_CANDIDATES:
        cp = plan(t, cand, P, use_cache=False)
        assert auto.cost.total_s <= cp.cost.total_s + 1e-15, (
            f"auto picked {auto.name} ({auto.cost.total_s}) but {cand} "
            f"models cheaper ({cp.cost.total_s})")
    # the recorded candidate scores agree with independently built plans
    assert auto.cost.total_s == min(auto.candidates.values())


def test_auto_picks_lite_on_skewed_tensor(skewed_tensor):
    """The paper's regime: a hub slice makes CoarseG collapse on E_max, so
    the selector must not pick it."""
    auto = plan(skewed_tensor, "auto", 16, use_cache=False)
    assert auto.name != "coarse"
    coarse = plan(skewed_tensor, "coarse", 16, use_cache=False)
    assert auto.cost.total_s < coarse.cost.total_s


def test_build_scheme_auto_returns_winner(small_tensor):
    s = build_scheme(small_tensor, "auto", 8)
    auto = plan(small_tensor, "auto", 8)
    assert s.name == auto.name
    assert s is auto.scheme


# ------------------------------------------------------------- plan cache
def test_cache_hit_returns_identical_object(small_tensor):
    plan_cache_clear()
    p1 = plan(small_tensor, "lite", 8)
    p2 = plan(small_tensor, "lite", 8)
    assert p1 is p2
    a1 = plan(small_tensor, "auto", 8)
    a2 = plan(small_tensor, "auto", 8)
    assert a1 is a2
    stats = plan_cache_stats()
    assert stats["hits"] >= 2
    # auto shares the lite candidate with the direct lite call
    assert plan(small_tensor, a1.name, 8).scheme is a1.scheme


def test_cache_is_content_keyed(small_tensor):
    """A structurally identical tensor (different arrays) hits the cache."""
    clone = SparseTensor(small_tensor.coords.copy(),
                         small_tensor.values.copy(), small_tensor.shape)
    assert clone is not small_tensor
    assert plan(small_tensor, "lite", 8) is plan(clone, "lite", 8)


def test_cache_discriminates_parameters(small_tensor):
    base = plan(small_tensor, "lite", 8)
    assert plan(small_tensor, "lite", 4) is not base
    assert plan(small_tensor, "lite", 8, core_dims=(4, 4, 4)) is not base
    assert plan(small_tensor, "lite", 8, path="baseline") is not base
    assert plan(small_tensor, "coarse", 8) is not base
    assert plan(small_tensor, "lite", 8, use_cache=False) is not base
    # content change -> different entry
    other = SparseTensor(small_tensor.coords,
                         small_tensor.values * 2.0, small_tensor.shape)
    assert plan(other, "lite", 8) is not base


def test_plan_from_prebuilt_scheme(small_tensor):
    # content keying means an equal-content scheme planned by an *earlier*
    # test would own the cached plan's .scheme — clear for determinism
    plan_cache_clear()
    s = build_scheme(small_tensor, "medium", 8)
    pl = plan(small_tensor, s, 8)
    assert isinstance(pl, PartitionPlan)
    assert pl.scheme is s
    assert pl.nmodes == small_tensor.ndim
    assert plan(small_tensor, s, 8) is pl  # cached by scheme content


def test_prebuilt_scheme_keyed_on_content_not_id(small_tensor):
    """Regression: plan() used to key prebuilt schemes on ``id(scheme)`` —
    equal-content rebuilt schemes missed the cache, and worse, a GC'd
    scheme's reused id could hand a *different* scheme the old plan."""
    s1 = build_scheme(small_tensor, "lite", 8)
    s2 = build_scheme(small_tensor, "lite", 8)  # equal content, new object
    assert s1 is not s2
    assert s1.content_key() == s2.content_key()
    assert plan(small_tensor, s1, 8) is plan(small_tensor, s2, 8)
    s3 = build_scheme(small_tensor, "coarse", 8)
    assert s3.content_key() != s1.content_key()
    assert plan(small_tensor, s3, 8) is not plan(small_tensor, s1, 8)


def test_prebuilt_scheme_id_reuse_not_aliased(small_tensor):
    """Build a plan, drop its scheme, rebuild *different* schemes until
    CPython hands one the dead scheme's id — the cache must not serve the
    stale plan to the impostor (the old id-keyed code did)."""
    import gc

    plan_cache_clear()  # equal-content plans from other tests would alias
    s1 = build_scheme(small_tensor, "lite", 8)
    p1 = plan(small_tensor, s1, 8)
    dead_id = id(s1)
    del s1
    aliased = None
    for seed in range(200):
        gc.collect()
        cand = build_scheme(small_tensor, "medium", 8, seed=seed)
        if id(cand) == dead_id:
            aliased = cand
            break
        del cand
    if aliased is None:
        pytest.skip("CPython did not reuse the scheme id in 200 attempts")
    p2 = plan(small_tensor, aliased, 8)
    assert p2 is not p1
    assert p2.scheme is aliased
    assert p2.name == "medium"


def test_plan_cost_is_deterministic(small_tensor):
    c1 = plan(small_tensor, "lite", 8, use_cache=False).cost
    c2 = plan(small_tensor, "lite", 8, use_cache=False).cost
    assert dataclasses.asdict(c1) == dataclasses.asdict(c2)
    assert c1.total_s == c1.flops_s + c1.comm_s
    assert c1.total_s > 0


def test_plan_validates_inputs(small_tensor):
    with pytest.raises(ValueError):
        plan(small_tensor, "lite", 8, path="bogus")
    with pytest.raises(ValueError):
        plan(small_tensor, "lite", 8, core_dims=(4, 4))
    with pytest.raises(ValueError):
        plan(small_tensor, "no-such-scheme", 8)


def test_fingerprint_stability(small_tensor):
    fp1 = small_tensor.fingerprint()
    clone = SparseTensor(small_tensor.coords.copy(),
                         small_tensor.values.copy(), small_tensor.shape)
    assert fp1 == clone.fingerprint()
    other = SparseTensor(small_tensor.coords,
                         small_tensor.values + 1.0, small_tensor.shape)
    assert fp1 != other.fingerprint()


def test_plan_cache_lru_hit_survives_eviction(small_tensor, monkeypatch):
    """Eviction is LRU, not FIFO: a recently-hit plan outlives an older
    insertion when the cache overflows."""
    import repro.core.plan as planmod

    plan_cache_clear()
    monkeypatch.setattr(planmod, "CACHE_MAX_ENTRIES", 2)
    p1 = plan(small_tensor, "lite", 2)
    p2 = plan(small_tensor, "lite", 3)
    assert plan(small_tensor, "lite", 2) is p1  # hit -> p1 becomes MRU
    plan(small_tensor, "lite", 4)  # overflow evicts LRU = p2, not p1
    assert plan_cache_stats()["size"] == 2
    assert plan(small_tensor, "lite", 2) is p1  # survived
    assert plan(small_tensor, "lite", 3) is not p2  # evicted -> rebuilt
    plan_cache_clear()


# -------------------------------------------------------------- persistence
def test_plan_save_load_roundtrip(small_tensor, tmp_path):
    """save()/load() preserves the scheme, every padded partition array,
    the §4 metrics, and the modeled cost."""
    p = plan(small_tensor, "auto", 8, core_dims=(4, 4, 4))
    f = str(tmp_path / "plan.npz")
    p.save(f)
    q = PartitionPlan.load(f, small_tensor)
    assert q is not p
    assert q.name == p.name and q.P == p.P
    assert q.core_dims == p.core_dims
    assert q.scheme.uni == p.scheme.uni
    assert q.candidates == p.candidates
    assert q.fingerprint == small_tensor.fingerprint()
    assert dataclasses.asdict(q.cost) == dataclasses.asdict(p.cost)
    assert dataclasses.asdict(q.metrics) == dataclasses.asdict(p.metrics)
    for mq, mp_ in zip(q.parts, p.parts):
        for fld in dataclasses.fields(mp_):
            a, b = getattr(mq, fld.name), getattr(mp_, fld.name)
            if isinstance(b, np.ndarray):
                assert np.array_equal(a, b), fld.name
                assert a.dtype == b.dtype, fld.name
            else:
                assert a == b, fld.name


def test_plan_load_rejects_fingerprint_mismatch(small_tensor, tmp_path):
    p = plan(small_tensor, "lite", 8)
    f = str(tmp_path / "plan.npz")
    p.save(f)
    other = SparseTensor(small_tensor.coords,
                         small_tensor.values * 3.0, small_tensor.shape)
    with pytest.raises(ValueError, match="refusing to apply a stale plan"):
        PartitionPlan.load(f, other)
    # load_plan alias + uni-policy scheme round-trips too
    u = plan(small_tensor, "medium", 8)
    u.save(f)
    q = load_plan(f, small_tensor)
    assert q.scheme.uni
    assert q.scheme.policy(0) is q.scheme.policy(1)  # one stored copy


# ------------------------------------------------- differential (in-process)
@pytest.mark.slow
@pytest.mark.parametrize("path", ["baseline", "liteopt"])
def test_dist_hooi_plan_matches_reference(path, lowrank_tensor):
    """On an exactly rank-(2,2,2) tensor, dist_hooi through a prebuilt auto
    plan reaches the same (near-1) final fit as single-process hooi, ±1e-3,
    and matches the string-API path."""
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs 4 simulated devices (conftest sets XLA_FLAGS)")
    from repro.core.hooi import hooi
    from repro.distributed.dist_hooi import dist_hooi

    t = lowrank_tensor
    core = (2, 2, 2)
    P = 4
    _, fits_ref = hooi(t, core, n_invocations=4, seed=0)

    pl = plan(t, "auto", P, core_dims=core, path=path)
    _, st_plan = dist_hooi(t, core, P, scheme=pl, n_invocations=4,
                           path=path, seed=0)
    _, st_str = dist_hooi(t, core, P, scheme="auto", n_invocations=4,
                          path=path, seed=0)

    assert st_plan.scheme == pl.name
    assert fits_ref[-1] > 0.99  # both implementations must nail exact rank
    assert abs(st_plan.fits[-1] - fits_ref[-1]) < 1e-3, (
        st_plan.fits, fits_ref)
    # string API resolves to the same cached plan -> identical run
    assert abs(st_str.fits[-1] - st_plan.fits[-1]) < 1e-6
    assert st_str.plan_cache_hit  # plan was already cached above


@pytest.mark.slow
def test_dist_hooi_reports_selection_and_cache(lowrank_tensor):
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs 4 simulated devices (conftest sets XLA_FLAGS)")
    from repro.distributed.dist_hooi import dist_hooi

    plan_cache_clear()
    t = lowrank_tensor
    _, s1 = dist_hooi(t, (2, 2, 2), 4, scheme="auto", n_invocations=1, seed=0)
    assert s1.scheme in AUTO_CANDIDATES
    assert set(s1.selection) == set(AUTO_CANDIDATES)
    assert not s1.plan_cache_hit
    _, s2 = dist_hooi(t, (2, 2, 2), 4, scheme="auto", n_invocations=1, seed=1)
    assert s2.plan_cache_hit
    # cached partitioning must be effectively free (acceptance criterion)
    assert s2.partition_build_s < 0.05
    assert s2.partition_build_s < s1.partition_build_s
