"""HooiExecutor engine: compiled-step + device-upload reuse across runs,
tensors, and processes (loaded plans); plan_seed threading; wrapper compat.

In-process multi-device tests rely on conftest.py setting 8 simulated host
devices before jax initializes.
"""

import numpy as np
import pytest

from repro.core.coo import SparseTensor
from repro.core.plan import PartitionPlan, plan, plan_cache_clear, \
    plan_cache_stats


def _need_devices(n):
    import jax

    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} simulated devices (conftest sets XLA_FLAGS)")


@pytest.fixture
def executor():
    _need_devices(4)
    from repro.distributed.executor import HooiExecutor

    return HooiExecutor(4)


# ------------------------------------------------------------ cache layers
@pytest.mark.slow
def test_second_run_zero_compilations_zero_uploads(executor, lowrank_tensor):
    """Acceptance: a rerun on a cached plan touches neither jit nor PCIe."""
    t = lowrank_tensor
    pl = plan(t, "lite", 4, core_dims=(2, 2, 2))
    _, s1 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0)
    assert s1.step_compilations == t.ndim  # one XLA compile per mode
    assert s1.uploads == 9 * t.ndim + 2
    assert not s1.upload_cache_hit

    _, s2 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=1)
    assert s2.step_compilations == 0
    assert s2.uploads == 0
    assert s2.upload_cache_hit
    assert s2.step_cache_hits == t.ndim
    assert s2.executor["runs"] == 2
    assert s2.fits[-1] > 0.99  # still a correct decomposition


@pytest.mark.slow
def test_identical_padded_shapes_share_compiled_steps(executor,
                                                      lowrank_tensor):
    """Multi-tensor batching: a second tensor whose partitions pad to the
    same shapes reuses every compiled step (only its uploads are new)."""
    t1 = lowrank_tensor
    t2 = SparseTensor(t1.coords.copy(), (t1.values * 1.5).copy(), t1.shape)
    assert t1.fingerprint() != t2.fingerprint()

    _, s1 = executor.run(t1, (2, 2, 2), "lite", n_invocations=1, seed=0)
    assert s1.step_compilations == t1.ndim
    _, s2 = executor.run(t2, (2, 2, 2), "lite", n_invocations=1, seed=0)
    assert s2.step_compilations == 0  # same (path, pads, P, K, niter)
    assert s2.uploads == 9 * t2.ndim + 2  # its own arrays still move once
    # interleave again: both plans stay resident on the one mesh
    _, s3 = executor.run(t1, (2, 2, 2), "lite", n_invocations=1, seed=1)
    assert s3.step_compilations == 0 and s3.uploads == 0
    assert s3.executor["cached_plans"] == 2


@pytest.mark.slow
def test_loaded_plan_reuses_compiled_steps(executor, lowrank_tensor,
                                           tmp_path):
    """Cross-process persistence meets the engine: a save/load round-tripped
    plan skips partitioning AND jit; only its device upload is paid."""
    t = lowrank_tensor
    pl = plan(t, "lite", 4, core_dims=(2, 2, 2))
    _, s1 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0)
    path = str(tmp_path / "plan.npz")
    pl.save(path)
    loaded = PartitionPlan.load(path, t)
    assert loaded is not pl
    _, s2 = executor.run(t, (2, 2, 2), loaded, n_invocations=1, seed=0)
    assert s2.step_compilations == 0  # identical padded shapes -> shared jit
    assert s2.uploads == 9 * t.ndim + 2  # new object -> one upload
    assert abs(s2.fits[-1] - s1.fits[-1]) < 1e-6  # same plan, same run


@pytest.mark.slow
def test_auto_plan_shares_upload_with_winner_candidate(executor,
                                                       lowrank_tensor):
    """An auto plan is a replace-copy of its winning candidate sharing the
    same parts tuple — the device arrays must go up (and stay resident)
    once, not twice."""
    t = lowrank_tensor
    _, s1 = executor.run(t, (2, 2, 2), "auto", n_invocations=1, seed=0)
    assert s1.uploads == 9 * t.ndim + 2
    # the concrete winner scheme resolves to the cached candidate object,
    # whose parts are identical to the auto plan's
    _, s2 = executor.run(t, (2, 2, 2), s1.scheme, n_invocations=1, seed=1)
    assert s2.uploads == 0
    assert s2.upload_cache_hit
    assert s2.step_compilations == 0


def test_compiled_step_cache_is_bounded(monkeypatch):
    """The jitted-executable cache on a long-lived executor is LRU-bounded;
    evicting a step also forgets its shape signatures so a re-created
    callable recounts its compilations."""
    _need_devices(4)
    import repro.distributed.executor as exmod

    ex = exmod.HooiExecutor(4)
    monkeypatch.setattr(exmod, "MAX_COMPILED_STEPS", 2)

    class FakeMP:  # only the static-signature fields are read before a call
        P = 4

        def __init__(self, mode):
            self.mode, self.R_pad, self.Lp, self.S_pad = mode, 8, 3, 1

    k0, s0 = ex._get_step(FakeMP(0), "liteopt", 2)
    ex._seen_shapes.add((k0, ("fake",)))
    k1, _ = ex._get_step(FakeMP(1), "liteopt", 2)
    assert ex._get_step(FakeMP(0), "liteopt", 2)[1] is s0  # hit -> MRU
    k2, _ = ex._get_step(FakeMP(2), "liteopt", 2)  # evicts k1 (LRU), not k0
    assert len(ex._steps) == 2
    assert k0 in ex._steps and k2 in ex._steps and k1 not in ex._steps
    assert ex._get_step(FakeMP(0), "liteopt", 2)[1] is s0  # survived
    assert (k0, ("fake",)) in ex._seen_shapes  # kept with its live step
    ex._get_step(FakeMP(3), "liteopt", 2)  # evicts k2; k0 is MRU
    ex._get_step(FakeMP(4), "liteopt", 2)  # now evicts k0
    assert k0 not in ex._steps
    assert (k0, ("fake",)) not in ex._seen_shapes  # purged with its step


# ------------------------------------------------------------- wrapper API
@pytest.mark.slow
def test_dist_hooi_wrapper_shares_engine(lowrank_tensor):
    """The historical entry point now amortizes across calls automatically."""
    _need_devices(4)
    from repro.distributed.dist_hooi import dist_hooi

    t = lowrank_tensor
    _, s1 = dist_hooi(t, (2, 2, 2), 4, scheme="lite", n_invocations=1, seed=0)
    _, s2 = dist_hooi(t, (2, 2, 2), 4, scheme="lite", n_invocations=1, seed=1)
    assert s2.plan_cache_hit
    assert s2.step_compilations == 0
    assert s2.uploads == 0
    assert s2.upload_cache_hit


@pytest.mark.slow
def test_plan_seed_threads_to_randomized_schemes(lowrank_tensor):
    """dist_hooi used to hardcode seed=0 into build_plan; plan_seed must
    reach the scheme constructor and discriminate the plan cache key."""
    _need_devices(4)
    from repro.distributed.dist_hooi import dist_hooi

    t = lowrank_tensor
    plan_cache_clear()
    _, s1 = dist_hooi(t, (2, 2, 2), 4, scheme="medium", n_invocations=1,
                      seed=0, plan_seed=0)
    assert not s1.plan_cache_hit
    # same plan_seed -> cache hit even though the factor seed changed
    _, s2 = dist_hooi(t, (2, 2, 2), 4, scheme="medium", n_invocations=1,
                      seed=1, plan_seed=0)
    assert s2.plan_cache_hit
    # different plan_seed -> distinct cache key, fresh partitioning
    misses = plan_cache_stats()["misses"]
    _, s3 = dist_hooi(t, (2, 2, 2), 4, scheme="medium", n_invocations=1,
                      seed=1, plan_seed=7)
    assert not s3.plan_cache_hit
    assert plan_cache_stats()["misses"] == misses + 1
    # the two seeds really produced different distributions
    p0 = plan(t, "medium", 4, core_dims=(2, 2, 2), seed=0)
    p7 = plan(t, "medium", 4, core_dims=(2, 2, 2), seed=7)
    assert p0 is not p7
    assert not np.array_equal(p0.scheme.policy(0), p7.scheme.policy(0))


@pytest.mark.slow
def test_executor_rejects_mismatched_plan(executor, lowrank_tensor):
    t = lowrank_tensor
    pl = plan(t, "lite", 2, core_dims=(2, 2, 2))
    with pytest.raises(ValueError, match="P=2"):
        executor.run(t, (2, 2, 2), pl, n_invocations=1)
    pl4 = plan(t, "lite", 4, core_dims=(2, 2, 2))
    # wrong tensor: the upload cache is plan-keyed, silently reusing the
    # original tensor's device arrays would corrupt the decomposition
    other = SparseTensor(t.coords.copy(), (t.values + 1.0).copy(), t.shape)
    with pytest.raises(ValueError, match="built for tensor"):
        executor.run(other, (2, 2, 2), pl4, n_invocations=1)
    with pytest.raises(ValueError, match="core_dims"):
        executor.run(t, (3, 3, 3), pl4, n_invocations=1)
    with pytest.raises(ValueError, match="path"):
        executor.run(t, (2, 2, 2), pl4, n_invocations=1, path="baseline")


# ------------------------------------------------------------- calibration
@pytest.mark.slow
def test_executor_records_calibration_samples(executor, lowrank_tensor):
    from repro.core.calibrate import fit_cost_model

    t = lowrank_tensor
    executor.run(t, (2, 2, 2), "lite", n_invocations=2, seed=0)
    executor.run(t, (2, 2, 2), "lite", n_invocations=1, seed=1)
    samples = executor.calibration_samples()
    assert len(samples) == 3
    assert all(s["seconds"] > 0 for s in samples)
    assert samples[0]["warm"] is False  # first sweep paid jit
    assert all(s["warm"] for s in samples[1:])
    cm = fit_cost_model(samples)
    assert cm.flop_rate > 0 and cm.source.startswith("fitted:")
