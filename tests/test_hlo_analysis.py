"""Trip-count-aware HLO analyzer vs known-FLOP programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def test_scan_flops_exact():
    L, N = 7, 128
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y
    comp = _compile(f, jax.ShapeDtypeStruct((N, N), jnp.float32),
                    jax.ShapeDtypeStruct((L, N, N), jnp.float32))
    st = analyze_hlo(comp.as_text())
    assert st.unknown_trip_loops == 0
    np.testing.assert_allclose(st.flops, 2 * N**3 * L, rtol=1e-6)


def test_nested_scan_flops_exact():
    L, inner, N = 5, 3, 64
    def g(x, ws):
        def outer(c, w):
            def body(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(body, c, None, length=inner)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y
    comp = _compile(g, jax.ShapeDtypeStruct((N, N), jnp.float32),
                    jax.ShapeDtypeStruct((L, N, N), jnp.float32))
    st = analyze_hlo(comp.as_text())
    np.testing.assert_allclose(st.flops, 2 * N**3 * L * inner, rtol=1e-6)


def test_plain_matmul_and_traffic():
    N = 256
    comp = _compile(lambda a, b: a @ b,
                    jax.ShapeDtypeStruct((N, N), jnp.float32),
                    jax.ShapeDtypeStruct((N, N), jnp.float32))
    st = analyze_hlo(comp.as_text())
    np.testing.assert_allclose(st.flops, 2 * N**3, rtol=1e-6)
    # traffic at least the three matrices
    assert st.traffic_bytes >= 3 * N * N * 4


def test_remat_counts_recompute():
    """jax.checkpoint recompute appears in backward -> more flops than fwd."""
    N = 64

    def fwd_only(x, w):
        return jnp.sum(jnp.tanh(x @ w) @ w)

    def with_grad(x, w):
        return jax.grad(
            lambda xx: jnp.sum(jax.checkpoint(
                lambda a: jnp.tanh(a @ w) @ w)(xx)))(x).sum()

    s = jax.ShapeDtypeStruct((N, N), jnp.float32)
    f1 = analyze_hlo(_compile(fwd_only, s, s).as_text()).flops
    f2 = analyze_hlo(_compile(with_grad, s, s).as_text()).flops
    # grad-only program: XLA DCEs the unused primal output, leaving
    # 1 recompute + 2 backward matmuls = 1.5x the forward's 2 matmuls
    assert f2 >= 1.4 * f1


def test_no_loops_no_unknown():
    comp = _compile(lambda x: x * 2 + 1,
                    jax.ShapeDtypeStruct((32, 32), jnp.float32))
    st = analyze_hlo(comp.as_text())
    assert st.unknown_trip_loops == 0
    assert st.flops == 0.0
