"""Roofline knobs: block (s-step) Lanczos, the fused Z-build→oracle
pipeline, and the bf16/fp32 mixed-precision contract — resolver policy,
convergence regressions, per-backend differentials, and the cached-step
rerun contract per variant.

In-process multi-device tests rely on conftest.py setting 8 simulated host
devices before jax initializes.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibrate import CostModel, fit_cost_model, set_cost_model
from repro.core.lanczos import (
    block_start_panel,
    effective_block_size,
    gk_block_bidiag,
    lanczos_niter,
    svd_from_bidiag,
)
from repro.engine import count_z_passes
from repro.engine.oracle import resolve_block_size, z_products
from repro.engine.zbuild import resolve_fused_zbuild, resolve_precision


def _need_devices(n):
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} simulated devices (conftest sets XLA_FLAGS)")


@pytest.fixture(autouse=True)
def _restore_cost_model():
    yield
    set_cost_model(None)


# ------------------------------------------------------------- resolvers
def test_resolve_precision_policy(monkeypatch):
    monkeypatch.delenv("REPRO_PRECISION", raising=False)
    assert resolve_precision(None) == "f32"
    assert resolve_precision("f32") == "f32"
    assert resolve_precision("bf16") == "bf16"
    assert resolve_precision("auto") == "f32"  # no calibrated bf16 rate
    with pytest.raises(ValueError):
        resolve_precision("fp64")
    monkeypatch.setenv("REPRO_PRECISION", "bf16")
    assert resolve_precision(None) == "bf16"
    assert resolve_precision("f32") == "f32"  # explicit beats env
    monkeypatch.setenv("REPRO_PRECISION", "half")
    with pytest.raises(ValueError):
        resolve_precision(None)
    monkeypatch.setenv("REPRO_PRECISION", "")  # empty string == unset
    assert resolve_precision(None) == "f32"


def test_resolve_precision_auto_consults_cost_model(monkeypatch):
    monkeypatch.delenv("REPRO_PRECISION", raising=False)
    set_cost_model(CostModel(ttm_flop_rate=1e9, ttm_flop_rate_bf16=2e9))
    assert resolve_precision("auto") == "bf16"
    set_cost_model(CostModel(ttm_flop_rate=1e9, ttm_flop_rate_bf16=1.01e9))
    assert resolve_precision("auto") == "f32"  # below the 5% margin
    # None never consults the model — only "auto" opts into the policy
    assert resolve_precision(None) == "f32"


def test_resolve_block_size_env(monkeypatch):
    monkeypatch.delenv("REPRO_LANCZOS_BLOCK", raising=False)
    assert resolve_block_size(None) == 1
    assert resolve_block_size(8) == 8
    monkeypatch.setenv("REPRO_LANCZOS_BLOCK", "4")
    assert resolve_block_size(None) == 4
    assert resolve_block_size(2) == 2  # explicit beats env
    monkeypatch.setenv("REPRO_LANCZOS_BLOCK", "")
    assert resolve_block_size(None) == 1
    with pytest.raises(ValueError):
        resolve_block_size(0)


def test_resolve_fused_zbuild_env(monkeypatch):
    monkeypatch.delenv("REPRO_FUSED_ZBUILD", raising=False)
    assert resolve_fused_zbuild(None) is False
    assert resolve_fused_zbuild(True) is True
    monkeypatch.setenv("REPRO_FUSED_ZBUILD", "1")
    assert resolve_fused_zbuild(None) is True
    assert resolve_fused_zbuild(False) is False  # explicit beats env


def test_vmem_budget_env_gate(monkeypatch):
    """REPRO_VMEM_BUDGET shrinks the admission gate; shapes over it fall
    back to the reference path through the ops wrapper."""
    from repro.core.hooi import random_factors
    from repro.core import ttm
    from repro.kernels import ops

    monkeypatch.delenv("REPRO_VMEM_BUDGET", raising=False)
    assert ops.vmem_budget_bytes() == ops._VMEM_BUDGET
    assert ops.kernel_fits_vmem(1000, 10, 10)
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")
    assert ops.vmem_budget_bytes() == 4096
    assert not ops.kernel_fits_vmem(1000, 10, 10)
    # the wrapper silently runs the reference under the shrunken budget
    rng = np.random.default_rng(8)
    coords = jnp.asarray(np.stack([rng.integers(0, 20, 60)] * 3, 1),
                         jnp.int32)
    values = jnp.asarray(rng.standard_normal(60), jnp.float32)
    factors = random_factors((20, 20, 20), (3, 3, 3), jax.random.PRNGKey(1))
    got = ops.penultimate_local(coords, values, coords[:, 0], factors, 0, 20,
                                use_kernel=True, interpret=True)
    want = ttm.penultimate_local(coords, values, coords[:, 0], factors, 0, 20)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "0")
    with pytest.raises(ValueError):
        ops.vmem_budget_bytes()


# -------------------------------------------------- block Lanczos algebra
def test_effective_block_size_clamps():
    # panel can never exceed min(2k, nrows, ncols)
    assert effective_block_size(2, 12, 4, 8) == 4
    assert effective_block_size(2, 3, 100, 8) == 3
    assert effective_block_size(10, 100, 100, 8) == 8
    assert effective_block_size(10, 100, 100, 1) == 1


def test_lanczos_niter_block_aware():
    base = lanczos_niter(10, 1000, 400)  # = 20
    assert base == 20
    assert lanczos_niter(10, 1000, 400, block_size=4) == 5
    assert lanczos_niter(10, 1000, 400, block_size=8) == 3  # ceil(20/8)
    assert lanczos_niter(10, 1000, 400, block_size=1) == base


def test_count_z_passes():
    assert count_z_passes(20) == 41            # vector: 1 write + 2/iter
    assert count_z_passes(20, True) == 40      # fused saves one read
    assert count_z_passes(3) == 7              # block-8: niter in blocks
    assert count_z_passes(3, True) == 6


@pytest.mark.parametrize("s", [4, 8])
def test_block_driver_matches_full_svd(s):
    """Block GK + svd_from_bidiag recovers the leading singular values of a
    well-conditioned dense operator at both panel widths."""
    key = jax.random.PRNGKey(7)
    m, n, k = 200, 60, 8
    u, _ = jnp.linalg.qr(jax.random.normal(key, (m, n)))
    v, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 9),
                                           (n, n)))
    spec = jnp.concatenate([10.0 * 0.5 ** jnp.arange(k),
                            1e-3 * jnp.ones(n - k)])
    Z = (u * spec) @ v
    mv, rmv = z_products(Z)
    niter = lanczos_niter(k, m, n, block_size=s)
    U, B = gk_block_bidiag(mv, rmv, m, n, niter, s,
                           jax.random.fold_in(key, 1))
    left, sv = svd_from_bidiag(U, B, k, jax.random.fold_in(key, 1))
    want = jnp.linalg.svd(Z, compute_uv=False)[:k]
    np.testing.assert_allclose(sv, want, rtol=1e-3)
    # left vectors orthonormal
    np.testing.assert_allclose(left.T @ left, np.eye(k), atol=1e-5)


def test_block_start_panel_orthonormal():
    P1 = block_start_panel(jax.random.PRNGKey(0), 37, 8)
    assert P1.shape == (37, 8)
    np.testing.assert_allclose(P1.T @ P1, np.eye(8), atol=1e-5)


def test_vector_query_budget_untouched(monkeypatch):
    """Env knobs resolve at the engine layer only: svd_via_lanczos keeps
    the historical 2*min(2k, m, n) oracle-query contract regardless."""
    from repro.core.lanczos import svd_via_lanczos

    monkeypatch.setenv("REPRO_LANCZOS_BLOCK", "8")
    monkeypatch.setenv("REPRO_FUSED_ZBUILD", "1")
    Z = jax.random.normal(jax.random.PRNGKey(3), (50, 20), jnp.float32)
    res = svd_via_lanczos(Z, 5, key=jax.random.PRNGKey(4))
    assert res.n_queries == 2 * min(2 * 5, 50, 20)


# ----------------------------------------------- convergence regressions
@pytest.mark.parametrize("s", [4, 8])
def test_hooi_block_convergence_parity(s, lowrank_tensor):
    """Regression pin: block Lanczos at s∈{4,8} must reach the vector
    path's fit on the exactly low-rank fixture (same final subspace)."""
    from repro.core.hooi import hooi

    t = lowrank_tensor
    _, fits_vec = hooi(t, (2, 2, 2), n_invocations=3, seed=0)
    _, fits_blk = hooi(t, (2, 2, 2), n_invocations=3, seed=0,
                       lanczos_block=s)
    assert fits_blk[-1] > 0.999
    assert abs(fits_blk[-1] - fits_vec[-1]) < 5e-3


def test_hooi_fused_zbuild_matches_plain(lowrank_tensor):
    """fused_zbuild only changes *where* the first product is computed —
    the reference-path trajectory is exactly the plain one."""
    from repro.core.hooi import hooi

    t = lowrank_tensor
    _, plain = hooi(t, (2, 2, 2), n_invocations=3, seed=0, lanczos_block=4)
    _, fused = hooi(t, (2, 2, 2), n_invocations=3, seed=0, lanczos_block=4,
                    fused_zbuild=True)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(fused))


def test_hooi_bf16_within_documented_bound(lowrank_tensor):
    """bf16 Z-build contributions: fit trajectory within the documented
    1e-2 bound of f32 and still converged on the low-rank fixture."""
    from repro.core.hooi import hooi

    t = lowrank_tensor
    _, f32 = hooi(t, (2, 2, 2), n_invocations=3, seed=0)
    _, bf16 = hooi(t, (2, 2, 2), n_invocations=3, seed=0, precision="bf16")
    assert bf16[-1] > 0.99
    assert max(abs(a - b) for a, b in zip(f32, bf16)) < 1e-2


# ------------------------------------- per-backend variant differentials
@pytest.mark.slow
@pytest.mark.parametrize("P,path,backend", [
    (1, "liteopt", "local"),
    (4, "baseline", "psum"),
    (4, "liteopt", "boundary"),
])
def test_dist_fused_zbuild_exact_all_backends(lowrank_tensor, P, path,
                                              backend):
    """Acceptance: the fused Z-build→oracle pipeline is f32-exact against
    the unfused block path on every comm backend (same partition, same
    start panel, same Krylov walk)."""
    _need_devices(P)
    from repro.distributed.dist_hooi import dist_hooi

    t = lowrank_tensor
    _, sa = dist_hooi(t, (2, 2, 2), P, scheme="lite", n_invocations=2,
                      seed=0, path=path, lanczos_block=4,
                      fused_zbuild=False, use_kernel=False)
    _, sb = dist_hooi(t, (2, 2, 2), P, scheme="lite", n_invocations=2,
                      seed=0, path=path, lanczos_block=4, fused_zbuild=True,
                      use_kernel=False)
    assert set(sa.comm_backends.values()) == {backend}
    np.testing.assert_array_equal(np.asarray(sa.fits), np.asarray(sb.fits))
    assert not sa.fused_zbuild and sb.fused_zbuild
    # the fused pipeline saves exactly one counted pass over Z per mode
    for n in sa.z_passes:
        assert sb.z_passes[n] == sa.z_passes[n] - 1


@pytest.mark.slow
@pytest.mark.parametrize("P,path", [(1, "liteopt"), (4, "baseline"),
                                    (4, "liteopt")])
def test_dist_bf16_within_bound_all_backends(lowrank_tensor, P, path):
    """Acceptance: bf16 stays within the documented fit bound of f32 on
    every comm backend and reports the resolved precision."""
    _need_devices(P)
    from repro.distributed.dist_hooi import dist_hooi

    t = lowrank_tensor
    _, sf = dist_hooi(t, (2, 2, 2), P, scheme="lite", n_invocations=2,
                      seed=0, path=path)
    _, sb = dist_hooi(t, (2, 2, 2), P, scheme="lite", n_invocations=2,
                      seed=0, path=path, precision="bf16")
    assert sb.precision == "bf16" and sf.precision == "f32"
    assert sb.fits[-1] > 0.99
    assert max(abs(a - b) for a, b in zip(sf.fits, sb.fits)) < 1e-2


@pytest.mark.slow
@pytest.mark.parametrize("s", [4, 8])
def test_dist_block_convergence_all_backends(lowrank_tensor, s):
    """Acceptance: block Lanczos converges on P=4 boundary (the TPU-native
    path) at both panel widths, with niter counted in blocks."""
    _need_devices(4)
    from repro.distributed.dist_hooi import dist_hooi

    t = lowrank_tensor
    _, st = dist_hooi(t, (2, 2, 2), 4, scheme="lite", n_invocations=2,
                      seed=0, path="liteopt", lanczos_block=s)
    assert st.fits[-1] > 0.999
    # panels are clamped per mode: never wider than min(2k, L_n, K_hat)
    for n, width in st.lanczos_block.items():
        assert width == effective_block_size(
            2, t.shape[n], 4, s)


# --------------------------------------- step-key and rerun per variant
def test_step_key_discriminates_variants():
    """(precision, block_size, fused_zbuild) must all be part of the
    compiled-step signature — no cache aliasing between variants."""
    from repro.distributed.executor import HooiExecutor

    ex = HooiExecutor(1)
    mp = type("MP", (), dict(mode=0, R_pad=8, Lp=8, S_pad=4))()
    base = ex._step_key(mp, "liteopt", 2, 4, use_kernel=True)
    assert base == ex._step_key(mp, "liteopt", 2, 4, use_kernel=True)
    variants = [
        ex._step_key(mp, "liteopt", 2, 4, use_kernel=True,
                     precision="bf16"),
        ex._step_key(mp, "liteopt", 2, 4, use_kernel=True, block_size=4),
        ex._step_key(mp, "liteopt", 2, 4, use_kernel=True,
                     fused_zbuild=True),
        ex._step_key(mp, "liteopt", 2, 4, use_kernel=True,
                     precision="bf16", block_size=4, fused_zbuild=True),
    ]
    keys = {base, *variants}
    assert len(keys) == 1 + len(variants)


@pytest.mark.slow
def test_rerun_contract_per_variant(lowrank_tensor):
    """Acceptance: each roofline variant compiles its own steps once; the
    cached-plan rerun of the *same* variant is 0 new jit / 0 new uploads,
    and switching variants never aliases into another variant's cache."""
    _need_devices(2)
    from repro.core.plan import plan
    from repro.distributed.executor import HooiExecutor

    t = lowrank_tensor
    ex = HooiExecutor(2)
    pl = plan(t, "lite", 2, core_dims=(2, 2, 2), path="liteopt")
    variants = [
        dict(),
        dict(precision="bf16"),
        dict(lanczos_block=4),
        dict(lanczos_block=4, fused_zbuild=True, precision="bf16"),
    ]
    for kw in variants:
        _, s1 = ex.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                       path="liteopt", **kw)
        # new variant -> its own compilations (no aliasing onto a cached
        # variant's executables)
        assert s1.step_compilations == t.ndim, (kw, s1.step_compilations)
        _, s2 = ex.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                       path="liteopt", **kw)
        assert s2.step_compilations == 0, kw
        assert s2.uploads == 0, kw
        assert s2.upload_cache_hit, kw


# -------------------------------------------------- bf16 calibration fit
def test_fit_cost_model_attaches_bf16_rate():
    """phase="ttm" samples labelled precision="bf16" yield the dedicated
    bf16 TTM rate without perturbing the f32 phase fit."""
    f32 = [
        {"critical_path_flops": 2e9, "ttm_flops": 2e9, "svd_flops": 0,
         "comm_bytes": 0.0, "seconds": 2.0, "warm": True, "phase": "ttm"},
        {"critical_path_flops": 3e9, "ttm_flops": 2e9, "svd_flops": 1e9,
         "comm_bytes": 0.0, "seconds": 3.0, "warm": True, "phase": "sweep"},
    ]
    bf16 = [
        {"critical_path_flops": 2e9, "ttm_flops": 2e9, "svd_flops": 0,
         "comm_bytes": 0.0, "seconds": 1.0, "warm": True, "phase": "ttm",
         "precision": "bf16"},
    ]
    cm = fit_cost_model(f32 + bf16)
    assert cm.ttm_flop_rate_bf16 == pytest.approx(2e9)
    assert cm.ttm_flop_rate == pytest.approx(1e9)  # bf16 sample excluded
    assert "+bf16" in cm.source
    # no bf16-labelled samples -> field stays None
    cm2 = fit_cost_model(f32)
    assert cm2.ttm_flop_rate_bf16 is None


def test_cost_model_rejects_nonpositive_bf16_rate():
    with pytest.raises(ValueError):
        CostModel(ttm_flop_rate_bf16=-1.0)


@pytest.mark.slow
def test_profile_phases_labels_precision(lowrank_tensor):
    """profile_phases(precision="bf16") labels its samples so the fitted
    model carries a bf16 rate the auto policy can consult."""
    _need_devices(2)
    from repro.distributed.executor import HooiExecutor

    t = lowrank_tensor
    ex = HooiExecutor(2)
    ex.profile_phases(t, (2, 2, 2), scheme="lite", path="liteopt",
                      repeats=1)
    ex.profile_phases(t, (2, 2, 2), scheme="lite", path="liteopt",
                      repeats=1, precision="bf16")
    labels = {s.get("precision") for s in ex.calibration_samples()}
    assert labels == {"f32", "bf16"}
    cm = fit_cost_model(ex.calibration_samples())
    assert cm.ttm_flop_rate_bf16 is not None and cm.ttm_flop_rate_bf16 > 0
    # the auto policy flips once the fitted bf16 rate clears the margin
    fast = dataclasses.replace(
        cm, ttm_flop_rate_bf16=2 * (cm.ttm_flop_rate or cm.flop_rate))
    set_cost_model(fast)
    assert resolve_precision("auto") == "bf16"
