"""Sketch-accelerated oracles: randomized range-finder warm starts and the
adaptive per-mode rank policy.

Pins the tentpole's contracts:

* ``warm_start="none"`` reproduces the historical HOOI trajectory bitwise
  (the default path is untouched);
* ``warm_start="sketch"`` reaches the full-GK fit within 1e-3 at a strictly
  lower counted-oracle-pass budget, locally and through the executor;
* sketch modes widen the start panel to ``>= k`` (``sketch_block_size``) —
  a narrower factor seed degrades into a cold half-budget Krylov run;
* ``choose_warm_start("auto")`` settles per mode by counted Z passes;
* ``adapt_rank`` grows on energetic tails, shrinks on collapsed ones, and
  is monotone in the spectrum ratios;
* executor reruns per (warm_start, rank) variant keep the 0-jit/0-upload
  contract, and ``rescore_plan`` reruns upload nothing.

In-process multi-device tests rely on conftest.py setting 8 simulated host
devices before jax initializes.
"""

import numpy as np
import pytest

from repro.core.lanczos import effective_block_size, lanczos_niter
from repro.core.sketch import (
    DEFAULT_POWER_ITERS,
    SKETCH_KINDS,
    adapt_rank,
    range_finder,
    seeded_start_panel,
    sketch_block_size,
    sketch_niter,
)
# aliased so pytest doesn't collect the library function as a test
from repro.core.sketch import test_matrix as sketch_test_matrix
from repro.engine.oracle import choose_warm_start, count_z_passes


def _need_devices(n):
    import jax

    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} simulated devices (conftest sets XLA_FLAGS)")


# ------------------------------------------------------ counting & widths
def test_sketch_niter_halves_the_gk_budget():
    """Refinement budget is min(k, ...) vs full GK's min(2k, ...)."""
    assert sketch_niter(10, 120, 100) == 10
    assert lanczos_niter(10, 120, 100) == 20
    # clamped by the operator, exactly like the full driver
    assert sketch_niter(10, 6, 100) == 6
    assert sketch_niter(10, 120, 4) == 4
    # block counting: ceil(base / s)
    assert sketch_niter(10, 120, 100, block_size=4) == 3
    assert sketch_niter(10, 120, 100, block_size=10) == 1
    # never zero, even for degenerate operators
    assert sketch_niter(1, 1, 1) == 1


def test_sketch_block_size_widens_to_rank():
    """Sketch panels are at least k wide (clamped to the vector budget):
    the factor seed must span the whole previous subspace or the warm
    start silently becomes a cold run on half the budget."""
    for k, nr, nc, req in [(10, 120, 100, 1), (10, 120, 100, 4),
                           (4, 60, 9, 1), (2, 3, 50, 8), (6, 200, 5, 1)]:
        s = sketch_block_size(k, nr, nc, req)
        assert s == effective_block_size(k, nr, nc, max(req, k))
        assert s >= min(k, lanczos_niter(k, nr, nc))
        # idempotent: re-widening an already-widened panel is a no-op
        assert sketch_block_size(k, nr, nc, s) == s
    # a request wider than k passes through (still clamped)
    assert sketch_block_size(4, 120, 100, 6) == 6


def test_count_z_passes_sketch_accounting():
    """1 build + 2/iter, minus the fused first read, plus seed+power."""
    assert count_z_passes(20) == 41
    assert count_z_passes(20, fused_zbuild=True) == 40
    assert count_z_passes(1, warm_start="sketch",
                          power_iters=1) == 1 + 2 + 1 + 2
    assert count_z_passes(2, warm_start="sketch", power_iters=0) == 6


def test_choose_warm_start_decisions():
    # explicit modes pass through untouched
    assert choose_warm_start("none", 10, 120, 100) == "none"
    assert choose_warm_start("sketch", 1, 2, 2) == "sketch"
    # k=10: full GK counts 41 passes, the widened sketch counts 6
    assert choose_warm_start("auto", 10, 120, 100) == "sketch"
    # k=1: full GK counts 5, sketch counts 6 -> stays cold
    assert choose_warm_start("auto", 1, 120, 100) == "none"
    # deterministic in the static geometry (executor/local must agree)
    assert (choose_warm_start("auto", 10, 120, 100)
            == choose_warm_start("auto", 10, 120, 100))


# ----------------------------------------------------- sketch primitives
def test_test_matrix_kinds_and_shapes():
    import jax

    key = jax.random.PRNGKey(0)
    for kind in SKETCH_KINDS:
        om = np.asarray(sketch_test_matrix(key, 37, 5, kind))
        assert om.shape == (37, 5)
        assert np.all(np.isfinite(om))
        # distinct columns (a degenerate sketch would alias directions)
        g = om.T @ om
        assert np.linalg.matrix_rank(g) == 5
    with pytest.raises(ValueError, match="unknown sketch kind"):
        sketch_test_matrix(key, 8, 2, "rademacher")


def test_seeded_start_panel_orthonormal_and_padded():
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(7)
    seed = jax.random.normal(key, (20, 3), jnp.float32)
    q = np.asarray(seeded_start_panel(seed, key, 20, 5))
    assert q.shape == (20, 5)
    np.testing.assert_allclose(q.T @ q, np.eye(5), atol=1e-5)
    # the first w columns span the seed exactly (QR preserves the span)
    proj = q @ (q.T @ np.asarray(seed))
    np.testing.assert_allclose(proj, np.asarray(seed), atol=1e-4)
    # deterministic per (key, shape)
    q2 = np.asarray(seeded_start_panel(seed, key, 20, 5))
    assert np.array_equal(q, q2)
    # no padding needed when the seed is already wide enough
    q3 = np.asarray(seeded_start_panel(seed, key, 20, 2))
    assert q3.shape == (20, 2)


def test_range_finder_recovers_leading_subspace(small_tensor):
    """The sketch's left basis captures (almost) the leading-k energy of
    the exact penultimate matrix, and its spectrum estimate is ordered."""
    import jax
    import jax.numpy as jnp
    from repro.core import ttm
    from repro.core.hooi import random_factors

    t = small_tensor
    factors = random_factors(t.shape, (4, 4, 4), jax.random.PRNGKey(2))
    coords = jnp.asarray(t.coords, jnp.int32)
    values = jnp.asarray(t.values, jnp.float32)
    k = 4
    Z = np.asarray(ttm.penultimate_local(
        coords, values, coords[:, 0], factors, 0, t.shape[0]))
    sv_exact = np.linalg.svd(Z, compute_uv=False)
    U, sv_est = range_finder(coords, values, coords[:, 0], factors, 0,
                             t.shape[0], k, jax.random.PRNGKey(9),
                             oversample=4, power_iters=2)
    U, sv_est = np.asarray(U), np.asarray(sv_est)
    assert U.shape == (t.shape[0], k) and sv_est.shape == (k,)
    np.testing.assert_allclose(U.T @ U, np.eye(k), atol=1e-4)
    captured = np.linalg.norm(U.T @ Z)
    exact = np.linalg.norm(sv_exact[:k])
    assert captured >= 0.9 * exact
    # spectrum estimate: sorted, positive, never above the true sigma_1
    assert np.all(np.diff(sv_est) <= 1e-5) and sv_est[0] > 0
    assert sv_est[0] <= sv_exact[0] * (1 + 1e-4)


# --------------------------------------------------------- rank policy
def test_adapt_rank_grow_shrink_and_clamps():
    # energetic tail -> grow by grow_step, clamped by k_max
    assert adapt_rank([1.0, 0.9, 0.8], 3, grow_thresh=0.5, k_max=8) == 5
    assert adapt_rank([1.0, 0.9, 0.8], 3, grow_thresh=0.5, k_max=4) == 4
    # k_max=None clamps growth at the current k
    assert adapt_rank([1.0, 0.9, 0.8], 3, grow_thresh=0.5) == 3
    # collapsed tail -> shrink to the energetic column count
    assert adapt_rank([1.0, 0.5, 1e-4, 1e-5], 4, grow_thresh=0.6,
                      shrink_thresh=0.01, k_min=2, k_max=8) == 2
    # k_min floor holds even when everything but sigma_1 collapsed
    assert adapt_rank([1.0, 1e-9, 1e-9], 3, shrink_thresh=0.5,
                      k_min=2, k_max=8) == 2
    # flat-enough tail inside the [shrink, grow] band -> keep k
    assert adapt_rank([1.0, 0.6, 0.3], 3, grow_thresh=0.5,
                      shrink_thresh=0.1, k_max=8) == 3
    # degenerate spectra never move the rank
    assert adapt_rank([], 3, k_max=8) == 3
    assert adapt_rank([0.0, 0.0], 3, k_max=8) == 3
    assert adapt_rank([np.nan, 1.0], 3, k_max=8) == 3


def test_adapt_rank_monotone_in_tail_ratios():
    """Holding k fixed, boosting any ratio sigma_j/sigma_1 never lowers
    the decided rank — the property the streaming scheduler leans on."""
    rng = np.random.default_rng(11)
    for _ in range(50):
        k = int(rng.integers(2, 7))
        s = np.sort(rng.uniform(0.0, 1.0, k))[::-1]
        s[0] = 1.0
        j = int(rng.integers(1, k))
        boosted = s.copy()
        boosted[j:] = np.minimum(
            np.maximum(boosted[j:], rng.uniform(boosted[j], 1.0)), 1.0)
        boosted = np.sort(boosted)[::-1]
        lo = adapt_rank(s, k, grow_thresh=0.4, shrink_thresh=0.1, k_max=12)
        hi = adapt_rank(boosted, k, grow_thresh=0.4, shrink_thresh=0.1,
                        k_max=12)
        assert hi >= lo


# ----------------------------------------------------- local HOOI parity
def test_warm_start_none_is_bitwise_default(small_tensor, monkeypatch):
    """With no env override, the default trajectory IS warm_start="none",
    bitwise — the historical path is untouched code. (Cleared explicitly:
    CI's sketch leg exports REPRO_WARM_START=sketch, which legitimately
    changes what ``None`` resolves to.)"""
    from repro.core.hooi import hooi

    monkeypatch.delenv("REPRO_WARM_START", raising=False)
    _, fits_default = hooi(small_tensor, (3, 3, 3), n_invocations=2, seed=0)
    _, fits_none = hooi(small_tensor, (3, 3, 3), n_invocations=2, seed=0,
                        warm_start="none")
    assert fits_default == fits_none  # bitwise, not approximately


def test_sketch_matches_full_gk_fit_local(lowrank_tensor):
    """Equal-quality contract at the reduced pass budget, single process."""
    from repro.core.hooi import hooi

    t = lowrank_tensor
    _, fits_full = hooi(t, (2, 2, 2), n_invocations=3, seed=0,
                        warm_start="none")
    _, fits_sk = hooi(t, (2, 2, 2), n_invocations=3, seed=0,
                      warm_start="sketch")
    assert fits_full[-1] > 0.99
    assert abs(fits_sk[-1] - fits_full[-1]) < 1e-3
    # and the counted budget actually dropped for this geometry
    k, nr, nc = 2, t.shape[0], t.shape[1] * t.shape[2]
    full = count_z_passes(lanczos_niter(k, nr, nc, 1))
    s_sk = sketch_block_size(k, nr, nc, 1)
    sk = count_z_passes(sketch_niter(k, nr, nc, s_sk), warm_start="sketch",
                        power_iters=DEFAULT_POWER_ITERS)
    assert sk < full


def test_auto_matches_its_per_mode_choice(small_tensor):
    """warm_start="auto" equals rerunning with each mode's settled choice
    — the resolution happens before any trace, never inside one."""
    from repro.core.hooi import hooi

    t = small_tensor
    k = 3
    choices = []
    for n in range(t.ndim):
        khat = k ** (t.ndim - 1)
        s_eff = effective_block_size(k, t.shape[n], khat, 1)
        choices.append(choose_warm_start("auto", k, t.shape[n], khat, s_eff))
    assert len(set(choices)) == 1  # uniform on this geometry
    _, fits_auto = hooi(t, (k,) * 3, n_invocations=2, seed=0,
                        warm_start="auto")
    _, fits_settled = hooi(t, (k,) * 3, n_invocations=2, seed=0,
                           warm_start=choices[0])
    assert fits_auto == fits_settled


# ------------------------------------------------- executor contracts
@pytest.fixture
def executor():
    _need_devices(4)
    from repro.distributed.executor import HooiExecutor

    return HooiExecutor(4)


@pytest.mark.slow
def test_executor_sketch_fit_and_stats(executor, small_tensor):
    """Distributed sketch matches the local sketch trajectory and reports
    per-mode warm-start modes, spectra, and the reduced pass counts.

    Compared on ``small_tensor`` (fit ~0.2, well-conditioned) — a
    saturated fit of ~1.0 turns the ``||T||² − ||G||²`` cancellation into
    1e-4-scale noise and the trajectories can't be compared tightly."""
    from repro.core.hooi import hooi
    from repro.core.plan import plan

    t = small_tensor
    k = 3
    pl = plan(t, "lite", 4, core_dims=(k, k, k))
    dec, stats = executor.run(t, (k, k, k), pl, n_invocations=3, seed=0,
                              warm_start="sketch")
    _, fits_local = hooi(t, (k, k, k), n_invocations=3, seed=0,
                         warm_start="sketch")
    np.testing.assert_allclose(stats.fits, fits_local, atol=1e-6)
    assert stats.warm_start == {n: "sketch" for n in range(t.ndim)}
    assert set(stats.mode_spectra) == set(range(t.ndim))
    for n, sv in stats.mode_spectra.items():
        assert sv.shape[0] >= 1 and np.all(np.isfinite(sv))
    for n in range(t.ndim):
        khat = k * k
        s_sk = sketch_block_size(k, t.shape[n], khat, 1)
        want = count_z_passes(sketch_niter(k, t.shape[n], khat, s_sk),
                              warm_start="sketch",
                              power_iters=DEFAULT_POWER_ITERS)
        assert stats.z_passes[n] == want


@pytest.mark.slow
def test_rerun_contract_under_sketch(executor, lowrank_tensor):
    """The 0-jit/0-upload rerun contract holds per warm-start variant."""
    from repro.core.plan import plan

    t = lowrank_tensor
    pl = plan(t, "lite", 4, core_dims=(2, 2, 2))
    _, s1 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                         warm_start="sketch")
    assert s1.step_compilations == t.ndim
    assert s1.uploads == 9 * t.ndim + 2
    _, s2 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=1,
                         warm_start="sketch")
    assert s2.step_compilations == 0
    assert s2.uploads == 0
    assert s2.step_cache_hits == t.ndim


@pytest.mark.slow
def test_step_key_discriminates_warm_start(executor, lowrank_tensor):
    """Switching warm_start compiles fresh steps (the traced graphs
    differ) but re-uses every uploaded device array."""
    from repro.core.plan import plan

    t = lowrank_tensor
    pl = plan(t, "lite", 4, core_dims=(2, 2, 2))
    _, s1 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                         warm_start="none")
    assert s1.step_compilations == t.ndim
    _, s2 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                         warm_start="sketch")
    assert s2.step_compilations == t.ndim  # new (warm_start) step keys
    assert s2.uploads == 0  # same plan parts -> no data movement
    # and flipping back hits the original compiled steps again
    _, s3 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                         warm_start="none")
    assert s3.step_compilations == 0 and s3.uploads == 0


@pytest.mark.slow
def test_rescore_plan_rerun_uploads_nothing(executor, lowrank_tensor):
    """The adaptive-rank reselect rung: a rescored plan shares the same
    parts, so running it moves no data and compiles only the new-K steps."""
    from repro.core.plan import plan, rescore_plan

    t = lowrank_tensor
    pl = plan(t, "lite", 4, core_dims=(2, 2, 2))
    _, s1 = executor.run(t, (2, 2, 2), pl, n_invocations=1, seed=0,
                         warm_start="sketch")
    assert s1.uploads == 9 * t.ndim + 2
    pl3 = rescore_plan(pl, t, (3, 3, 3))
    assert pl3.parts is pl.parts
    _, s2 = executor.run(t, (3, 3, 3), pl3, n_invocations=1, seed=0,
                         warm_start="sketch")
    assert s2.uploads == 0  # same parts tuple -> upload cache hit
    assert s2.step_compilations == t.ndim  # new K_n -> genuinely new steps


@pytest.mark.slow
@pytest.mark.parametrize("P,path,backend", [
    (1, "liteopt", "local"),
    (4, "baseline", "psum"),
    (4, "liteopt", "boundary"),
])
def test_sketch_matches_full_gk_on_every_backend(P, path, backend,
                                                 small_tensor):
    """The equal-fit contract holds however oracle answers cross the
    mesh — the warm start changes the Krylov start panel, never the comm."""
    _need_devices(P)
    from repro.distributed.dist_hooi import dist_hooi

    t = small_tensor
    _, s_full = dist_hooi(t, (3, 3, 3), P, scheme="lite", path=path,
                          n_invocations=3, seed=0, warm_start="none")
    _, s_sk = dist_hooi(t, (3, 3, 3), P, scheme="lite", path=path,
                        n_invocations=3, seed=0, warm_start="sketch")
    assert set(s_sk.comm_backends.values()) == {backend}
    assert abs(s_sk.fits[-1] - s_full.fits[-1]) < 1e-3
    assert all(v == "sketch" for v in s_sk.warm_start.values())
    assert all(v == "none" for v in s_full.warm_start.values())
