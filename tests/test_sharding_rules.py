"""Sharding-rule unit tests (subprocess with a 2x4 mesh)."""

import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8, timeout=600) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        from repro.jax_compat import make_mesh_auto as _mk_mesh
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


@pytest.mark.slow
def test_param_specs_and_divisibility_guards():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.models import transformer as tfm
        from repro.launch import sharding as shr

        mesh = _mk_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen2-1.5b", smoke=True)
        shapes = jax.eval_shape(
            lambda k: tfm.init_params(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = shr.param_specs(mesh, shapes)

        # embedding: (padded_vocab, d) -> vocab on model, d on data
        emb = specs["embed"]["table"]
        assert emb == P("model", ("data",)), emb
        # stacked attn wq: (L, d, H*hd) -> (None, fsdp, tp)
        wq = specs["seg0"]["attn"]["wq"]["w"]
        assert wq == P(None, ("data",), "model"), wq
        # norm scales replicated
        sc = specs["seg0"]["norm1"]["scale"]
        assert all(e is None for e in sc), sc

        # divisibility guard: a dim of 7 can't shard on 4-way model axis
        bad = jax.ShapeDtypeStruct((10, 7), jnp.float32)
        spec = shr.param_specs(mesh, {"mlp": {"up": {"w": bad}}})
        entries = tuple(spec["mlp"]["up"]["w"])
        assert entries[1] is None, entries  # 7 % 4 != 0 -> replicated

        # batch specs shard dim0 over dp
        bsp = shr.batch_specs(mesh, {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)})
        assert bsp["tokens"][0] in ("data", ("data",))
        print("SHARDING_OK")
    """)
    assert "SHARDING_OK" in out


@pytest.mark.slow
def test_small_dryrun_cell_on_8_devices():
    """The dry-run machinery end-to-end on a small mesh: lower+compile a
    smoke config train step with the production sharding rules."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.launch import sharding as shr
        from repro.train import train_step as ts
        from repro.train.optimizer import AdamWConfig
        from repro.launch.hlo_analysis import analyze_hlo

        mesh = _mk_mesh((2, 4), ("data", "model"))
        cfg = get_config("granite-3-2b", smoke=True)
        key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
        step = ts.make_train_step(cfg, AdamWConfig(), remat=True,
                                  hint=shr.make_hint_fn(mesh),
                                  act_dtype=jnp.bfloat16, moe_groups=2)
        state_shape = jax.eval_shape(lambda k: ts.make_train_state(cfg, k),
                                     key_spec)
        batch_shape = {
            "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
        }
        state_sh = shr.state_shardings(mesh, state_shape)
        batch_sh = shr.batch_shardings(mesh, batch_shape)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh, NamedSharding(mesh, P())),
                out_shardings=(state_sh, None)).lower(
                state_shape, batch_shape, key_spec)
            compiled = lowered.compile()
        st = analyze_hlo(compiled.as_text())
        assert st.flops > 0
        ma = compiled.memory_analysis()
        assert ma.argument_size_in_bytes > 0
        print("DRYRUN_SMALL_OK")
    """)
    assert "DRYRUN_SMALL_OK" in out
