"""Root pytest config: device setup + shared small-tensor fixtures.

XLA locks the host device count at first jax init, so it must be set before
any test module imports jax. 8 simulated host devices let in-process
distributed tests (tests/test_plan.py) run without a subprocess; the
subprocess-based tests (test_dist_hooi.py etc.) pop XLA_FLAGS from their
child environments and set their own counts, so they are unaffected.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running tests (multi-device subprocesses, full HOOI "
        "runs); deselect with -m 'not slow'",
    )


# --------------------------------------------------------- shared fixtures
@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def small_tensor():
    """Seeded random 3-way sparse tensor, deduplicated — cheap everywhere."""
    from repro.core.coo import SparseTensor

    r = np.random.default_rng(42)
    shape = (24, 18, 15)
    coords = np.stack([r.integers(0, L, 400) for L in shape], axis=1)
    return SparseTensor(coords, r.standard_normal(400), shape).dedup()


@pytest.fixture
def skewed_tensor():
    """Hub-slice tensor (the paper's pathological-for-CoarseG regime)."""
    from repro.data.tensors import synth_tensor

    return synth_tensor((30, 80, 80), 5_000, alphas=(1.2, 1.0, 1.0),
                        hub_fraction=0.3, hub_modes=(0,), seed=7)


@pytest.fixture
def lowrank_tensor():
    """Exactly rank-(2,2,2) dense tensor as COO — HOOI fit converges to ~1,
    which makes tight cross-implementation fit comparisons meaningful."""
    from repro.core.coo import SparseTensor

    r = np.random.default_rng(3)
    G = r.standard_normal((2, 2, 2))
    A = [r.standard_normal((L, 2)) for L in (12, 10, 8)]
    dense = np.einsum("abc,ia,jb,kc->ijk", G, A[0], A[1], A[2])
    return SparseTensor.fromdense(dense.astype(np.float32))
